"""§4.2/§4.3 reproduction: multipass iteration costs, under the unified
iterative executor.

  * logregr IRLS and k-means (fused Lloyd) per-iteration cost and
    iterations/sec, local vs sharded engine — the executor's compiled
    ``lax.while_loop``/``scan`` fast path means the whole fit is one XLA
    program on either engine.
  * driver overhead: compiled loop vs the paper-faithful host driver
    (``mode="host"``), reproducing the paper's "driver overhead is a
    fraction of a second" claim.
  * k-means two-pass (paper-faithful, 2 scans/round) vs fused single
    pass (footnote 1: "cannot be expressed in standard SQL").

``run()`` feeds the CSV harness (benchmarks/run.py); ``python -m
benchmarks.bench_iterative [--json out.json]`` emits a JSON document for
the bench trajectory.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core import Table, synthetic_classification_table
from repro.core.compat import make_mesh
from repro.core.iterative import fit
from repro.methods.kmeans import KMeansTask, kmeans_fit
from repro.methods.logregr import IRLSTask, logregr


def _time_fit(task_factory, table, *, iters: int, reps: int,
              mode: str = "compiled") -> float:
    """Steady-state seconds per iteration of a fixed-count fit.

    ``fit()`` jits a fresh closure per call, so a naive warmup never warms
    anything and a single timing would be compile-dominated.  Instead we
    time counted fits of ``iters`` and ``2·iters`` rounds and divide the
    delta — compile time (length-independent for a rolled scan) and fixed
    setup cancel, leaving the marginal per-iteration cost."""
    def run_n(n: int) -> float:
        t0 = time.perf_counter()
        res = fit(task_factory(), table, max_iters=n, tol=None, mode=mode)
        jax.block_until_ready(jax.tree.leaves(res.state)[0])
        return time.perf_counter() - t0
    run_n(iters)
    run_n(2 * iters)  # warm persistent caches / autotuning
    delta = 0.0
    for _ in range(reps):
        t1 = run_n(iters)
        t2 = run_n(2 * iters)
        delta += t2 - t1
    return max(delta / (reps * iters), 1e-9)


def bench(rows: int = 100_000, k_vars: int = 20, k_clusters: int = 8,
          dims: int = 16, iters: int = 10, reps: int = 3) -> dict:
    key = jax.random.PRNGKey(0)
    out: dict = {"config": {"rows": rows, "k_vars": k_vars,
                            "k_clusters": k_clusters, "dims": dims,
                            "iters": iters, "reps": reps,
                            "n_devices": jax.device_count()}}

    mesh = make_mesh((jax.device_count(),), ("data",))

    # --- logregr IRLS ----------------------------------------------------
    tbl, _ = synthetic_classification_table(key, rows, k_vars)
    engines = {"local": tbl, "sharded": tbl.distribute(mesh)}
    out["logregr_irls"] = {}
    for name, t in engines.items():
        s = _time_fit(IRLSTask, t, iters=iters, reps=reps)
        out["logregr_irls"][name] = {"per_iter_s": s, "iters_per_sec": 1 / s}
    s_host = _time_fit(IRLSTask, tbl, iters=iters, reps=reps, mode="host")
    out["logregr_irls"]["host_mode"] = {"per_iter_s": s_host,
                                        "iters_per_sec": 1 / s_host}
    out["logregr_irls"]["driver_overhead_s"] = max(
        s_host - out["logregr_irls"]["local"]["per_iter_s"], 0.0)
    res = logregr(tbl, max_iters=30)
    out["logregr_irls"]["iters_to_converge"] = res.n_iters

    # --- k-means ---------------------------------------------------------
    kk = jax.random.split(key, 3)
    centers = jax.random.normal(kk[0], (k_clusters, dims)) * 4
    pts = centers[jax.random.randint(kk[1], (rows,), 0, k_clusters)] \
        + jax.random.normal(kk[2], (rows, dims))
    tblk = Table.from_columns({"x": pts})
    seed_c = jax.random.normal(kk[0], (k_clusters, dims)) * 2
    out["kmeans"] = {}
    for name, t in (("local", tblk), ("sharded", tblk.distribute(mesh))):
        s = _time_fit(lambda: KMeansTask(seed_c), t, iters=iters, reps=reps)
        out["kmeans"][name] = {"per_iter_s": s, "iters_per_sec": 1 / s}
    for variant in ("fused", "two_pass"):
        t0 = time.perf_counter()
        r = kmeans_fit(tblk, k_clusters, init_centroids=seed_c,
                       max_iters=iters, variant=variant)
        dt = (time.perf_counter() - t0) / r.n_iters
        out["kmeans"][f"{variant}_fit_per_iter_s"] = dt
    return out


def run(rows: int = 100_000, k_vars: int = 20, reps: int = 3):
    """CSV rows for benchmarks/run.py: (name, us_per_call, derived)."""
    r = bench(rows=rows, k_vars=k_vars, reps=reps)
    res = []
    for method in ("logregr_irls", "kmeans"):
        for eng in ("local", "sharded"):
            e = r[method][eng]
            res.append((f"{method}_{eng}_per_iter", e["per_iter_s"] * 1e6,
                        f"iters_per_sec={e['iters_per_sec']:.1f}"))
    res.append(("logregr_driver_overhead",
                r["logregr_irls"]["driver_overhead_s"] * 1e6,
                f"iters={r['logregr_irls']['iters_to_converge']}"))
    for variant in ("fused", "two_pass"):
        res.append((f"kmeans_{variant}_per_iter",
                    r["kmeans"][f"{variant}_fit_per_iter_s"] * 1e6, ""))
    return res


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the JSON document here (default: stdout)")
    ap.add_argument("--rows", type=int, default=100_000)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()
    doc = bench(rows=args.rows, iters=args.iters, reps=args.reps)
    text = json.dumps(doc, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.json}")
    else:
        print(text)
