"""§4.2/§4.3 reproduction: multipass iteration costs.

  * logregr IRLS: per-iteration time + iterations-to-converge (the paper's
    "driver overhead is a fraction of a second" claim — we report the
    driver overhead separately from the aggregate time).
  * k-means: the paper's two-pass limitation vs the fused single pass XLA
    enables (footnote 1: "cannot be expressed in standard SQL").
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import Table, synthetic_classification_table
from repro.methods.kmeans import kmeans_fit
from repro.methods.logregr import IRLSAggregate, logregr
from repro.core.aggregates import run_local


def run(rows: int = 100_000, k_vars: int = 20, reps: int = 3):
    key = jax.random.PRNGKey(0)
    results = []

    # --- IRLS ------------------------------------------------------------
    tbl, _ = synthetic_classification_table(key, rows, k_vars)
    beta = jnp.zeros((k_vars,))
    agg = IRLSAggregate(beta)
    fn = jax.jit(lambda cols: agg.transition(
        agg.init(cols), cols, jnp.ones((rows,), bool)))
    for _ in range(1):
        jax.block_until_ready(fn(dict(tbl.columns)))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(dict(tbl.columns)))
    per_iter = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    res = logregr(tbl, max_iters=30)
    total = time.perf_counter() - t0
    driver_overhead = total - res.n_iters * per_iter
    results.append(("logregr_irls_per_iter", per_iter * 1e6,
                    f"iters={res.n_iters}"))
    results.append(("logregr_driver_overhead", max(driver_overhead, 0.0)
                    * 1e6, f"frac={max(driver_overhead, 0) / total:.2f}"))

    # --- k-means: two-pass (paper-faithful) vs fused ----------------------
    kk = jax.random.split(key, 3)
    centers = jax.random.normal(kk[0], (8, 16)) * 4
    pts = centers[jax.random.randint(kk[1], (rows,), 0, 8)] \
        + jax.random.normal(kk[2], (rows, 16))
    tblk = Table.from_columns({"x": pts})
    seed_c = jax.random.normal(kk[0], (8, 16)) * 2
    for variant in ("two_pass", "fused"):
        t0 = time.perf_counter()
        out = kmeans_fit(tblk, 8, init_centroids=seed_c, max_iters=10,
                         variant=variant)
        dt = (time.perf_counter() - t0) / out.n_iters
        results.append((f"kmeans_{variant}_per_iter", dt * 1e6,
                        f"sse={out.sse:.3g}"))
    return results


if __name__ == "__main__":
    for name, us, extra in run():
        print(f"{name},{us:.1f},{extra}")
