"""Table 3 reproduction (§5.2): statistical text analytics throughput —
feature extraction, Viterbi, Gibbs, Metropolis-Hastings, q-gram matching."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import Table
from repro.core.aggregates import run_local
from repro.methods.crf import (crf_init_params, extract_features,
                               gibbs_sample, mh_sample, viterbi_decode)
from repro.methods.string_match import (TrigramIndexAggregate, approx_match,
                                        encode_strings, jaccard_scores,
                                        trigram_signature)


def _timeit(fn, reps=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def run(B: int = 256, T: int = 64, L: int = 5, F: int = 512):
    key = jax.random.PRNGKey(0)
    results = []
    toks = jax.random.randint(key, (B, T), 0, 5000)
    mask = jnp.ones((B, T), jnp.float32)

    dt = _timeit(jax.jit(lambda: extract_features(toks, F)))
    results.append(("text_feature_extraction", dt * 1e6,
                    f"tok_per_s={B * T / dt:.3g}"))

    params = crf_init_params(F, L, key, scale=0.3)
    feats = extract_features(toks, F)
    dt = _timeit(jax.jit(lambda: viterbi_decode(params, feats, mask)))
    results.append(("viterbi_inference", dt * 1e6,
                    f"tok_per_s={B * T / dt:.3g}"))

    dt = _timeit(lambda: gibbs_sample(params, feats, mask, key,
                                      n_sweeps=10)[0])
    results.append(("mcmc_gibbs_10sweeps", dt * 1e6,
                    f"site_updates_per_s={10 * B * T / dt:.3g}"))

    dt = _timeit(lambda: mh_sample(params, feats, mask, key,
                                   n_steps=100)[0])
    results.append(("mcmc_mh_100steps", dt * 1e6, ""))

    corpus = [f"entity number {i} the quick brown fox" for i in range(2000)]
    chars = encode_strings(corpus)
    tbl = Table.from_columns({"chars": chars,
                              "doc_id": jnp.arange(len(corpus))})
    t0 = time.perf_counter()
    index = run_local(TrigramIndexAggregate(len(corpus), 512), tbl)
    jax.block_until_ready(index)
    dt_index = time.perf_counter() - t0
    results.append(("trigram_index_build", dt_index * 1e6,
                    f"docs_per_s={len(corpus) / dt_index:.3g}"))

    q = trigram_signature(encode_strings(["entity number 42"]), 512)[0]
    dt = _timeit(jax.jit(lambda: jaccard_scores(index, q)))
    results.append(("approx_string_match", dt * 1e6,
                    f"docs_per_s={len(corpus) / dt:.3g}"))
    return results


if __name__ == "__main__":
    for name, us, extra in run():
        print(f"{name},{us:.1f},{extra}")
