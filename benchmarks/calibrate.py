"""Measured cost calibration harness — fills the planner's statistics
catalog (:mod:`repro.core.calibration`).

Micro-benches every (engine x aggregate class x shape bucket) cell on
the CURRENT backend:

  * engines: ``local``, ``sharded`` (when >1 device), ``grouped-segment``
    / ``grouped-masked``, and their ``sharded-grouped-*`` variants —
    exactly the keys :func:`repro.core.plan.select_scan_engine` /
    :func:`select_grouped_method` look up;
  * aggregate classes: ``xtx`` (linregr-shaped dense normal equations)
    and ``sketch`` (integer count-min transitions); ``generic`` is the
    per-cell mean of the measured classes, the fallback bucket for
    aggregates that declare neither;
  * shape buckets: the ``--rows`` x ``--groups`` grid, nearest-bucket
    lookup in log2 space at plan time.

Each local cell also replays compiled-HLO cost analysis
(:func:`repro.launch.hlo_analysis.analyze` over the lowered fold) so the
JSON carries dot-FLOPs / bytes-accessed context next to the wall-clock
seconds — the roofline story for WHY a cell costs what it does.  The
grouped-block sweep times the segment engine across candidate block
sizes and records the measured best per bucket
(:func:`repro.core.aggregates.segment_block_size` consumes it).  On a
TPU backend the kernel tile sweep times the ``xtx`` / ``countmin``
Pallas kernels across row tiles and records the winner (the registry's
``supports`` rankers read it back through ``calibration.kernel_param``).

The output JSON changes nothing by itself — activation is explicit
(``calibration.use(path)`` / ``MADJAX_CALIBRATION=path``).

CI smoke: ``python -m benchmarks.calibrate --tiny --interpret --out
calibration_smoke.json`` — tiny buckets, plus ``--interpret`` runs every
registered Pallas kernel body in interpret mode against its jnp ref and
records the bit-identity verdicts under ``kernel_smoke``.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Table, run_grouped, run_local, run_sharded
from repro.core import calibration
from repro.core.aggregates import segment_block_size
from repro.kernels import registry as kernels
from repro.launch.hlo_analysis import analyze
from repro.methods.linregr import LinregrAggregate
from repro.methods.sketches import CountMinAggregate

from .roofline import _fmt_s

# aggregate class -> (factory, columns builder)
_DIMS = 8


def _xtx_cols(rng, rows):
    return {"x": jnp.asarray(rng.standard_normal((rows, _DIMS),
                                                 dtype=np.float32)),
            "y": jnp.asarray(rng.standard_normal(rows, dtype=np.float32))}


def _sketch_cols(rng, rows):
    return {"item": jnp.asarray(rng.integers(0, 10_000, rows)
                                .astype(np.int32))}


CLASSES = {
    "xtx": (LinregrAggregate, _xtx_cols),
    "sketch": (lambda: CountMinAggregate(4, 128), _sketch_cols),
}


def _time(fn, reps: int) -> float:
    fn()  # compile, untimed
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(jax.tree.leaves(out)[0])
        best = min(best, time.perf_counter() - t0)
    return best


def _hlo_context(agg, cols) -> dict:
    """Replayed compiled-HLO statistics for one masked fold over the
    bucket's columns — context metadata only, never consumed by lookup."""
    try:
        mask = jnp.ones(jax.tree.leaves(cols)[0].shape[0], jnp.bool_)

        def fold(c, m):
            return agg.transition(agg.init(c), c, m)

        txt = jax.jit(fold).lower(cols, mask).compile().as_text()
        stats = analyze(txt, {})
        return {"hlo_dot_flops": stats["dot_flops"],
                "hlo_bytes_accessed": stats["bytes_accessed"]}
    except Exception as e:  # HLO text dialects drift across jax releases
        return {"hlo_error": type(e).__name__}


def _skewed_gids(rng, rows: int, groups: int) -> jnp.ndarray:
    w = 1.0 / (np.arange(groups) + 1.0)
    return jnp.asarray(rng.choice(groups, rows, p=w / w.sum())
                       .astype(np.int32))


def _mesh():
    if len(jax.devices()) <= 1:
        return None
    from repro.core.compat import make_mesh
    n = len(jax.devices())
    return make_mesh((n,), ("data",))


def measure(rows_list, groups_list, reps: int, block_sizes) -> dict:
    """engines / grouped_block tables (see Calibration's schema)."""
    engines: dict[str, dict[str, list]] = {}
    grouped_block: list = []
    mesh = _mesh()

    def put(engine, cls, entry):
        engines.setdefault(engine, {}).setdefault(cls, []).append(entry)

    for rows in rows_list:
        rng = np.random.default_rng(rows)
        for cls, (make, build) in CLASSES.items():
            cols = build(rng, rows)
            tbl = Table.from_columns(cols)
            base = {"rows": rows, **_hlo_context(make(), cols)}
            s = _time(lambda: run_local(make(), tbl), reps)
            put("local", cls, {**base, "seconds": s})
            print(f"  local/{cls} rows={rows}: {_fmt_s(s)}")
            if mesh is not None:
                dist = tbl.distribute(mesh)
                s = _time(lambda: run_sharded(make(), dist), reps)
                put("sharded", cls, {"rows": rows, "seconds": s})
                print(f"  sharded/{cls} rows={rows}: {_fmt_s(s)}")

            for groups in groups_list:
                gids = _skewed_gids(rng, rows, groups)
                gtbl = Table.from_columns(dict(cols, g=gids))
                view = gtbl.group_by("g", groups)
                gb = {"rows": rows, "groups": groups}
                for method in ("segment", "masked"):
                    s = _time(lambda m=method: run_grouped(
                        make(), view, method=m), reps)
                    put(f"grouped-{method}", cls, {**gb, "seconds": s})
                    print(f"  grouped-{method}/{cls} rows={rows} "
                          f"groups={groups}: {_fmt_s(s)}")
                    if mesh is not None:
                        s = _time(lambda m=method: run_grouped(
                            make(), view, method=m, mesh=mesh), reps)
                        put(f"sharded-grouped-{method}", cls,
                            {**gb, "seconds": s})

        # grouped-block sweep: measured-best segment block size per
        # bucket (class-independent — the xtx workload is the driver)
        make, build = CLASSES["xtx"]
        cols = build(rng, rows)
        for groups in groups_list:
            gtbl = Table.from_columns(
                dict(cols, g=_skewed_gids(rng, rows, groups)))
            view = gtbl.group_by("g", groups)
            timed = {}
            for bs in block_sizes:
                if bs * 2 > max(rows, 1):
                    continue
                timed[bs] = _time(lambda b=bs: run_grouped(
                    make(), view, method="segment", block_size=b), reps)
            if timed:
                best = min(timed, key=timed.get)
                grouped_block.append(
                    {"rows": rows, "groups": groups, "block": best,
                     "heuristic_block": segment_block_size(rows, groups),
                     "sweep": {str(b): s for b, s in timed.items()}})
                print(f"  block sweep rows={rows} groups={groups}: "
                      f"best={best} ({_fmt_s(timed[best])})")

    # generic = mean of the measured classes, cell by cell
    for engine, table in engines.items():
        buckets: dict[tuple, list] = {}
        for entries in table.values():
            for e in entries:
                key = (e["rows"], e.get("groups"))
                buckets.setdefault(key, []).append(e["seconds"])
        table["generic"] = [
            {"rows": r, **({"groups": g} if g is not None else {}),
             "seconds": float(np.mean(ss))}
            for (r, g), ss in sorted(buckets.items())]
    return {"engines": engines, "grouped_block": grouped_block}


def tune_kernels(reps: int) -> dict:
    """TPU-only row-tile sweep for the block kernels the ``supports``
    rankers consult.  Off-TPU the sweep would time interpret mode —
    meaningless for tile choice — so it records nothing."""
    if jax.default_backend() != "tpu":
        return {}
    rng = np.random.default_rng(0)
    n = 1 << 17
    x = jnp.asarray(rng.standard_normal((n, _DIMS), dtype=np.float32))
    y = jnp.asarray(rng.standard_normal(n, dtype=np.float32))
    items = jnp.asarray(rng.integers(0, 10_000, n).astype(np.int32))
    mask = jnp.ones(n, jnp.bool_)
    tuned = {}
    for name, call in (
        ("xtx", lambda t: kernels.dispatch(
            "xtx", x, y, impl="pallas", tile_n=t)),
        ("countmin", lambda t: kernels.dispatch(
            "countmin", items, mask, 4, 128, impl="pallas", tile_n=t)),
    ):
        timed = {t: _time(lambda tt=t: call(tt), reps)
                 for t in (512, 1024, 2048, 4096)}
        best = min(timed, key=timed.get)
        tuned[name] = {"tile_n": best,
                       "sweep": {str(t): s for t, s in timed.items()}}
        print(f"  kernel {name}: tile_n={best} ({_fmt_s(timed[best])})")
    return tuned


def kernel_smoke() -> list:
    """Force every registered Pallas kernel body (interpret mode off-TPU)
    on a tiny layout and record bit-identity against its jnp ref — the
    CI evidence that the compiled path computes the same states."""
    import warnings
    rng = np.random.default_rng(42)
    bs, nb, G = 16, 5, 3
    gids = jnp.asarray(np.append(rng.integers(0, G, nb - 1), G)
                       .astype(np.int32))  # trailing sentinel pad block
    n2 = nb * bs
    valid = jnp.asarray(rng.random(n2) < 0.8)
    x = jnp.asarray((rng.integers(-8, 8, (n2, 3)) / 4).astype(np.float32))
    y = jnp.asarray((rng.integers(-8, 8, n2) / 4).astype(np.float32))
    items = jnp.asarray(rng.integers(0, 500, n2).astype(np.int32))
    mask = jnp.asarray(rng.random(n2) < 0.8)
    cases = {
        "segment_linregr": ((x, y, valid, gids), {"num_groups": G}),
        "segment_countmin": ((items, valid, gids),
                             {"depth": 4, "width": 128, "num_groups": G}),
        "segment_fm": ((items, valid, gids),
                       {"num_hashes": 4, "bits": 32, "num_groups": G}),
        "xtx": ((x, y), {}),
        "countmin": ((items, mask, 4, 128), {}),
    }
    out = []
    for name, (args, kw) in cases.items():
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")  # forced-pallas interpret note
            got = kernels.dispatch(name, *args, impl="pallas", **kw)
        want = kernels.dispatch(name, *args, impl="ref", **kw)
        same = all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree.leaves(got),
                                   jax.tree.leaves(want)))
        out.append({"kernel": name, "impl": "pallas(interpret)"
                    if jax.default_backend() != "tpu" else "pallas",
                    "bit_identical": bool(same)})
        print(f"  kernel smoke {name}: "
              f"{'bit-identical' if same else 'MISMATCH'}")
        if not same:
            raise SystemExit(f"kernel smoke: {name} pallas body diverged "
                             "from its jnp ref")
    return out


def main(argv=None) -> str:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--rows", default="20000,200000",
                    help="comma list of row-bucket sizes")
    ap.add_argument("--groups", default="8,64",
                    help="comma list of group-bucket sizes")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--block-sizes", default="256,1024,4096",
                    help="segment block sizes for the grouped sweep")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: one tiny bucket, reps=1")
    ap.add_argument("--interpret", action="store_true",
                    help="also run every Pallas kernel body (interpret "
                         "mode off-TPU) against its ref, bit-exact")
    ap.add_argument("--out", default=None,
                    help="output path (default: "
                         "benchmarks/calibration/<backend>.json)")
    args = ap.parse_args(argv)

    backend = jax.default_backend()
    if args.tiny:
        rows_list, groups_list, reps = [4096], [8], 1
        block_sizes = [64, 256]
    else:
        rows_list = [int(r) for r in args.rows.split(",")]
        groups_list = [int(g) for g in args.groups.split(",")]
        reps = args.reps
        block_sizes = [int(b) for b in args.block_sizes.split(",")]

    print(f"calibrating backend={backend} devices={len(jax.devices())} "
          f"rows={rows_list} groups={groups_list} reps={reps}")
    tables = measure(rows_list, groups_list, reps, block_sizes)
    tuned = tune_kernels(reps)
    cal = calibration.Calibration(
        backend=backend,
        timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"),
        engines=tables["engines"],
        kernels=tuned,
        grouped_block=tables["grouped_block"],
    )
    doc = cal.to_dict()
    if args.interpret:
        doc["kernel_smoke"] = kernel_smoke()

    out = args.out or os.path.join(os.path.dirname(__file__),
                                   "calibration", f"{backend}.json")
    d = os.path.dirname(out)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(out, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out}")
    # round-trip sanity: the file loads and answers a lookup
    cal2 = calibration.load(out)
    probe = cal2.engine_seconds("grouped-segment", "xtx", rows_list[0],
                                groups_list[0])
    print(f"lookup grouped-segment/xtx rows={rows_list[0]} "
          f"groups={groups_list[0]}: "
          f"{'MISSING' if probe is None else _fmt_s(probe)}")
    return out


if __name__ == "__main__":
    main()
