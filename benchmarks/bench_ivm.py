"""Incremental view maintenance — delta-fold refresh vs full rescan.

The always-fresh-dashboard workload: a retained statement batch
(profile + count-min + FM + linregr) over an append-only fact table.
Without IVM every read after an ingest batch pays a full rescan;
:class:`~repro.core.materialize.MaterializedHandle` pays only the fold
of the NEW rows plus one merge per member (§4.1 merge combinators).
This bench appends ``fraction`` of the base rows and times both paths
on the SAME grown table with warm compile caches, so the ratio is pure
data-pass work:

* **update** — restore the handle's prefix pin, then ``result()``:
  slice + delta fold of the appended rows + merge + final.
* **rescan** — un-pin the handle entirely (stale epoch), then
  ``result()``: full fold of all rows + final.

Columns are dyadic f32 in ``[0, 1)`` (multiples of 1/8), so every
fold sum stays exactly representable and the bench can ASSERT the
tentpole's exactness claim: the delta-merged state is bit-identical to
the rescanned state, leaf for leaf.  A grouped section does the same
for a per-group linregr (fixed ``num_groups``).

``run()`` feeds the CSV harness (benchmarks/run.py); ``python -m
benchmarks.bench_ivm [--json out.json]`` emits the JSON document for
the bench trajectory and the CI smoke artifact.
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.core import (
    ProfileAggregate, Table, materialize, trace_execution,
)
from repro.core.plan import GroupedScanAgg, ScanAgg
from repro.methods.linregr import LinregrAggregate
from repro.methods.sketches import CountMinAggregate, FMAggregate


def _dyadic(rng, shape):
    """f32 multiples of 1/8 in [0, 1): sums/sums-of-squares over a few
    hundred thousand rows stay under 2**24 when scaled, i.e. exact."""
    return (rng.integers(0, 8, shape).astype(np.float32) / 8.0)


def _columns(rows: int, dims: int, groups: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    return {"x": _dyadic(rng, (rows, dims)),
            "y": _dyadic(rng, (rows,)),
            "item": rng.integers(0, 1000, rows).astype(np.int32),
            "g": rng.integers(0, groups, rows).astype(np.int32)}


def _nodes(table: Table, block_size: int) -> list:
    return [
        ScanAgg(ProfileAggregate(), table, columns=("x", "y"),
                block_size=block_size),
        ScanAgg(CountMinAggregate(4, 1024, item_col="item"), table,
                columns=("item",), block_size=block_size),
        ScanAgg(FMAggregate(item_col="item"), table, columns=("item",),
                block_size=block_size),
        ScanAgg(LinregrAggregate(), table, columns={"x": "x", "y": "y"},
                block_size=block_size),
    ]


def _bit_identical(s1, s2) -> bool:
    l1, l2 = jax.tree.leaves(s1), jax.tree.leaves(s2)
    return len(l1) == len(l2) and all(
        np.asarray(a).tobytes() == np.asarray(b).tobytes()
        for a, b in zip(l1, l2))


def _pin_of(h) -> tuple:
    return (h._state, h._version, h._epoch, h._n_rows)


def _restore(h, pin) -> None:
    # Reset the handle to a saved pin so the SAME refresh path can be
    # timed repeatedly (refresh() consumes the staleness otherwise).
    h._state, h._version, h._epoch, h._n_rows = pin
    h._result_cache = None


def _time_refresh(h, pin, reps: int) -> tuple[float, int]:
    """(min seconds over reps, delta events per refresh) for
    restore-pin -> result(), blocking on every result leaf."""
    best = float("inf")
    deltas = 0
    for _ in range(reps):
        _restore(h, pin)
        with trace_execution() as t:
            t0 = time.perf_counter()
            out = h.result()
            for leaf in jax.tree.leaves(out):
                jax.block_until_ready(leaf)
            best = min(best, time.perf_counter() - t0)
        deltas = len(t.deltas)
    return best, deltas


def _section(handle_factory, base_rows: int, delta_cols: dict,
             reps: int) -> dict:
    """Time update vs rescan for one handle shape over one append."""
    h = handle_factory()
    h.result()                       # warm: full build + final programs
    prefix_pin = _pin_of(h)
    h.table.append(delta_cols)
    h.result()                       # warm: delta fold + merge programs
    delta_state = h._state
    up_s, up_deltas = _time_refresh(h, prefix_pin, reps)

    # stale-epoch pin => refresh() takes the full-rescan path
    rescan_pin = (prefix_pin[0], -1, -1, prefix_pin[3])
    _restore(h, rescan_pin)
    h.result()                       # warm (build program already cached)
    rescan_state = h._state
    re_s, _ = _time_refresh(h, rescan_pin, reps)
    return {
        "base_rows": base_rows,
        "delta_rows": int(next(iter(delta_cols.values())).shape[0]),
        "update_seconds": up_s, "update_deltas": up_deltas,
        "rescan_seconds": re_s,
        "speedup": re_s / up_s,
        "bit_identical": _bit_identical(delta_state, rescan_state),
    }


def bench(rows: int = 200_000, dims: int = 8, groups: int = 16,
          reps: int = 3, block_size: int = 4096,
          fractions=(0.01, 0.05, 0.10)) -> dict:
    out: dict = {"config": {"rows": rows, "dims": dims, "groups": groups,
                            "reps": reps, "block_size": block_size,
                            "fractions": list(fractions)},
                 "fractions": {}}
    for f in fractions:
        m = max(int(rows * f), 1)
        table = Table.from_columns(_columns(rows, dims, groups, seed=0))
        delta = _columns(m, dims, groups, seed=1)
        sec = _section(lambda: materialize(_nodes(table, block_size)),
                       rows, delta, reps)
        out["fractions"][f"{f:g}"] = sec

    # grouped living view: per-group linregr, fixed group count
    table = Table.from_columns(_columns(rows, dims, groups, seed=0))
    delta = _columns(max(int(rows * 0.05), 1), dims, groups, seed=1)
    out["grouped"] = _section(
        lambda: materialize(GroupedScanAgg(
            LinregrAggregate(), table, "g", num_groups=groups,
            columns={"x": "x", "y": "y"}, block_size=block_size)),
        rows, delta, reps)

    headline = out["fractions"].get("0.05") or next(
        iter(out["fractions"].values()))
    out["speedup"] = headline["speedup"]
    out["bit_identical"] = (
        all(s["bit_identical"] for s in out["fractions"].values())
        and out["grouped"]["bit_identical"])
    return out


def run(rows: int = 200_000, reps: int = 3):
    """CSV rows for benchmarks/run.py: (name, us_per_call, derived)."""
    r = bench(rows=rows, reps=reps)
    h = r["fractions"].get("0.05") or next(iter(r["fractions"].values()))
    return [
        ("ivm_update_5pct", h["update_seconds"] * 1e6,
         f"deltas={h['update_deltas']}"),
        ("ivm_rescan_5pct", h["rescan_seconds"] * 1e6, ""),
        ("ivm_speedup_5pct", h["speedup"],
         f"bit_identical={r['bit_identical']}"),
        ("ivm_grouped_speedup_5pct", r["grouped"]["speedup"],
         f"bit_identical={r['grouped']['bit_identical']}"),
    ]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the JSON document here (default: stdout)")
    ap.add_argument("--rows", type=int, default=200_000)
    ap.add_argument("--dims", type=int, default=8)
    ap.add_argument("--groups", type=int, default=16)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--block-size", type=int, default=4096)
    args = ap.parse_args()
    doc = bench(rows=args.rows, dims=args.dims, groups=args.groups,
                reps=args.reps, block_size=args.block_size)
    text = json.dumps(doc, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.json}")
    else:
        print(text)
