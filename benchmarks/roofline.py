"""Roofline aggregation: results/dryrun/*.json -> the §Roofline table.

Prints a markdown table per mesh with the three terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, and a one-line "what would move the
dominant term" note derived from the cell's structure.
"""

from __future__ import annotations

import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def _fmt_s(v: float) -> str:
    if v >= 1.0:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v * 1e3:.1f}ms"
    return f"{v * 1e6:.0f}us"


def _advice(r: dict) -> str:
    dom = r["roofline"]["dominant"]
    kind = r["kind"]
    if dom == "memory_s":
        if kind == "decode":
            return "quantize/shard KV cache further; fuse cache update"
        return "raise arithmetic intensity: less remat, fuse attn (Pallas)"
    if dom == "collective_s":
        if r["collectives"]["wire_bytes"].get("all-reduce", 0) > \
                r["collectives"]["total_wire_bytes"] * 0.6:
            return "reduce-scatter grads + int8 compress inter-pod"
        return "overlap a2a/AG with compute; resharding of activations"
    return "MXU-align tiles; cut redundant recompute (remat policy)"


def load_cells():
    cells = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def table(mesh: str = "16x16") -> str:
    rows = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL/HLO | next lever |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load_cells():
        if r["mesh"] != mesh:
            continue
        rl = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(rl['compute_s'])} | "
            f"{_fmt_s(rl['memory_s'])} | {_fmt_s(rl['collective_s'])} | "
            f"{rl['dominant'].replace('_s', '')} | "
            f"{r['useful_flops_ratio']:.2f} | {_advice(r)} |")
    return "\n".join(rows)


def run():
    """benchmarks.run hook: emit one CSV row per dry-run cell."""
    out = []
    for r in load_cells():
        rl = r["roofline"]
        dom_s = rl[rl["dominant"]]
        out.append((
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            dom_s * 1e6,
            f"dom={rl['dominant']};useful={r['useful_flops_ratio']:.2f}"))
    return out


if __name__ == "__main__":
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### mesh {mesh}\n")
        print(table(mesh))
