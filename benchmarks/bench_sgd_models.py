"""Table 2 reproduction (§5.1): six models, ONE convex-optimization
abstraction, one SGD solver.  Reports fit time + final objective per row
of the table — the Wisconsin claim is that adding a model costs only its
objective definition ("a matter of days" -> here, lines of code)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import Table, synthetic_classification_table, \
    synthetic_regression_table
from repro.core.convex import sgd
from repro.methods.crf import crf_init_params, crf_program, \
    extract_features
from repro.methods.sgd_models import (lasso_program, least_squares_program)
from repro.methods.logregr import logistic_program
from repro.methods.svm import svm_program
from repro.methods.svd import lowrank_program


def _obj(prog, params, columns, n):
    mask = jnp.ones((n,), bool)
    return float(prog.total_loss(params, columns, mask)) / n


def run(rows: int = 20_000, d: int = 16, epochs: int = 3):
    key = jax.random.PRNGKey(0)
    results = []
    reg_tbl, _ = synthetic_regression_table(key, rows, d)
    cls_tbl, _ = synthetic_classification_table(key, rows, d)

    jobs = [
        ("least_squares", least_squares_program(), reg_tbl,
         jnp.zeros((d,)), 0.05),
        ("lasso", lasso_program(mu=0.05), reg_tbl, jnp.zeros((d,)), 0.05),
        ("logistic", logistic_program(), cls_tbl, jnp.zeros((d,)), 0.3),
        ("svm", svm_program(mu=1e-3), cls_tbl, jnp.zeros((d,)), 0.1),
    ]
    # recommendation: sparse ratings
    kk = jax.random.split(key, 4)
    nr, nc, rank = 128, 96, 4
    ii = jax.random.randint(kk[0], (rows,), 0, nr).astype(jnp.float32)
    jj = jax.random.randint(kk[1], (rows,), 0, nc).astype(jnp.float32)
    l0 = jax.random.normal(kk[2], (nr, rank))
    r0 = jax.random.normal(kk[3], (nc, rank))
    vv = jnp.sum(l0[ii.astype(int)] * r0[jj.astype(int)], -1)
    rec_tbl = Table.from_columns({"i": ii, "j": jj, "v": vv})
    rec_params = {"L": 0.5 * jax.random.normal(kk[0], (nr, rank)),
                  "R": 0.5 * jax.random.normal(kk[1], (nc, rank))}
    jobs.append(("recommendation", lowrank_program(nr, nc, rank, mu=1e-5),
                 rec_tbl, rec_params, 0.1))
    # CRF labeling
    B, T, L, F = 256, 12, 3, 64
    toks = jax.random.randint(kk[2], (B, T), 0, 30)
    feats = extract_features(toks, F)
    crf_tbl = Table.from_columns({
        "feats": feats, "labels": (toks % L).astype(jnp.int32),
        "mask": jnp.ones((B, T), jnp.float32)})
    jobs.append(("crf", crf_program(F, L, mu=1e-4), crf_tbl,
                 crf_init_params(F, L, kk[3]), 0.3))

    for name, prog, tbl, params0, lr in jobs:
        n = tbl.n_rows
        mask = jnp.ones((n,), bool)
        before = _obj(prog, params0, dict(tbl.columns), n)
        t0 = time.perf_counter()
        params = sgd(prog, tbl, params0, stepsize=lr, epochs=epochs,
                     batch=min(256, n), key=key, anneal=False)
        jax.block_until_ready(jax.tree.leaves(params)[0])
        dt = time.perf_counter() - t0
        after = _obj(prog, params, dict(tbl.columns), n)
        results.append((f"sgd_{name}", dt * 1e6,
                        f"obj {before:.4g}->{after:.4g}"))
    return results


if __name__ == "__main__":
    for name, us, extra in run():
        print(f"{name},{us:.1f},{extra}")
