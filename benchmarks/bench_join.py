"""Star-schema joined GROUP BY: shared-sort sort-merge vs gather-materialize.

The classical plan for ``SELECT dim.attr, agg(...) FROM fact JOIN dim ON
fact.fk = dim.key GROUP BY dim.attr`` materializes the join — gathers
the dimension attribute onto every fact row — and then groups the
widened table, paying the dimension sort AND the fact partitioning sort
once per statement.  The join layer (``core/join.py`` +
``JoinedGroupedScanAgg``) instead resolves keys device-side against the
memoized dimension key sort and routes ONE fact-aligned int32 gid column
into the unchanged grouped core, so an N-statement batch over the same
star triple pays 2 sorts TOTAL (dim keys + fact partition) and one
fused pass.

Sections (sorts/scans counted by :func:`repro.core.trace_execution`,
results checked BIT-identical to a numpy-lookup materialized oracle):

* **naive** — per statement: fresh tables (no shared memo, the
  pre-join-layer cost), device gather of the dimension attribute onto
  fact rows, own partitioning sort, own scan.
* **planned** — the same statements as one ``Session`` batch of
  ``JoinedGroupedScanAgg`` nodes: one key resolution, one shared sort
  pair, ONE fused pass.

``run()`` feeds the CSV harness (benchmarks/run.py); ``python -m
benchmarks.bench_join [--json out.json]`` emits the JSON document the
CI smoke asserts on (bit_identical, per-statement sort counts, the
fused explain).
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Join, JoinedGroupedScanAgg, ProfileAggregate, Session, Table, execute,
    run_grouped, trace_execution,
)
from repro.core.plan import GroupedScanAgg
from repro.methods.linregr import LinregrAggregate
from repro.methods.quantiles import HistogramAggregate


def _star_columns(fact_rows: int, dim_rows: int, groups: int,
                  dims: int) -> tuple[dict, dict]:
    rng = np.random.default_rng(0)
    # sparse, shuffled dimension keys: keys are not row positions
    dim_keys = rng.permutation(dim_rows * 13)[:dim_rows].astype(np.int32)
    dim_attr = (rng.permutation(dim_rows) % groups).astype(np.int32)
    fk = dim_keys[rng.integers(0, dim_rows, fact_rows)].astype(np.int32)
    x = rng.standard_normal((fact_rows, dims), dtype=np.float32)
    b = rng.standard_normal(dims, dtype=np.float32)
    y = (x @ b + 0.1 * rng.standard_normal(fact_rows, dtype=np.float32))
    fact = {"x": x, "y": y.astype(np.float32), "fk": fk,
            "item": rng.integers(0, 1000, fact_rows).astype(np.int32)}
    dim = {"key": dim_keys, "region": dim_attr}
    return fact, dim


def _aggs():
    """The 3-statement joined batch: scan-dominated (cheap-transition)
    statistics per dimension attribute, all over the same star triple —
    the regime where the per-statement sorts ARE the cost the shared-sort
    plan removes."""
    return [
        ("linregr_joined", lambda: LinregrAggregate(),
         {"x": "x", "y": "y"}),
        ("profile_joined", lambda: ProfileAggregate(), ("y",)),
        ("hist_joined",
         lambda: HistogramAggregate(-8.0, 8.0, 1024, "y"), ("y",)),
    ]


def _time(fn, reps: int) -> tuple[float, int, int]:
    """(min seconds over reps, scans, sorts) after one untimed warmup,
    blocking on every result leaf."""
    fn()
    best = float("inf")
    scans = sorts = 0
    for _ in range(reps):
        with trace_execution() as t:
            t0 = time.perf_counter()
            out = fn()
            for leaf in jax.tree.leaves(out):
                jax.block_until_ready(leaf)
            best = min(best, time.perf_counter() - t0)
        scans, sorts = len(t.scans), len(t.sorts)
    return best, scans, sorts


def bench(fact_rows: int = 200_000, dim_rows: int = 512,
          groups: int = 64, dims: int = 8, reps: int = 3) -> dict:
    fact_cols, dim_cols = _star_columns(fact_rows, dim_rows, groups, dims)
    n_stmts = len(_aggs())
    out: dict = {"config": {"fact_rows": fact_rows, "dim_rows": dim_rows,
                            "groups": groups, "dims": dims, "reps": reps,
                            "statements": n_stmts}}

    # Prepared statements (bench_plan's "prepared" regime): aggregate
    # instances are built ONCE so engine program caches hit on every rep
    # and the timings compare the two join strategies' DATA work —
    # sorts, gathers, key resolution, passes — not trace/compile.
    prepared = [(name, make(), proj) for name, make, proj in _aggs()]

    # -- naive: gather-materialize, fresh tables per statement ------------
    def naive():
        res = []
        for name, agg, proj in prepared:
            f = Table.from_columns(fact_cols)   # fresh: no shared memos
            d = Table.from_columns(dim_cols)
            sorted_keys, perm = d.sort_permutation("key")  # dim sort
            pos = jnp.clip(jnp.searchsorted(sorted_keys, f["fk"]),
                           0, dim_rows - 1)
            gid = d["region"][perm][pos]        # gather attr onto fact
            tbl = f.with_column("g", gid.astype(jnp.int32))
            res.append(execute(GroupedScanAgg(
                agg, tbl, "g", groups, columns=proj, label=name)))
        return res

    # -- planned: one joined batch over one star triple -------------------
    fact = Table.from_columns(fact_cols)
    dim = Table.from_columns(dim_cols)
    stmts = [JoinedGroupedScanAgg(
        agg, Join(fact, dim, "fk", "key", "region"), groups,
        columns=proj, label=name) for name, agg, proj in prepared]

    def planned():
        sess = Session()
        for node in stmts:
            sess.statement(node)
        return sess.run()

    def planned_cold():
        # memoized sort/resolution products would hide the planned
        # path's real per-batch cost: drop them so every timed rep pays
        # its own key resolution + shared sort pair, mirroring naive's
        # fresh-tables-per-statement accounting
        fact.invalidate(), dim.invalidate()
        return planned()

    n_s, n_scans, n_sorts = _time(naive, reps)
    p_s, p_scans, p_sorts = _time(planned_cold, reps)
    out["naive"] = {"seconds": n_s, "scans": n_scans, "sorts": n_sorts,
                    "sorts_per_stmt": n_sorts / n_stmts}
    out["planned"] = {"seconds": p_s, "scans": p_scans, "sorts": p_sorts,
                      "sorts_per_stmt": p_sorts / n_stmts}
    out["speedup"] = n_s / p_s

    # -- bit-identity vs the materialized oracle --------------------------
    lookup = {int(k): int(a) for k, a in zip(dim_cols["key"],
                                             dim_cols["region"])}
    gids = np.array([lookup[int(f)] for f in fact_cols["fk"]], np.int32)
    got = planned()
    identical = True
    for (name, make, proj), g in zip(_aggs(), got):
        # the oracle sees exactly the statement's projection, so
        # schema-driven aggregates (profile) produce matching trees
        names = proj.values() if isinstance(proj, dict) else proj
        oracle_tbl = Table.from_columns(
            {**{c: fact_cols[c] for c in names}, "g": gids})
        want = run_grouped(make(), oracle_tbl, "g", groups)
        a_l, b_l = jax.tree.leaves(g), jax.tree.leaves(want)
        identical &= len(a_l) == len(b_l) and all(
            bool(jnp.all(jnp.asarray(a) == jnp.asarray(b)))
            for a, b in zip(a_l, b_l))
    out["bit_identical"] = identical

    sess = Session()
    for node in stmts:
        sess.statement(node)
    out["explain"] = sess.explain()
    return out


def run(fact_rows: int = 200_000, reps: int = 3):
    """CSV rows for benchmarks/run.py: (name, us_per_call, derived)."""
    r = bench(fact_rows=fact_rows, reps=reps)
    return [
        ("join_naive_3stmt", r["naive"]["seconds"] * 1e6,
         f"sorts={r['naive']['sorts']} scans={r['naive']['scans']}"),
        ("join_planned_3stmt", r["planned"]["seconds"] * 1e6,
         f"sorts={r['planned']['sorts']} scans={r['planned']['scans']}"),
        ("join_speedup", r["speedup"],
         f"bit_identical={r['bit_identical']} sorts/stmt "
         f"{r['naive']['sorts_per_stmt']:.2f}->"
         f"{r['planned']['sorts_per_stmt']:.2f}"),
    ]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the JSON document here (default: stdout)")
    ap.add_argument("--fact-rows", type=int, default=200_000)
    ap.add_argument("--dim-rows", type=int, default=512)
    ap.add_argument("--groups", type=int, default=64)
    ap.add_argument("--dims", type=int, default=8)
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()
    doc = bench(fact_rows=args.fact_rows, dim_rows=args.dim_rows,
                groups=args.groups, dims=args.dims, reps=args.reps)
    text = json.dumps(doc, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.json}")
    else:
        print(text)
