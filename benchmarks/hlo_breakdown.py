"""Per-op-kind / per-computation byte & flop breakdown of a dumped HLO —
the §Perf profiling tool (dry-run profiles are lowered IR, not traces).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch X --shape Y \
      --dump-hlo /tmp/x.hlo
  PYTHONPATH=src python benchmarks/hlo_breakdown.py /tmp/x.hlo
"""

from __future__ import annotations

import json
import re
import sys
from collections import defaultdict

from repro.launch import hlo_analysis as HA


def breakdown(path: str, top: int = 15):
    text = open(path).read()
    registry = json.load(open(path + ".registry"))
    comps = HA.parse_computations(text)
    symtabs = {n: {o.name: o.shape for o in ops} for n, ops in comps.items()}

    bykind = defaultdict(float)
    byop = defaultdict(float)
    flops_byname = defaultdict(float)
    unknown: list = []

    # reuse analyze()'s exact logic by monkey-walking with instrumentation
    orig = HA.analyze(text, registry)

    NO = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
          "after-all", "partition-id", "replica-id", "while",
          "conditional", "call"}

    def operand_names(op):
        head = op.rest.split("metadata=")[0]
        head = re.split(r"\b(?:calls|to_apply|body|condition|dimensions"
                        r"|sharding|channel_id)=", head)[0]
        return [m.group(1) for m in re.finditer(r"%([\w\.\-]+)", head)]

    def callees(op):
        out = []
        if op.kind == "while":
            mb = re.search(r"body=%?([\w\.\-]+)", op.rest)
            mc = re.search(r"condition=%?([\w\.\-]+)", op.rest)
            trip = HA._trip_count(op.op_name, registry, unknown)
            if mb:
                out.append((mb.group(1), float(trip)))
            if mc:
                out.append((mc.group(1), float(trip)))
        elif op.kind in ("fusion", "call"):
            for a in ("calls", "to_apply"):
                m = re.search(a + r"=%?([\w\.\-]+)", op.rest)
                if m:
                    out.append((m.group(1), 1.0))
        return out

    def walk(cn, mult, cb, depth=0):
        ops = comps.get(cn)
        if ops is None or depth > 64:
            return
        st = symtabs[cn]
        for op in ops:
            if op.kind == "dot":
                f = HA._dot_flops(op, st)
                flops_byname[(cn[:40], op.op_name[-70:])] += mult * f
            if cb and op.kind not in NO:
                b = mult * (HA.shape_bytes(op.shape))
                bykind[op.kind] += b
                byop[(cn[:40], op.kind, op.shape[:44])] += b
            for c, extra in callees(op):
                walk(c, mult * extra,
                     cb and op.kind in ("while", "call", "conditional"),
                     depth + 1)

    entry = next(n for n, ops in comps.items()
                 if n != "__ENTRY__" and ops is comps["__ENTRY__"])
    walk(entry, 1.0, True)

    print(f"== analyze(): {orig['dot_flops']/1e12:.2f} TF, "
          f"{orig['bytes_accessed']/1e9:.1f} GB, "
          f"wire {orig['total_wire_bytes']/1e9:.2f} GB ==")
    print("\n-- output bytes by op kind (x mult) --")
    for k, v in sorted(bykind.items(), key=lambda t: -t[1])[:top]:
        print(f"  {k:28s} {v/1e9:10.2f} GB")
    print("\n-- top individual (computation, kind, shape) --")
    for (c, k, s), v in sorted(byop.items(), key=lambda t: -t[1])[:top]:
        print(f"  {v/1e9:8.2f} GB  {k:22s} {s:46s} {c}")
    print("\n-- top dot sites (flops) --")
    for (c, on), v in sorted(flops_byname.items(),
                             key=lambda t: -t[1])[:top]:
        print(f"  {v/1e12:8.2f} TF  {c:42s} ...{on}")
    if orig["unknown_whiles"]:
        print("\nUNKNOWN WHILES:", orig["unknown_whiles"])


if __name__ == "__main__":
    breakdown(sys.argv[1], int(sys.argv[2]) if len(sys.argv) > 2 else 15)
