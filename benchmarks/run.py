# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
#
# Mapping to the paper:
#   bench_linregr     — Figures 4/5 (linregr UDA scaling: k-sweep,
#                        implied segment speedup, v0.1 vs v0.3 history)
#   bench_iterative   — §4.2 IRLS cost + driver overhead; §4.3 k-means
#                        two-pass vs fused single pass
#   bench_profile     — §Table 1 profile: shared-scan fused aggregates
#                        (pass count + wall time) vs scan-per-aggregate
#   bench_plan        — §3.2 declarative batches: planned (scan-sharing
#                        optimizer) vs naive per-statement execution
#   bench_join        — star-schema joined GROUP BY: shared-sort
#                        sort-merge join vs per-statement
#                        gather-materialize
#   bench_ivm         — §4.1 merge combinators as incremental view
#                        maintenance: delta-fold refresh vs full rescan
#   bench_serve       — §3.2 serving: cross-session admission-window
#                        scan sharing + version-keyed result caching
#   bench_sgd_models  — Table 2 (six models, one SGD abstraction)
#   bench_text        — Table 3 (feature extraction, Viterbi, MCMC,
#                        q-gram matching)
#   roofline          — §Roofline rows from the dry-run artifacts (only
#                        emitted when results/dryrun exists)

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import bench_ivm, bench_join, bench_linregr, bench_iterative, \
        bench_plan, bench_profile, bench_serve, bench_sgd_models, \
        bench_text, roofline

    suites = [
        ("bench_linregr", bench_linregr.run),
        ("bench_iterative", bench_iterative.run),
        ("bench_profile", bench_profile.run),
        ("bench_plan", bench_plan.run),
        ("bench_join", bench_join.run),
        ("bench_ivm", bench_ivm.run),
        ("bench_serve", bench_serve.run),
        ("bench_sgd_models", bench_sgd_models.run),
        ("bench_text", bench_text.run),
        ("roofline", roofline.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for suite_name, fn in suites:
        try:
            for name, us, extra in fn():
                print(f"{name},{us:.1f},{extra}", flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{suite_name},NaN,ERROR:{type(e).__name__}:{e}",
                  flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
