"""Figure 4/5 reproduction: linear-regression UDA execution times.

The paper's claims:
  (1) runtime O(k^3 + n·k^2/p) in #variables k, rows n, segments p;
  (2) near-perfect linear speedup in p (6->24 segments);
  (3) v0.1 (nested-loop outer product) vs v0.3 (blocked rank-update)
      version history (§4.4).

This container exposes one CPU core, so p-speedup is reproduced under the
shared-nothing model the paper itself relies on: each segment folds its
n/p rows independently (associative merge — the property tested in
test_properties.py), so cluster time = single-segment time over n/p rows
+ a k×k merge.  We measure exactly that per-segment fold and report the
implied speedup, alongside the directly-measured k-sweep and the
v0.1-vs-v0.3 comparison which need no parallelism.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import Table, run_local, synthetic_regression_table
from repro.methods.linregr import LinregrAggregate


def _timeit(fn, *args, reps=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps, out


def naive_outer_product_xtx(x, y):
    """v0.1alpha: row-at-a-time rank-1 updates (lax.fori over rows)."""
    n, d = x.shape

    def body(i, acc):
        xtx, xty = acc
        xi = x[i]
        return xtx + jnp.outer(xi, xi), xty + xi * y[i]

    return jax.lax.fori_loop(
        0, n, body, (jnp.zeros((d, d)), jnp.zeros((d,))))


def run(rows: int = 200_000, reps: int = 3):
    key = jax.random.PRNGKey(0)
    results = []

    # --- (1) k-sweep: the paper's #variables column (Fig 4) -------------
    agg = LinregrAggregate()
    for k in (10, 20, 40, 80, 160, 320):
        tbl, _ = synthetic_regression_table(key, rows, k)
        fn = jax.jit(lambda cols: agg.final(agg.transition(
            agg.init(cols), cols, jnp.ones((rows,), bool))))
        dt, _ = _timeit(fn, dict(tbl.columns), reps=reps)
        results.append((f"linregr_k{k}_n{rows}", dt * 1e6,
                        f"rows_per_s={rows / dt:.3g}"))

    # --- (2) implied p-speedup: per-segment fold of n/p rows ------------
    k = 80
    base_dt = None
    for p in (1, 6, 12, 18, 24):
        n_seg = rows // p
        tbl, _ = synthetic_regression_table(key, n_seg, k)
        fn = jax.jit(lambda cols, m: agg.transition(agg.init(cols), cols, m))
        dt, _ = _timeit(fn, dict(tbl.columns),
                        jnp.ones((n_seg,), bool), reps=reps)
        if p == 1:
            base_dt = dt
        speedup = base_dt / dt
        results.append((f"linregr_seg{p}_k{k}", dt * 1e6,
                        f"implied_speedup={speedup:.2f}x_of_{p}x"))

    # --- (3) §4.4 version history: v0.1 loop vs v0.3 blocked ------------
    n_small = 20_000
    for k in (10, 40, 80):
        tbl, _ = synthetic_regression_table(key, n_small, k)
        x, y = tbl["x"], tbl["y"]
        v01 = jax.jit(naive_outer_product_xtx)
        dt01, _ = _timeit(v01, x, y, reps=1)
        v03 = jax.jit(lambda x, y: (x.T @ x, x.T @ y))
        dt03, _ = _timeit(v03, x, y, reps=reps)
        results.append((f"linregr_v01_loop_k{k}", dt01 * 1e6, ""))
        results.append((f"linregr_v03_blocked_k{k}", dt03 * 1e6,
                        f"speedup_over_v01={dt01 / dt03:.1f}x"))
    return results


if __name__ == "__main__":
    for name, us, extra in run():
        print(f"{name},{us:.1f},{extra}")
