"""GROUP BY execution cost: the partitioned grouped-scan core vs the
masked-vmap lowering.

  * ``run_grouped`` on a skewed-G workload — the segment path folds all
    groups in one O(n) blocked scan of group-aligned blocks; the masked
    path scans the full table once per group (O(G·n)).  The speedup
    should track G.
  * ``fit_grouped`` under skewed convergence — groups converge at
    spread-out rounds; the segment layout gather-compacts still-active
    groups' blocks each round, so iters/sec stays high as the tail
    thins, while the masked layout pays G full scans every round.

  * ``--sharded`` — device-count scaling of the SHARDED grouped engine:
    ``run_grouped(mesh=)`` / ``fit_grouped(mesh=)`` on meshes of 1, 2,
    4, ... devices (run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a CPU
    smoke).  Emits per-device-count rows_per_sec / iters_per_sec JSON.

  * ``--kernel={auto,ref,pallas}`` — which segment-fold implementation
    the linregr transitions route through (``use_kernel=`` on the
    aggregate): the registry-dispatched jnp ref, the Pallas kernel
    (interpret mode off-TPU — correctness path, not a throughput
    number), or auto.  The JSON records the RESOLVED kernel name from
    the execution trace plus blocks/sec of the segment scan.

``run()`` feeds the CSV harness (benchmarks/run.py); ``python -m
benchmarks.bench_grouped [--json out.json]`` emits a JSON document for
the bench trajectory and the CI smoke artifact.
"""

from __future__ import annotations

import json
import time
import warnings

import jax
import jax.numpy as jnp

from repro.core import Table, fit_grouped, run_grouped, trace_execution
from repro.core.aggregates import segment_block_size
from repro.methods.linregr import LinregrAggregate
from repro.methods.logregr import IRLSTask


def _skewed_groups(key, rows: int, groups: int) -> jax.Array:
    """Zipf-ish group sizes: a few big segments, a long tail of small
    ones (the shape that makes O(G·n) masking hurt most)."""
    w = 1.0 / (jnp.arange(groups) + 1.0)
    probs = w / jnp.sum(w)
    return jax.random.choice(key, groups, (rows,), p=probs).astype(jnp.int32)


def _grouped_table(key, rows: int, dims: int, groups: int) -> Table:
    kx, kb, kg, ke = jax.random.split(key, 4)
    x = jax.random.normal(kx, (rows, dims))
    b = jax.random.normal(kb, (dims,))
    y = x @ b + 0.1 * jax.random.normal(ke, (rows,))
    return Table.from_columns({"x": x, "y": y,
                               "g": _skewed_groups(kg, rows, groups)})


def _skewed_logistic_table(key, rows: int, dims: int, groups: int) -> Table:
    """Skewed sizes AND skewed convergence: per-group coefficient scales
    spread the IRLS iteration counts, so group models freeze at very
    different rounds — the gather-compaction showcase."""
    kx, kb, kg, ku = jax.random.split(key, 4)
    x = jax.random.normal(kx, (rows, dims))
    g = _skewed_groups(kg, rows, groups)
    b = jax.random.normal(kb, (groups, dims)) \
        * (1.0 + (jnp.arange(groups)[:, None] % 7))
    p = jax.nn.sigmoid(jnp.sum(x * b[g], -1))
    y = (jax.random.uniform(ku, (rows,)) < p).astype(jnp.float32)
    return Table.from_columns({"x": x, "y": y, "g": g})


def _time(fn, reps: int) -> float:
    """Min wall-clock over reps, after one untimed call.  run_grouped /
    fit_grouped build a fresh jitted closure per call, so every rep pays
    the same trace+dispatch overhead on BOTH strategies — the comparison
    is apples-to-apples; the partitioning sort is hoisted out by passing
    a prebuilt GroupedView where the strategy uses one."""
    fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(jax.tree.leaves(out)[0])
        best = min(best, time.perf_counter() - t0)
    return best


def bench(rows: int = 200_000, dims: int = 8, groups: int = 64,
          fit_groups: int = 64, max_iters: int = 25, reps: int = 3,
          kernel: str = "auto") -> dict:
    key = jax.random.PRNGKey(0)
    out: dict = {"config": {"rows": rows, "dims": dims, "groups": groups,
                            "fit_groups": fit_groups,
                            "max_iters": max_iters, "reps": reps,
                            "kernel": kernel}}

    # --- one-pass: run_grouped linregr states, segment vs masked ---------
    tbl = _grouped_table(key, rows, dims, groups)
    view = tbl.group_by("g", groups)  # sort paid once, outside the timer
    agg = LinregrAggregate(use_kernel=kernel)
    one_pass = {}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # forced-pallas interpret note
        for method in ("segment", "masked"):
            s = _time(lambda m=method: run_grouped(agg, view, method=m),
                      reps)
            one_pass[method] = {"seconds": s,
                                "rows_per_sec": rows / s}
        # resolved kernel + blocks/sec of the segment scan, from the trace
        with trace_execution() as t:
            run_grouped(agg, view, method="segment")
    bs = segment_block_size(rows, groups)
    nb = int(view.aligned_blocks(bs)[2].shape[0])
    ev = t.kernels[0] if t.kernels else None
    one_pass["kernel"] = {
        "requested": kernel,
        "resolved": None if ev is None else ev.engine,
        "name": None if ev is None else ev.detail["name"],
        "blocks": nb,
        "blocks_per_sec": nb / one_pass["segment"]["seconds"],
    }
    one_pass["segment_speedup"] = \
        one_pass["masked"]["seconds"] / one_pass["segment"]["seconds"]
    out["run_grouped"] = one_pass

    # --- iterative: fit_grouped IRLS under skewed convergence ------------
    ftbl = _skewed_logistic_table(jax.random.fold_in(key, 1), rows, dims,
                                  fit_groups)
    fit_stats = {}
    rounds = {}
    for layout in ("segment", "masked"):
        def one(la=layout):
            return fit_grouped(IRLSTask(), ftbl, "g", fit_groups,
                               max_iters=max_iters, tol=1e-6, layout=la)
        res = one()  # compile + capture diagnostics
        t0 = time.perf_counter()
        res = one()
        s = time.perf_counter() - t0
        rounds[layout] = int(res.n_iters.max())
        fit_stats[layout] = {"seconds": s,
                             "iters_per_sec": rounds[layout] / s}
        if res.stats["layout"] == "segment":
            fit_stats[layout]["blocks"] = res.stats["blocks"]
            fit_stats[layout]["blocks_full_scan"] = \
                res.stats["blocks_full_scan"]
            fit_stats[layout]["n_iters_min_max"] = \
                [int(res.n_iters.min()), int(res.n_iters.max())]
    fit_stats["segment_speedup"] = \
        fit_stats["masked"]["seconds"] / fit_stats["segment"]["seconds"]
    out["fit_grouped"] = fit_stats

    # --- iters/sec vs G (segment layout scaling) -------------------------
    sweep = []
    for g_sweep in (max(2, fit_groups // 4), fit_groups, 4 * fit_groups):
        t = _skewed_logistic_table(jax.random.fold_in(key, g_sweep), rows,
                                   dims, g_sweep)

        def one_sweep(tt=t, gg=g_sweep):
            return fit_grouped(IRLSTask(), tt, "g", gg,
                               max_iters=max_iters, tol=1e-6,
                               layout="segment")
        r = one_sweep()
        t0 = time.perf_counter()
        r = one_sweep()
        s = time.perf_counter() - t0
        sweep.append({"groups": g_sweep, "seconds": s,
                      "iters_per_sec": int(r.n_iters.max()) / s})
    out["fit_grouped_vs_G"] = sweep
    return out


def bench_sharded(rows: int = 200_000, dims: int = 8, groups: int = 64,
                  fit_groups: int = 64, max_iters: int = 25,
                  reps: int = 3) -> dict:
    """Device-count scaling of the sharded grouped engine.

    For each mesh size (1, 2, 4, ... up to the available device count):
    one ``run_grouped`` segment scan and one ``fit_grouped`` IRLS fit,
    both with the group-aligned blocks chunked across the mesh.  The
    local (mesh=None) engine is the 0-device baseline row.

    Each ``run_grouped(mesh=)`` call re-places the block layout on the
    mesh, so ``seconds`` includes that host-side gather + device_put;
    ``placement_seconds`` reports it separately (measured via
    ``GroupedView.sharded_blocks``) so the scan-only scaling is
    ``seconds - placement_seconds``.  ``fit_grouped`` amortizes one
    placement over the whole multi-round fit.
    """
    from repro.core.compat import make_mesh

    key = jax.random.PRNGKey(0)
    tbl = _grouped_table(key, rows, dims, groups)
    view = tbl.group_by("g", groups)
    ftbl = _skewed_logistic_table(jax.random.fold_in(key, 1), rows, dims,
                                  fit_groups)
    agg = LinregrAggregate()
    devices = jax.devices()
    counts = [c for c in (1, 2, 4, 8, 16, 32) if c <= len(devices)]
    out: dict = {"config": {"rows": rows, "dims": dims, "groups": groups,
                            "fit_groups": fit_groups,
                            "max_iters": max_iters, "reps": reps,
                            "available_devices": len(devices)},
                 "device_scaling": []}

    def one_point(mesh, label):
        s = _time(lambda: run_grouped(agg, view, method="segment",
                                      mesh=mesh), reps)
        from repro.core.aggregates import segment_block_size
        bs = segment_block_size(rows, groups)
        place = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            out = view.sharded_blocks(mesh, ("data",), bs) if mesh is not \
                None else view.aligned_blocks(bs)
            jax.block_until_ready(jax.tree.leaves(out[0])[0])
            place = min(place, time.perf_counter() - t0)
        def fit_once():
            return fit_grouped(IRLSTask(), ftbl, "g", fit_groups,
                               max_iters=max_iters, tol=1e-6,
                               layout="segment", mesh=mesh)
        res = fit_once()  # compile + diagnostics, untimed
        fs = float("inf")
        for _ in range(reps):  # honor --reps like the one-pass points
            t0 = time.perf_counter()
            fit_once()
            fs = min(fs, time.perf_counter() - t0)
        rounds = int(res.n_iters.max())
        return {"devices": label,
                "run_grouped": {"seconds": s, "rows_per_sec": rows / s,
                                "placement_seconds": place},
                "fit_grouped": {"seconds": fs,
                                "iters_per_sec": rounds / fs,
                                "rounds": rounds,
                                "blocks": res.stats["blocks"]}}

    base = one_point(None, 0)  # local engine baseline
    out["device_scaling"].append(base)
    for nd in counts:
        mesh = make_mesh((nd,), ("data",), devices=devices[:nd])
        point = one_point(mesh, nd)
        point["run_grouped"]["speedup_vs_local"] = \
            base["run_grouped"]["seconds"] / point["run_grouped"]["seconds"]
        point["fit_grouped"]["speedup_vs_local"] = \
            base["fit_grouped"]["seconds"] / point["fit_grouped"]["seconds"]
        out["device_scaling"].append(point)
    return out


def run(rows: int = 200_000, groups: int = 64, reps: int = 3):
    """CSV rows for benchmarks/run.py: (name, us_per_call, derived)."""
    r = bench(rows=rows, groups=groups, reps=reps)
    res = []
    for method in ("segment", "masked"):
        e = r["run_grouped"][method]
        res.append((f"run_grouped_{method}", e["seconds"] * 1e6,
                    f"rows_per_sec={e['rows_per_sec']:.0f}"))
    res.append(("run_grouped_segment_speedup",
                r["run_grouped"]["segment_speedup"], ""))
    for layout in ("segment", "masked"):
        e = r["fit_grouped"][layout]
        res.append((f"fit_grouped_{layout}", e["seconds"] * 1e6,
                    f"iters_per_sec={e['iters_per_sec']:.2f}"))
    return res


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the JSON document here (default: stdout)")
    ap.add_argument("--rows", type=int, default=200_000)
    ap.add_argument("--groups", type=int, default=64)
    ap.add_argument("--fit-groups", type=int, default=64)
    ap.add_argument("--iters", type=int, default=25)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--kernel", choices=("auto", "ref", "pallas"),
                    default="auto",
                    help="segment-fold implementation for the one-pass "
                         "linregr scan (pallas runs interpret off-TPU)")
    ap.add_argument("--sharded", action="store_true",
                    help="device-count scaling of the sharded grouped "
                         "engine instead of the segment-vs-masked bench")
    args = ap.parse_args()
    if args.sharded:
        doc = bench_sharded(rows=args.rows, groups=args.groups,
                            fit_groups=args.fit_groups,
                            max_iters=args.iters, reps=args.reps)
    else:
        doc = bench(rows=args.rows, groups=args.groups,
                    fit_groups=args.fit_groups, max_iters=args.iters,
                    reps=args.reps, kernel=args.kernel)
    text = json.dumps(doc, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.json}")
    else:
        print(text)
