"""Analytics serving: cross-session admission-window sharing + caching.

N analysts hitting one table used to pay N independent executions — even
with each analyst's own batch perfectly fused (PR 5), the table is
scanned once PER SESSION.  The :class:`~repro.core.AnalyticsServer`
admission window plans across sessions: all compatible statements in a
window fuse into ONE pass, identical statements deduplicate to one
member, and repeats against an unchanged table are answered from the
version-keyed result cache with ZERO scans.  Four sections, with scans
counted by :func:`repro.core.trace_execution` (engine events, not
guesses):

* **solo** (baseline) — every session plans and runs its OWN prepared
  batch: ``sessions`` fused passes per round.
* **served** — the same statements submitted by concurrent sessions into
  one admission window, result cache cleared between rounds so the
  number measures window fusion + dedup alone: ONE fused pass per round.
* **cached** — the server round WITHOUT clearing: every statement hits
  the version-keyed cache, zero scans, and the JSON records
  ``bit_identical`` against a fresh solo execution (exact-state
  aggregates only — integer sketches and deterministic f32 folds).
* **mutation** — ``Table.append`` between rounds: eviction hooks drop
  the dead entries and the next round replans (scans back to one),
  results matching a fresh solo run over the grown table bitwise.
* **isolation** — per-table admission windows under the BACKGROUND
  drainer (``drain="thread"``): a deterministically slow statement on
  table A (an eager transition that sleeps) executes while table B's
  statements drain on their own worker.  Overlap and B's drain latency
  come from the per-table ``admission`` trace events' monotonic
  timestamps — never from wall-clock heuristics around the round.

``--drain=thread`` runs the served/cached sections against a
background-drainer server (submitters wait passively on their handles;
the server's own thread fires the windows), measuring the production
serving posture; the default ``demand`` drains on ``flush()`` as
before.  ``--smoke`` asserts the structural claims
(scans-per-statement <= 1/N submitters; cached rounds execute zero
scans with bit-identical results; B's drains overlap A's slow
statement) and is wired into CI with the JSON uploaded as an artifact;
the full run also reports served-vs-solo statement throughput (the
>=3x serving win on scan-dominated batches).
"""

from __future__ import annotations

import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AnalyticsServer, ProfileAggregate, ScanAgg, Session, Table, execute,
    trace_execution,
)
from repro.core.aggregates import MERGE_SUM, Aggregate
from repro.methods.linregr import LinregrAggregate
from repro.methods.sketches import CountMinAggregate, FMAggregate


def _columns(rows: int, dims: int) -> dict:
    rng = np.random.default_rng(0)
    x = rng.standard_normal((rows, dims), dtype=np.float32)
    b = rng.standard_normal(dims, dtype=np.float32)
    y = (x @ b + 0.1 * rng.standard_normal(rows, dtype=np.float32))
    return {"x": x, "y": y.astype(np.float32),
            "item": rng.integers(0, 1000, rows).astype(np.int32)}


def _statements(table: Table, block_size: int) -> list:
    """One analyst's statement set — profile / linregr / two sketches —
    as prebuilt nodes (prepared statements, so steady-state rounds
    measure execution, not trace+compile)."""
    return [
        ScanAgg(ProfileAggregate(), table, columns=("x", "y"),
                block_size=block_size, label="profile"),
        ScanAgg(LinregrAggregate(), table, columns={"x": "x", "y": "y"},
                block_size=block_size, label="linregr"),
        ScanAgg(CountMinAggregate(4, 1024, item_col="item"), table,
                columns=("item",), block_size=block_size, label="countmin"),
        ScanAgg(FMAggregate(item_col="item"), table, columns=("item",),
                block_size=block_size, label="fm"),
    ]


def _block_on(results) -> None:
    for leaf in jax.tree.leaves(results):
        jax.block_until_ready(leaf)


def _bitwise_equal(a, b) -> bool:
    fa = [np.asarray(x) for x in jax.tree.leaves(a)]
    fb = [np.asarray(x) for x in jax.tree.leaves(b)]
    return len(fa) == len(fb) and all(
        x.shape == y.shape and (x == y).all() for x, y in zip(fa, fb))


class _SleepAggregate(Aggregate):
    """Deterministically slow scan: the transition sleeps on the host.
    Run eagerly (``jit=False``, unblocked -> ONE Python-level call), the
    sleep genuinely occupies the executing drain worker for
    ``seconds`` — the isolation section's 'slow statement on table A'."""

    merge_ops = MERGE_SUM

    def __init__(self, seconds: float):
        self.seconds = seconds

    def init(self, block):
        return jnp.zeros((), dtype=jnp.float32)

    def transition(self, state, block, mask):
        time.sleep(self.seconds)
        return state + jnp.sum(jnp.where(mask, block["y"], 0.0))


def _served_round(server: AnalyticsServer, batches: list[list],
                  passive: bool = False) -> list:
    """All sessions submit concurrently into ONE window, then one drain;
    returns every statement's result.  ``passive`` (the drain-thread
    axis) waits on the handles instead of flushing — the server's own
    drainer fires the window."""
    sessions = [Session(server=server) for _ in batches]
    out: list = [None] * len(batches)
    handles: list = []

    def submit(i):
        for node in batches[i]:
            handles.append(sessions[i].statement(node))

    threads = [threading.Thread(target=submit, args=(i,))
               for i in range(len(batches))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if passive:
        for h in handles:
            assert h.wait(60), "background drainer never fired"
    else:
        server.flush()
    for i, s in enumerate(sessions):
        out[i] = s.run()       # window already drained; gathers handles
    return [r for batch in out for r in batch]


def _time_rounds(fn, reps: int):
    """(min seconds over reps, scans in last round) after one untimed
    warmup round (compile)."""
    fn()
    best = float("inf")
    scans = 0
    for _ in range(reps):
        with trace_execution() as t:
            t0 = time.perf_counter()
            out = fn()
            _block_on(out)
            best = min(best, time.perf_counter() - t0)
        scans = len(t.scans)
    return best, scans


def bench(rows: int = 200_000, dims: int = 8, sessions: int = 8,
          reps: int = 3, block_size: int = 4096,
          drain: str = "demand") -> dict:
    cols = _columns(rows, dims)
    table = Table.from_columns(cols)
    n_stmts = sessions * 4
    out: dict = {"config": {"rows": rows, "dims": dims,
                            "sessions": sessions, "reps": reps,
                            "block_size": block_size, "drain": drain,
                            "statements": n_stmts}}

    # -- solo baseline: each session fuses ITS OWN batch, pays its own scan
    solo_batches = [_statements(table, block_size) for _ in range(sessions)]

    def solo_round():
        res = []
        for batch in solo_batches:
            sess = Session()
            for node in batch:
                sess.statement(node)
            res.extend(sess.run())
        return res

    solo_s, solo_scans = _time_rounds(solo_round, reps)
    solo_results = solo_round()
    out["solo"] = {"seconds": solo_s, "scans": solo_scans,
                   "stmts_per_sec": n_stmts / solo_s}

    # -- served: one admission window across all sessions, cache cleared
    passive = drain == "thread"
    server = AnalyticsServer(
        window_size=4 * n_stmts, drain=drain,
        window_timeout=0.01 if passive else None)
    served_batches = [_statements(table, block_size)
                      for _ in range(sessions)]

    def served_round():
        server.clear_cache()
        return _served_round(server, served_batches, passive)

    served_s, served_scans = _time_rounds(served_round, reps)
    out["served"] = {"seconds": served_s, "scans": served_scans,
                     "stmts_per_sec": n_stmts / served_s,
                     "scans_per_statement": served_scans / n_stmts}
    out["speedup"] = solo_s / served_s

    # -- cached: the same window again WITHOUT clearing ------------------
    _served_round(server, served_batches)  # warm the cache
    with trace_execution() as t:
        t0 = time.perf_counter()
        cached_results = _served_round(server, served_batches)
        _block_on(cached_results)
        cached_s = time.perf_counter() - t0
    out["cached"] = {
        "seconds": cached_s, "scans": len(t.scans),
        "cache_hits": len(t.cache_hits),
        "stmts_per_sec": n_stmts / cached_s,
        "bit_identical": _bitwise_equal(cached_results, solo_results),
        "speedup_vs_solo": solo_s / cached_s,
    }

    # -- mutation: append evicts, the next window replans ----------------
    delta = {k: v[: max(1, rows // 100)] for k, v in cols.items()}
    table.append(delta)
    evicted = server.stats["evicted"]
    with trace_execution() as t:
        post_results = _served_round(server, served_batches)
        _block_on(post_results)
    fresh = [execute(node) for node in _statements(table, block_size)]
    out["mutation"] = {
        "evicted": evicted, "scans": len(t.scans),
        "cache_hits": len(t.cache_hits),
        "bit_identical_to_fresh": _bitwise_equal(
            post_results[: len(fresh)], fresh),
    }
    out["server_stats"] = dict(server.stats)
    server.close()

    out["isolation"] = _isolation_section(rows, dims, block_size)
    return out


def _isolation_section(rows: int, dims: int, block_size: int,
                       slow_seconds: float = 0.5) -> dict:
    """Per-table window isolation under the background drainer: while
    table A's drain worker is stuck in a deterministically slow
    statement, table B's statements drain on their own worker.  Overlap
    and latency are read off the per-table ``admission`` trace events
    (monotonic ``opened_at``/``drained_at``), plus one structural check:
    every B handle resolved while A's was still pending."""
    ta = Table.from_columns(_columns(max(rows // 4, 1000), dims))
    tb = Table.from_columns(_columns(max(rows // 4, 1000), dims))
    # warm compile caches so B's drain time measures serving, not XLA
    _block_on([execute(n) for n in _statements(tb, block_size)])
    srv = AnalyticsServer(window_size=1, drain="thread")
    try:
        with trace_execution() as t:
            ha = srv.submit(ScanAgg(
                _SleepAggregate(slow_seconds), ta, columns=("y",),
                engine="local", jit=False, label="slow"))
            time.sleep(0.05)            # let A's worker enter the sleep
            s = Session(server=srv)
            hbs = [s.statement(n) for n in _statements(tb, block_size)]
            for h in hbs:
                assert h.wait(60), "table B starved behind table A"
            overlapped = not ha.done()  # B finished while A still ran
            assert ha.wait(60)
        a_evs = [e.detail for e in t.admissions
                 if e.detail["table"] == id(ta)]
        b_evs = [e.detail for e in t.admissions
                 if e.detail["table"] == id(tb)]
        return {
            "slow_exec_seconds": slow_seconds,
            "a_windows": len(a_evs),
            "b_windows": len(b_evs),
            "b_latency_max": max(e["latency"] for e in b_evs),
            "b_last_drained_before_a_done": (
                max(e["drained_at"] for e in b_evs)
                < a_evs[0]["drained_at"] + slow_seconds),
            "overlapped": overlapped,
        }
    finally:
        srv.close()


def check_smoke(doc: dict) -> None:
    """The structural serving claims, asserted from trace-counted scans —
    CI fails loudly if cross-session sharing or caching regresses."""
    n_sessions = doc["config"]["sessions"]
    served = doc["served"]
    assert served["scans_per_statement"] <= 1.0 / n_sessions, (
        f"window fusion regressed: {served['scans_per_statement']:.3f} "
        f"scans/statement with {n_sessions} submitters (want <= "
        f"{1.0 / n_sessions:.3f})")
    assert served["scans"] >= 1, "served round executed nothing"
    cached = doc["cached"]
    assert cached["scans"] == 0, (
        f"cached round executed {cached['scans']} scans (want 0)")
    assert cached["bit_identical"], (
        "cached results are not bit-identical to solo execution")
    mut = doc["mutation"]
    assert mut["evicted"] >= 1, "append evicted nothing"
    assert mut["scans"] >= 1, "post-mutation round served stale cache"
    assert mut["bit_identical_to_fresh"], (
        "post-mutation results do not match a fresh run")
    iso = doc["isolation"]
    assert iso["overlapped"], (
        "per-table isolation regressed: table B's statements waited out "
        "table A's slow drain")
    assert iso["b_last_drained_before_a_done"], (
        "table B's drains were queued behind table A's slow statement "
        "(admission timestamps)")
    assert iso["b_latency_max"] < iso["slow_exec_seconds"], (
        f"table B drain latency {iso['b_latency_max']:.3f}s approaches "
        f"table A's {iso['slow_exec_seconds']}s execution — windows are "
        "not isolated")


def run(rows: int = 200_000, reps: int = 3):
    """CSV rows for benchmarks/run.py: (name, us_per_call, derived)."""
    r = bench(rows=rows, reps=reps)
    return [
        ("serve_solo_8x4stmt", r["solo"]["seconds"] * 1e6,
         f"scans={r['solo']['scans']}"),
        ("serve_served_8x4stmt", r["served"]["seconds"] * 1e6,
         f"scans={r['served']['scans']}"),
        ("serve_speedup", r["speedup"], ""),
        ("serve_cached_8x4stmt", r["cached"]["seconds"] * 1e6,
         f"hits={r['cached']['cache_hits']} "
         f"bitident={r['cached']['bit_identical']}"),
        ("serve_cached_speedup", r["cached"]["speedup_vs_solo"], ""),
    ]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the JSON document here (default: stdout)")
    ap.add_argument("--rows", type=int, default=200_000)
    ap.add_argument("--dims", type=int, default=8)
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--block-size", type=int, default=4096)
    ap.add_argument("--drain", choices=("demand", "thread"),
                    default="demand",
                    help="'thread' = served/cached sections run against "
                         "the background drainer (passive submitters)")
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + assert the structural claims")
    args = ap.parse_args()
    if args.smoke:
        args.rows = min(args.rows, 20_000)
        args.reps = min(args.reps, 2)
    doc = bench(rows=args.rows, dims=args.dims, sessions=args.sessions,
                reps=args.reps, block_size=args.block_size,
                drain=args.drain)
    if args.smoke:
        check_smoke(doc)
        doc["smoke"] = "ok"
    text = json.dumps(doc, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.json}")
    else:
        print(text)
