"""Planned vs naive N-statement batches — the logical-plan layer's win.

An analyst wanting N independent one-pass statistics from one table used
to pay N full data passes; the plan layer's scan-sharing optimizer folds
every compatible statement into ONE pass.  Three sections, all with
scans/sorts counted by :func:`repro.core.trace_execution` (engine-entry
events, not guesses):

* **out_of_core** (headline "speedup") — the 4-statement batch over a
  host-side block stream, the regime the paper's §2.1 argues from (data
  sets larger than memory: a scan means actually moving the data).
  naive re-streams all blocks once per statement (4 host→device feeds);
  planned fuses the four statements into ONE ``run_stream`` fold.
* **in_memory** — the same batch as resident-table ``ScanAgg``
  statements, both ``first_run`` (fresh statements: per-statement
  trace+compile, what a one-shot query pays) and ``prepared`` (retained
  statements: the engine program caches hit, so only execution remains —
  on an in-memory CPU table the scan term is nearly free and fusion is
  cost-neutral, which the JSON reports transparently).
* **grouped** — the sort-dedup win: N grouped statements over one key
  pay ONE partitioning sort planned vs N when each statement owns a
  fresh table.

``run()`` feeds the CSV harness (benchmarks/run.py); ``python -m
benchmarks.bench_plan [--json out.json]`` emits a JSON document for the
bench trajectory and the CI smoke artifact.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ProfileAggregate, Session, Table, execute, trace_execution,
)
from repro.core.plan import GroupedScanAgg, ScanAgg, StreamAgg
from repro.methods.linregr import LinregrAggregate
from repro.methods.naive_bayes import NaiveBayesAggregate
from repro.methods.quantiles import HistogramAggregate
from repro.methods.sketches import CountMinAggregate, FMAggregate


def _columns(rows: int, dims: int) -> dict:
    rng = np.random.default_rng(0)
    x = rng.standard_normal((rows, dims), dtype=np.float32)
    b = rng.standard_normal(dims, dtype=np.float32)
    y = (x @ b + 0.1 * rng.standard_normal(rows, dtype=np.float32))
    return {"x": x, "y": y.astype(np.float32),
            "cls": (y > 0).astype(np.float32),
            "item": rng.integers(0, 1000, rows).astype(np.int32),
            "g": (np.arange(rows) % 16).astype(np.int32)}


def _aggs():
    """The 4-statement batch: representative one-pass statistics with
    scan-dominated (cheap-transition) folds, each with its projection so
    templated members keep their schemas under fusion."""
    return [
        ("profile", ProfileAggregate(), ("x", "y")),
        ("linregr", LinregrAggregate(), {"x": "x", "y": "y"}),
        ("quantile_hist", HistogramAggregate(-8.0, 8.0, 4096, "y"), None),
        ("naive_bayes", NaiveBayesAggregate(2), {"x": "x", "y": "cls"}),
    ]


def _time(fn, reps: int) -> tuple[float, int]:
    """(min seconds over reps, scans per call) after one untimed warmup,
    blocking on EVERY result leaf."""
    fn()
    best = float("inf")
    scans = 0
    for _ in range(reps):
        with trace_execution() as t:
            t0 = time.perf_counter()
            out = fn()
            for leaf in jax.tree.leaves(out):
                jax.block_until_ready(leaf)
            best = min(best, time.perf_counter() - t0)
        scans = len(t.scans)
    return best, scans


def _section(naive, planned, reps: int) -> dict:
    n_s, n_scans = _time(naive, reps)
    p_s, p_scans = _time(planned, reps)
    return {"naive": {"seconds": n_s, "scans": n_scans},
            "planned": {"seconds": p_s, "scans": p_scans},
            "speedup": n_s / p_s}


def bench(rows: int = 200_000, dims: int = 8, reps: int = 3,
          block_size: int = 4096) -> dict:
    cols = _columns(rows, dims)
    out: dict = {"config": {"rows": rows, "dims": dims, "reps": reps,
                            "block_size": block_size,
                            "statements": len(_aggs())}}

    # -- out-of-core: the paper's §2.1 regime (headline) ------------------
    host_blocks = [{k: v[i:i + block_size] for k, v in cols.items()}
                   for i in range(0, rows, block_size)]

    def factory():
        return iter([dict(b) for b in host_blocks])

    stream_stmts = [StreamAgg(agg, None, columns=proj, label=name)
                    for name, agg, proj in _aggs()]

    def stream_naive():
        res = []
        for node in stream_stmts:
            node.blocks = factory()  # each statement re-streams the data
            res.append(execute(node))
        return res

    def stream_planned():
        src = factory()  # ONE shared stream, fused by the planner
        sess = Session()
        for node in stream_stmts:
            node.blocks = src
            sess.statement(node)
        return sess.run()

    out["out_of_core"] = _section(stream_naive, stream_planned, reps)
    out["speedup"] = out["out_of_core"]["speedup"]

    # -- in-memory: first-run (compile included) and prepared -------------
    table = Table.from_columns(cols)

    def make_stmts():
        return [ScanAgg(agg, table, columns=proj, block_size=block_size,
                        label=name) for name, agg, proj in _aggs()]

    def inmem_naive_first():
        return [execute(node) for node in make_stmts()]

    def inmem_planned_first():
        sess = Session()
        for node in make_stmts():
            sess.statement(node)
        return sess.run()

    prepared = make_stmts()

    def inmem_naive_prepared():
        return [execute(node) for node in prepared]

    def inmem_planned_prepared():
        sess = Session()
        for node in prepared:
            sess.statement(node)
        return sess.run()

    out["in_memory"] = {
        "first_run": _section(inmem_naive_first, inmem_planned_first,
                              reps),
        "prepared": _section(inmem_naive_prepared, inmem_planned_prepared,
                             reps),
    }

    sess = Session()
    for node in make_stmts():
        sess.statement(node)
    out["explain"] = sess.explain()

    # -- grouped batches: the sort-dedup win ------------------------------
    def grouped_nodes(tbl):
        return [
            GroupedScanAgg(CountMinAggregate(depth=4, width=1024,
                                             item_col="item"), tbl, "g",
                           columns=("item",), label="countmin_grouped"),
            GroupedScanAgg(FMAggregate(item_col="item"), tbl, "g",
                           columns=("item",), label="fm_grouped"),
            GroupedScanAgg(LinregrAggregate(), tbl, "g",
                           columns=("x", "y"), label="linregr_grouped"),
        ]

    def grouped_naive():
        # fresh table per statement = no shared memo: the pre-plan cost
        res = []
        for node in grouped_nodes(table):
            node.table = Table(dict(table.columns))
            res.append(execute(node))
        return res

    def grouped_planned():
        tbl = Table(dict(table.columns))
        sess = Session()
        for node in grouped_nodes(tbl):
            sess.statement(node)
        return sess.run()

    grouped = _section(grouped_naive, grouped_planned, reps)
    with trace_execution() as t:
        grouped_naive()
    grouped["naive"]["sorts"] = len(t.sorts)
    with trace_execution() as t:
        grouped_planned()
    grouped["planned"]["sorts"] = len(t.sorts)
    out["grouped"] = grouped
    return out


def run(rows: int = 200_000, reps: int = 3):
    """CSV rows for benchmarks/run.py: (name, us_per_call, derived)."""
    r = bench(rows=rows, reps=reps)
    return [
        ("plan_stream_naive_4stmt", r["out_of_core"]["naive"]["seconds"]
         * 1e6, f"scans={r['out_of_core']['naive']['scans']}"),
        ("plan_stream_planned_4stmt",
         r["out_of_core"]["planned"]["seconds"] * 1e6,
         f"scans={r['out_of_core']['planned']['scans']}"),
        ("plan_stream_speedup", r["speedup"], ""),
        ("plan_inmem_first_run_speedup",
         r["in_memory"]["first_run"]["speedup"], ""),
        ("plan_grouped_speedup", r["grouped"]["speedup"],
         f"sorts {r['grouped']['naive']['sorts']}->"
         f"{r['grouped']['planned']['sorts']}"),
    ]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the JSON document here (default: stdout)")
    ap.add_argument("--rows", type=int, default=200_000)
    ap.add_argument("--dims", type=int, default=8)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--block-size", type=int, default=4096)
    args = ap.parse_args()
    doc = bench(rows=args.rows, dims=args.dims, reps=args.reps,
                block_size=args.block_size)
    text = json.dumps(doc, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.json}")
    else:
        print(text)
