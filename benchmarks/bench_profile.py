"""Shared-scan profile benchmark — the §4.1 data-movement argument, one
level up.

MADlib's ``profile`` computes every column's statistics in ONE table
scan; the sequential baseline here re-scans the table once per aggregate
(one ProfileAggregate pass + one FM pass per integer column — exactly
what ``profile`` did before FusedAggregate).  We report, for growing
column counts, the number of data passes each strategy executes (counted
by wrapping the top-level transition) and the measured wall time.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import Table, run_local
from repro.core.aggregates import FusedAggregate
from repro.core.templates import ProfileAggregate
from repro.methods.profile import profile, profile_aggregates
from repro.methods.sketches import FMAggregate


def _timeit(fn, reps=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


class _CountingFused(FusedAggregate):
    """Counts top-level transition invocations == data passes executed."""

    passes = 0

    def transition(self, state, block, mask):
        _CountingFused.passes += 1
        return super().transition(state, block, mask)


def _make_table(key, rows, n_int_cols):
    cols = {"f0": jax.random.normal(key, (rows,)),
            "f1": jax.random.normal(jax.random.fold_in(key, 1), (rows,))}
    for i in range(n_int_cols):
        cols[f"i{i}"] = jax.random.randint(
            jax.random.fold_in(key, 100 + i), (rows,), 0, 5000)
    return Table.from_columns(cols)


def _sequential_profile(table, block_size):
    """The pre-FusedAggregate dataflow: one scan per aggregate."""
    out = dict(run_local(ProfileAggregate(), table, block_size=block_size))
    for name, col in table.columns.items():
        if jnp.issubdtype(col.dtype, jnp.integer) and col.ndim == 1:
            t = Table({"item": col})
            est = run_local(FMAggregate(item_col="item"), t,
                            block_size=block_size)
            out[name] = dict(out[name], approx_distinct=est)
    return out


def run(rows: int = 100_000, reps: int = 3):
    key = jax.random.PRNGKey(0)
    results = []
    block_size = 8192
    for n_int in (1, 4, 8):
        tbl = _make_table(key, rows, n_int)

        # -- pass counts (trace-time; independent of wall clock) ----------
        _CountingFused.passes = 0
        run_local(_CountingFused(profile_aggregates(
            tbl, distinct_counts=True)), tbl, block_size=None)
        fused_passes = _CountingFused.passes
        seq_passes = 1 + n_int               # stats scan + one FM per col

        # -- wall time ----------------------------------------------------
        dt_seq = _timeit(lambda: _sequential_profile(tbl, block_size),
                         reps=reps)
        dt_fused = _timeit(lambda: profile(tbl, distinct_counts=True,
                                           block_size=block_size), reps=reps)
        results.append((
            f"profile_seq_cols{n_int}_n{rows}", dt_seq * 1e6,
            f"passes={seq_passes}"))
        results.append((
            f"profile_fused_cols{n_int}_n{rows}", dt_fused * 1e6,
            f"passes={fused_passes}_speedup={dt_seq / dt_fused:.2f}x"))
    return results


if __name__ == "__main__":
    for name, us, extra in run():
        print(f"{name},{us:.1f},{extra}")
