"""Data pipeline: deterministic synthetic token streams, sharded batch
placement, background prefetch, and MADlib-sketch corpus profiling.

The profiling layer is the paper's descriptive-statistics catalogue run as
UDAs over the token stream (count-min token frequencies, FM distinct
n-grams, histogram quantiles of sequence lengths) — MADlib's ``profile``
applied to an LM corpus, used for data-quality monitoring in the trainer.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..core.table import Table
from ..core.aggregates import run_local
from ..methods.sketches import CountMinAggregate, FMAggregate, \
    countmin_query
from ..methods.quantiles import HistogramAggregate


@dataclasses.dataclass
class TokenStream:
    """Deterministic synthetic LM corpus: Zipfian unigrams with short-range
    bigram structure (so models have something learnable)."""

    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    zipf_a: float = 1.2

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed)
        # Zipf over a capped vocab for sampling stability
        v_eff = min(self.vocab, 50_000)
        ranks = np.arange(1, v_eff + 1)
        probs = ranks ** (-self.zipf_a)
        probs /= probs.sum()
        while True:
            base = rng.choice(v_eff, size=(self.batch, self.seq_len),
                              p=probs)
            # bigram structure: with p=0.5, token t+1 = (token t + 1) % v
            rep = rng.random((self.batch, self.seq_len)) < 0.5
            shifted = (np.roll(base, 1, axis=1) + 1) % v_eff
            toks = np.where(rep, shifted, base).astype(np.int32)
            yield {
                "tokens": toks,
                "labels": np.roll(toks, -1, axis=1).astype(np.int32),
                "mask": np.ones((self.batch, self.seq_len), np.float32),
            }


def synthetic_batch(cfg, batch: int, seq: int, key) -> dict:
    """One random batch matching input_specs (for tests/benches)."""
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab)
    return {
        "tokens": toks,
        "labels": jnp.roll(toks, -1, axis=1),
        "mask": jnp.ones((batch, seq), jnp.float32),
    }


def make_lm_batches(stream: TokenStream, mesh=None, sharding=None,
                    prefetch: int = 2) -> Iterator[dict]:
    """Host->device pipeline with a background prefetch thread.

    The producer thread keeps ``prefetch`` batches in flight (device_put
    overlaps with compute — the data-pipeline guide's double-buffering
    pattern)."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def produce():
        for np_batch in stream:
            if stop.is_set():
                return
            batch = {k: jnp.asarray(v) for k, v in np_batch.items()}
            if sharding is not None:
                batch = {k: jax.device_put(v, sharding[k])
                         for k, v in batch.items()}
            q.put(batch)

    t = threading.Thread(target=produce, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()


def corpus_profile(token_batches, *, vocab: int, n_batches: int = 4,
                   cm_width: int = 4096) -> dict:
    """MADlib-sketch profile of a token stream: heavy hitters (count-min),
    distinct-token estimate (FM), token-id quantiles (histogram)."""
    cm = CountMinAggregate(depth=4, width=cm_width, item_col="tokens")
    fm = FMAggregate(item_col="tokens")
    cm_state, fm_state, hist_state = None, None, None
    hist = HistogramAggregate(0, vocab, bins=1024, value_col="tokens")
    it = iter(token_batches)
    for _ in range(n_batches):
        b = next(it)
        flat = jnp.asarray(b["tokens"]).reshape(-1)
        tbl = {"tokens": flat}
        mask = jnp.ones(flat.shape, jnp.bool_)
        cm_state = cm.transition(
            cm_state if cm_state is not None else cm.init(tbl), tbl, mask)
        fm_state = fm.transition(
            fm_state if fm_state is not None else fm.init(tbl), tbl, mask)
        hist_state = hist.transition(
            hist_state if hist_state is not None else hist.init(tbl), tbl,
            mask)
    top_ids = jnp.arange(64)
    return {
        "heavy_hitters": countmin_query(cm_state, top_ids),
        "distinct_estimate": fm.final(fm_state),
        "token_histogram": hist_state,
    }
