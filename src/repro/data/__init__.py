from .pipeline import (
    TokenStream,
    corpus_profile,
    make_lm_batches,
    synthetic_batch,
)

__all__ = ["TokenStream", "corpus_profile", "make_lm_batches",
           "synthetic_batch"]
