from .optimizers import (
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    sgdm_init,
    sgdm_update,
)
from .schedules import cosine_schedule, linear_warmup_cosine

__all__ = ["AdamWState", "adamw_init", "adamw_update",
           "clip_by_global_norm", "sgdm_init", "sgdm_update",
           "cosine_schedule", "linear_warmup_cosine"]
