"""Optimizers (explicit pytree states, fp32 moments over bf16 params).

The optimizer *is* the UDA ``final`` function of the gradient aggregate
(DESIGN.md §3): transition = microbatch grads, merge = psum, final = the
update below.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any          # first moment (fp32)
    nu: Any          # second moment (fp32)
    count: jax.Array


def adamw_init(params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(jax.tree.map(f32, params), jax.tree.map(f32, params),
                      jnp.zeros((), jnp.int32))


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    count = state.count + 1
    t = count.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        m_hat = m_new / (1 - b1 ** t)
        v_hat = v_new / (1 - b2 ** t)
        step = m_hat / (jnp.sqrt(v_hat) + eps) + weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), \
            m_new, v_new

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(new_mu, new_nu, count)


def sgdm_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgdm_update(grads, momentum, params, *, lr, beta=0.9):
    new_m = jax.tree.map(
        lambda m, g: beta * m + g.astype(jnp.float32), momentum, grads)
    new_p = jax.tree.map(
        lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
        params, new_m)
    return new_p, new_m


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm
