"""Version tolerance for the narrow slice of the JAX API that moved.

The codebase targets current JAX (``jax.shard_map``, ``jax.make_mesh``
with ``axis_types``); older releases still carry ``shard_map`` under
``jax.experimental`` (with ``check_rep`` instead of ``check_vma``) and
meshes without axis types.  Every mesh / shard_map construction in the
repo funnels through these two helpers so the rest of the code can be
written against the current API only.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` where available, the experimental one otherwise."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def random_multinomial(key, n: int, p):
    """``jax.random.multinomial`` where available; categorical+histogram
    fallback (same distribution, different draws) otherwise."""
    if hasattr(jax.random, "multinomial"):
        return jax.random.multinomial(key, n, p)
    import jax.numpy as jnp
    idx = jax.random.categorical(key, jnp.log(p), shape=(int(n),))
    return jnp.zeros(p.shape[-1], p.dtype).at[idx].add(1)


def axis_size(axis_name) -> "jax.Array | int":
    """``jax.lax.axis_size`` where available; psum-of-ones fallback."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict: older JAX returns a
    one-element list of per-device dicts."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost or {})


def make_mesh(axis_shapes: tuple[int, ...], axis_names: tuple[str, ...],
              devices=None):
    """An Auto-typed mesh on new JAX; a plain mesh where types don't exist.

    ``devices`` selects an explicit device subset (e.g. meshes of 1/2/4
    devices on an 8-device host for device-count scaling benchmarks) —
    ``jax.make_mesh`` requires the product of ``axis_shapes`` to cover
    every addressable device, so subsets build a plain ``Mesh`` directly
    on every JAX version."""
    if devices is not None:
        import numpy as np
        return jax.sharding.Mesh(
            np.asarray(devices).reshape(axis_shapes), axis_names)
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)
