"""Templated queries — MADlib §3.1.3.

SQL's first-order-logic roots force queries to know their input schema;
MADlib generates SQL from templates by interrogating the catalog.  JAX's
trace-time shape polymorphism gives us the same thing natively: a
"templated" op interrogates the *pytree structure* of a Table at trace
time and synthesizes the computation for whatever columns are present.

The flagship instance is :func:`profile_spec` (the MADlib ``profile``
module): given an arbitrary table it emits, per numeric column, the
univariate summary aggregate — whose state is a mixed-merge pytree
(count=sum, min=min, max=max, moments=sum), exercising the per-leaf merge
combinators of :mod:`repro.core.aggregates`.
"""

from __future__ import annotations

from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from .aggregates import Aggregate, MERGE_MAX, MERGE_MIN, MERGE_SUM
from .table import Table, Columns


class ProfileAggregate(Aggregate):
    """Schema-generic univariate statistics over every numeric column.

    State per column: {count, sum, sumsq, min, max}; final adds mean/std.
    The merge-op pytree is synthesized from the input schema at trace time —
    this is the "templated query" pattern.
    """

    def __init__(self):
        self.merge_ops = None  # synthesized in init()

    def cache_key(self):
        # No constructor parameters: the result is a pure function of the
        # input schema/rows, which the server's cache key already pins via
        # (table id, table version, projection).
        return ("profile",)

    def init(self, block: Columns):
        state, ops = {}, {}
        for name, col in block.items():
            if not jnp.issubdtype(col.dtype, jnp.number):
                continue
            f = jnp.float32
            state[name] = {
                "count": jnp.zeros((), f),
                "sum": jnp.zeros(col.shape[1:], f),
                "sumsq": jnp.zeros(col.shape[1:], f),
                "min": jnp.full(col.shape[1:], jnp.inf, f),
                "max": jnp.full(col.shape[1:], -jnp.inf, f),
            }
            ops[name] = {
                "count": MERGE_SUM, "sum": MERGE_SUM, "sumsq": MERGE_SUM,
                "min": MERGE_MIN, "max": MERGE_MAX,
            }
        self.merge_ops = ops
        return state

    def transition(self, state, block: Columns, mask):
        out = {}
        for name, st in state.items():
            col = block[name].astype(jnp.float32)
            m = mask.astype(jnp.float32).reshape((-1,) + (1,) * (col.ndim - 1))
            big = jnp.where(
                mask.reshape((-1,) + (1,) * (col.ndim - 1)), col, jnp.inf
            )
            small = jnp.where(
                mask.reshape((-1,) + (1,) * (col.ndim - 1)), col, -jnp.inf
            )
            out[name] = {
                "count": st["count"] + jnp.sum(mask.astype(jnp.float32)),
                "sum": st["sum"] + jnp.sum(col * m, axis=0),
                "sumsq": st["sumsq"] + jnp.sum(col * col * m, axis=0),
                "min": jnp.minimum(st["min"], jnp.min(big, axis=0)),
                "max": jnp.maximum(st["max"], jnp.max(small, axis=0)),
            }
        return out

    def final(self, state):
        out = {}
        for name, st in state.items():
            n = jnp.maximum(st["count"], 1.0)
            mean = st["sum"] / n
            var = jnp.maximum(st["sumsq"] / n - mean ** 2, 0.0)
            out[name] = dict(st, mean=mean, std=jnp.sqrt(var))
        return out


def map_columns(table: Table, fn: Callable[[str, jax.Array], jax.Array | None]
                ) -> Table:
    """Apply ``fn(name, column)`` to every column; drop columns mapped to
    None.  A templated SELECT-expression generator."""
    cols = {}
    for name, col in table.columns.items():
        new = fn(name, col)
        if new is not None:
            cols[name] = new
    return Table(cols, table.mesh, table.row_axes)


def one_hot_encode(table: Table, column: str, num_classes: int) -> Table:
    """Templated categorical expansion: replaces an int column with a
    ``(n, num_classes)`` one-hot float column (schema synthesized at trace)."""
    col = table[column].astype(jnp.int32)
    return table.with_column(column, jax.nn.one_hot(col, num_classes))
