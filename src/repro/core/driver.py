"""Driver functions for multipass iteration — MADlib §3.1.2.

MADlib implements iterative methods (IRLS, k-means, MCMC) with a thin
Python driver that kicks off bulk parallel work each round and stages
inter-iteration state in temp tables, so that *no large data ever moves
through the driver*.  The two engines here preserve that design:

* :func:`host_driver` — a host-side loop around a jitted, buffer-donating
  step function.  Inter-iteration state lives in donated device buffers
  (the temp-table analogue); the host pulls only the scalar convergence
  criterion each round.  This is the paper-faithful pattern, and the right
  one when each iteration is itself a big pjit computation (LM training).
* :func:`device_driver` — a fully fused ``lax.while_loop`` with a
  data-dependent stopping condition (the paper's "recursive query"
  workaround, done natively).  Zero host round-trips; the whole iteration
  compiles into one XLA program.

Both return an :class:`IterationResult` carrying the final state, iteration
count, and a trace of the convergence metric.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, TypeVar

import jax
import jax.numpy as jnp

S = TypeVar("S")

StepFn = Callable[[S], S]                # state -> state
MetricFn = Callable[[S, S], jax.Array]   # (prev, new) -> scalar convergence metric


@dataclasses.dataclass
class IterationResult:
    state: Any
    n_iters: int
    converged: bool
    metric_trace: list | jax.Array


def host_driver(step: StepFn, init_state: S, *, metric: MetricFn,
                tol: float, max_iters: int,
                donate: bool = True) -> IterationResult:
    """Host-controlled iteration with device-resident state.

    ``step`` is jitted once with the previous state donated, so each round
    reuses buffers in place ("CREATE TEMP TABLE ... AS SELECT" without the
    MVCC copy, DESIGN.md §2).  Only ``metric`` (a scalar) crosses to the
    host per round.
    """

    @partial(jax.jit, donate_argnums=(0,) if donate else ())
    def fused(prev):
        new = step(prev)
        return new, metric(prev, new)

    # Copy so that donation never consumes caller-owned buffers.
    state = jax.tree.map(lambda x: jnp.array(x, copy=True), init_state)
    trace = []
    converged = False
    it = 0
    for it in range(1, max_iters + 1):
        state, m = fused(state)
        m = float(m)  # the only host pull per round
        trace.append(m)
        if m < tol:
            converged = True
            break
    return IterationResult(state, it, converged, trace)


def device_driver(step: StepFn, init_state: S, *, metric: MetricFn,
                  tol: float, max_iters: int) -> IterationResult:
    """Fully on-device iteration via ``lax.while_loop``.

    The convergence test is part of the compiled program (data-dependent
    stopping), so the driver round-trip disappears entirely.  The metric
    trace is materialized as a fixed-size buffer (NaN beyond the stop).
    """

    def cond(carry):
        _, i, m, _ = carry
        return jnp.logical_and(i < max_iters, m >= tol)

    def body(carry):
        prev, i, _, trace = carry
        new = step(prev)
        m = metric(prev, new)
        trace = trace.at[i].set(m)
        return new, i + 1, m, trace

    trace0 = jnp.full((max_iters,), jnp.nan, jnp.float32)
    init = (jax.tree.map(jnp.asarray, init_state), jnp.int32(0), jnp.float32(jnp.inf), trace0)
    state, n, m, trace = jax.jit(lambda c: jax.lax.while_loop(cond, body, c))(init)
    n = int(n)
    return IterationResult(state, n, bool(m < tol), trace[:n])


def counted_driver(step: StepFn, init_state: S, n_iters: int,
                   *, unroll: int = 1) -> S:
    """Fixed-count iteration (the paper's "virtual table" counted join):
    ``lax.scan`` over ``n_iters`` rounds, compiled once."""

    def body(state, _):
        return step(state), None

    state, _ = jax.jit(
        lambda s: jax.lax.scan(body, s, None, length=n_iters, unroll=unroll)
    )(jax.tree.map(jnp.asarray, init_state))
    return state[0] if isinstance(state, tuple) and len(state) == 2 else state


def relative_change(prev, new) -> jax.Array:
    """Default convergence metric: ||new - prev|| / (||prev|| + eps)."""
    dn = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda p, n: jnp.sum((n - p) ** 2), prev, new),
    )
    pn = jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda p: jnp.sum(p ** 2), prev)
    )
    return jnp.sqrt(dn) / (jnp.sqrt(pn) + 1e-12)
