"""Driver functions for multipass iteration — MADlib §3.1.2.

Thin compatibility layer over :mod:`repro.core.iterative`, which owns the
actual loop engines (the unified executor absorbed this module's
``lax.while_loop`` / ``lax.scan`` / host-loop machinery).  These helpers
remain for step-function-shaped iteration that has no table scan at all —
``step: state -> state`` plus a convergence metric:

* :func:`host_driver`   — host loop, donated device buffers, one scalar
  pulled per round (the paper-faithful temp-table pattern).
* :func:`device_driver` — fully fused ``lax.while_loop`` with
  data-dependent stopping (the "recursive query" done natively).
* :func:`counted_driver`— fixed-count ``lax.scan``.

Anything that *does* scan a table each round should instead register an
:class:`repro.core.iterative.IterativeTask` and call
:func:`repro.core.iterative.fit`.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, TypeVar

import jax
import jax.numpy as jnp

from .iterative import _while_fit, relative_change

S = TypeVar("S")

StepFn = Callable[[S], S]                # state -> state
MetricFn = Callable[[S, S], jax.Array]   # (prev, new) -> scalar convergence metric


@dataclasses.dataclass
class IterationResult:
    state: Any
    n_iters: int
    converged: bool
    metric_trace: list | jax.Array


def host_driver(step: StepFn, init_state: S, *, metric: MetricFn,
                tol: float, max_iters: int,
                donate: bool = True) -> IterationResult:
    """Host-controlled iteration with device-resident state.

    ``step`` is jitted once with the previous state donated, so each round
    reuses buffers in place ("CREATE TEMP TABLE ... AS SELECT" without the
    MVCC copy, DESIGN.md §2).  Only ``metric`` (a scalar) crosses to the
    host per round.
    """

    @partial(jax.jit, donate_argnums=(0,) if donate else ())
    def fused(prev):
        new = step(prev)
        return new, metric(prev, new)

    # Copy so that donation never consumes caller-owned buffers.
    state = jax.tree.map(lambda x: jnp.array(x, copy=True), init_state)
    trace = []
    converged = False
    it = 0
    for it in range(1, max_iters + 1):
        state, m = fused(state)
        m = float(m)  # the only host pull per round
        trace.append(m)
        if m < tol:
            converged = True
            break
    return IterationResult(state, it, converged, trace)


def device_driver(step: StepFn, init_state: S, *, metric: MetricFn,
                  tol: float, max_iters: int) -> IterationResult:
    """Fully on-device iteration via the unified executor's
    ``lax.while_loop`` fast path: the convergence test is part of the
    compiled program, so the driver round-trip disappears entirely."""

    def iter_fn(state):
        new = step(state)
        m = jnp.asarray(metric(state, new), jnp.float32)
        return new, jnp.zeros(()), m, m  # aux unused; trace the metric

    state, _, n, m, trace = jax.jit(
        lambda s: _while_fit(iter_fn, s, max_iters, tol)
    )(jax.tree.map(jnp.asarray, init_state))
    n = int(n)
    return IterationResult(state, n, bool(m < tol), trace[:n])


def counted_driver(step: StepFn, init_state: S, n_iters: int,
                   *, unroll: int = 1) -> S:
    """Fixed-count iteration (the paper's "virtual table" counted join):
    ``lax.scan`` over ``n_iters`` rounds, compiled once."""

    def body(state, _):
        return step(state), None

    state, _ = jax.jit(
        lambda s: jax.lax.scan(body, s, None, length=n_iters, unroll=unroll)
    )(jax.tree.map(jnp.asarray, init_state))
    return state[0] if isinstance(state, tuple) and len(state) == 2 else state
