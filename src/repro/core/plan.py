"""Logical plans over the engine matrix — the declarative layer (§3.2).

MADlib's interface is declarative: the analyst issues *statements* and
the database decides how to execute them, sharing work across the query
where it can.  Feng et al. ("Towards a Unified Architecture for
in-RDBMS Analytics") and sql4ml argue the same split — declarative
statements above, ONE unified execution architecture below.  This module
is the layer above our engine matrix: method wrappers stop calling
``run_local`` / ``run_sharded`` / ``run_grouped`` / ``fit*`` directly
and instead emit **logical plan nodes**; the planner then

* **shares scans across statements** — every compatible :class:`ScanAgg`
  over the same (table, mask, block size) fuses into ONE ``run_many``
  pass, every compatible :class:`GroupedScanAgg` over the same
  (table, group column) into ONE ``run_grouped`` pass, and
  :class:`StreamAgg` statements over the same block source into ONE
  ``run_stream`` fold (mandatory there: a shared iterator can only be
  consumed once).  ``profile``'s PR-1 hand-built fusion now *falls out*
  of this optimizer;
* **dedups sorts** — grouped passes resolve their :class:`GroupedView`
  through the memoized :meth:`Table.group_by`, so N grouped statements
  (and ``fit_grouped``) over one key pay ONE partitioning sort;
* **fuses joined statements** — :class:`JoinedGroupedScanAgg` statements
  over one ``(fact, dim, key, attr)`` star triple share ONE device-side
  sort-merge key resolution (:class:`~repro.core.join.Join`, memoized)
  and ONE segment scan; the cost model prices the sort-share strategy
  against gather-materializing the dimension onto fact rows
  (:func:`join_cost`), and ``explain()`` renders the join and its
  shared sort;
* **selects engines cost-based** — candidates come from
  :data:`ENGINE_CAPS` (the capability matrix) filtered by what the
  statement needs (mask? group_by? fit? stream?), ranked by a row-cost
  model (rows × mesh segments × generic-merge fallbacks), and the
  chosen physical plan renders like ``EXPLAIN`` via
  :meth:`PhysicalPlan.explain`.

Fusion is *refused loudly* when it would be wrong: statements with
different base masks (or tables, or block partitionings) must never fold
into one ``run_many`` — one statement's filter would silently apply to
another.  The planner keys passes so this cannot happen, and the pass
constructors re-check and raise (:func:`fused_scan_pass`).

Correctness contract: fusing changes the number of physical passes and
NOTHING else.  Members run their own transitions on the same blocked
partitioning as a solo run, so exact-state aggregates (integer sketches,
histogram counts, dyadic sums) are **bit-identical** to per-statement
execution; templated members (``ProfileAggregate``) see exactly their
statement's columns through the :class:`_Projected` adapter even when
the fused block carries more.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import calibration as _calibration
from .aggregates import (
    Aggregate, _fused_for, probe_segment_ops, run_grouped, run_many,
    run_stream, segment_block_size,
)
from .iterative import (
    IterativeTask, _segment_task_ok, fit, fit_grouped, fit_stream,
)
from .join import Join
from .table import Columns, GroupedView, Table
from .trace import record as _record

# ---------------------------------------------------------------------------
# The capability matrix — which cross-cutting features each engine honors.
# (The docstring table in core/__init__ is rendered from this data; the
# planner filters candidate engines through it before costing them.)
# ---------------------------------------------------------------------------

ENGINE_CAPS: dict[str, dict[str, bool]] = {
    "local":           {"mask": True,  "group_by": False, "fit": True,
                        "stream": False},
    "sharded":         {"mask": True,  "group_by": False, "fit": True,
                        "stream": False},
    "stream":          {"mask": False, "group_by": False, "fit": True,
                        "stream": True},
    "grouped-segment": {"mask": True,  "group_by": True,  "fit": True,
                        "stream": False},
    "grouped-masked":  {"mask": True,  "group_by": True,  "fit": True,
                        "stream": False},
    "sharded-grouped": {"mask": True,  "group_by": True,  "fit": True,
                        "stream": False},
}


# ---------------------------------------------------------------------------
# Logical plan nodes.
# ---------------------------------------------------------------------------

# ``columns`` on scan nodes is the statement's projection: a tuple of
# column names, or a {target: source} mapping when the aggregate expects
# renamed keys (``linregr`` reading x from "features").  None = the whole
# table.  Projections are semantic, not just an optimization: templated
# aggregates (ProfileAggregate) profile exactly the columns they see.
Projection = "tuple[str, ...] | Mapping[str, str] | None"


@dataclasses.dataclass(eq=False)
class ScanAgg:
    """One-pass aggregate over a table (``SELECT agg(...) FROM t``)."""

    agg: Aggregate
    table: Table
    columns: Any = None          # Projection
    mask: Any = None             # base row filter, table row order
    block_size: int | None = None
    engine: str = "auto"         # "auto" | "local" | "sharded"
    jit: bool = True
    label: str | None = None


@dataclasses.dataclass(eq=False)
class GroupedScanAgg:
    """Grouped aggregate (``SELECT g, agg(...) FROM t GROUP BY g``).

    ``table`` may be a prebuilt :class:`GroupedView` (``group_col``
    ignored); otherwise the planner resolves the view through the
    memoized ``Table.group_by`` — the sort-dedup point.
    """

    agg: Aggregate
    table: Any                   # Table | GroupedView
    group_col: str | None = None
    num_groups: int | None = None
    columns: Any = None          # Projection (of the view's data columns)
    mask: Any = None
    block_size: int | None = None
    method: str = "auto"         # "auto" | "segment" | "masked"
    mesh: Any = None             # None -> the table's mesh (may be None)
    row_axes: Any = None
    jit: bool = True
    label: str | None = None


@dataclasses.dataclass(eq=False)
class JoinedGroupedScanAgg:
    """Grouped aggregate over an equi-join (``SELECT dim.attr, agg(...)
    FROM fact JOIN dim GROUP BY dim.attr``) — the first multi-table
    statement.

    ``join`` is a :class:`~repro.core.join.Join` spec; the planner
    resolves it via the memoized device-side sort-merge (one dimension
    key argsort + one searchsorted, producing a fact-aligned group-id
    column) and routes the result through the ordinary grouped core —
    the dimension's columns are never materialized onto fact rows.
    Statements over one (fact, dim, key, attr) triple fuse into ONE
    pass; ``num_groups`` defaults to ``max(dim.attr) + 1``.  ``mask``
    (like ``columns``) is in FACT row order — the joined table is
    fact-row-aligned.
    """

    agg: Aggregate
    join: Join
    num_groups: int | None = None
    columns: Any = None          # Projection (of the fact's columns)
    mask: Any = None             # base row filter, fact row order
    block_size: int | None = None
    method: str = "auto"         # "auto" | "segment" | "masked"
    mesh: Any = None             # None -> the fact table's mesh
    row_axes: Any = None
    jit: bool = True
    label: str | None = None


@dataclasses.dataclass(eq=False)
class IterativeFit:
    """Iterative model fit (the §3.1.2 driver pattern as a statement).

    Dispatches on its attributes: ``blocks`` set -> ``fit_stream``;
    ``group_col`` set -> ``fit_grouped``; else ``fit``.  Fit statements
    never fuse with one another — each owns its driver loop — but they
    share partitioning sorts with grouped scans through the same
    ``group_by`` memo.
    """

    task: IterativeTask
    table: Table | None = None
    blocks: Callable[[], Iterable[Columns]] | None = None
    group_col: str | None = None
    num_groups: int | None = None
    max_iters: int = 100
    tol: float | None = 1e-6
    engine: str = "auto"         # fit(): "auto" | "local" | "sharded"
    mode: str = "compiled"
    layout: str = "auto"         # fit_grouped(): "auto"|"segment"|"masked"
    block_size: int | None = None
    mask: Any = None
    warm_start: Any = None
    mesh: Any = None
    row_axes: Any = None
    jit: bool = True
    label: str | None = None


@dataclasses.dataclass(eq=False)
class StreamAgg:
    """One-pass aggregate over an out-of-core block stream.

    ``blocks`` is an iterable of column dicts or a zero-arg factory.
    Statements sharing the same ``blocks`` object MUST fuse (the planner
    does): a shared iterator can only be consumed once.
    """

    agg: Aggregate
    blocks: Any
    columns: Any = None          # Projection
    label: str | None = None


Node = ("ScanAgg | GroupedScanAgg | JoinedGroupedScanAgg | IterativeFit"
        " | StreamAgg")


# ---------------------------------------------------------------------------
# Projection adapter — a member sees exactly its statement's columns.
# ---------------------------------------------------------------------------

def _normalize_projection(columns) -> dict[str, str] | None:
    if columns is None:
        return None
    if isinstance(columns, Mapping):
        return dict(columns)
    return {name: name for name in columns}


class _Projected(Aggregate):
    """Feed a fused member only its statement's (possibly renamed)
    columns.  All merge/final behavior delegates to the wrapped
    aggregate, so fusion stays a pure scan-sharing transform."""

    merge_ops = None  # never consulted: every path below delegates

    def __init__(self, agg: Aggregate, columns):
        self.agg = agg
        self.projection = _normalize_projection(columns)

    def _project(self, block):
        if self.projection is None:
            return block
        return {tgt: block[src] for tgt, src in self.projection.items()}

    def init(self, block):
        return self.agg.init(self._project(block))

    def transition(self, state, block, mask):
        return self.agg.transition(state, self._project(block), mask)

    def merge(self, a, b):
        return self.agg.merge(a, b)

    def mesh_merge(self, state, axes):
        return self.agg.mesh_merge(state, axes)

    def segment_ops(self, state):
        return self.agg.segment_ops(state)

    def final(self, state):
        return self.agg.final(state)

    # Kernel hook + calibration class forward to the wrapped aggregate,
    # so projection never hides the grouped fast path or the planner's
    # cost bucket; segment_kernel_args applies this member's projection,
    # so the kernel reads the statement's (possibly renamed) columns.
    @property
    def segment_kernel(self):
        return self.agg.segment_kernel

    @property
    def kernel_impl(self):
        return self.agg.kernel_impl

    @property
    def cost_class(self):
        return self.agg.cost_class

    def segment_kernel_args(self, columns, valid, block_gids, num_groups):
        return self.agg.segment_kernel_args(self._project(columns), valid,
                                            block_gids, num_groups)


# Wrapper memo: planning the same statement again (a bench rep, a
# repeated prepared batch) must yield the SAME projected-aggregate
# object, so run_many's fused cache — and through it the local engine's
# program cache — hits instead of recompiling.  Entries pin their
# wrapped aggregates, so live keys can't collide.  Bounded FIFO.
_PROJECTED_CACHE: dict[tuple, "_Projected"] = {}
_PROJECTED_CACHE_MAX = 512


def _member_agg(node) -> Aggregate:
    columns = getattr(node, "columns", None)
    if columns is None:
        return node.agg
    proj = _normalize_projection(columns)
    key = (id(node.agg), tuple(sorted(proj.items())))
    hit = _PROJECTED_CACHE.get(key)
    if hit is not None and hit.agg is node.agg:
        return hit
    wrapped = _Projected(node.agg, proj)
    if len(_PROJECTED_CACHE) >= _PROJECTED_CACHE_MAX:
        _PROJECTED_CACHE.pop(next(iter(_PROJECTED_CACHE)))
    _PROJECTED_CACHE[key] = wrapped
    return wrapped


# ---------------------------------------------------------------------------
# Cost model — the ranking behind engine selection.  With an ACTIVE
# measured calibration (see repro.core.calibration) candidates rank by
# interpolated measured seconds; otherwise by the documented rows-moved
# heuristics below, exactly as in the PR-5 planner.
# ---------------------------------------------------------------------------

_HEURISTIC = {"kind": "heuristic"}


def _agg_cost_class(aggs) -> str:
    """Calibration bucket of a (possibly fused) pass: the members' shared
    ``cost_class`` when they agree, else the generic tables."""
    classes = {getattr(a, "cost_class", "generic") for a in aggs}
    return classes.pop() if len(classes) == 1 else "generic"


def _measured_costs(cand_keys: Mapping[str, str], agg_cls: str, rows: int,
                    groups: int | None = None):
    """``(costs_in_seconds, source)`` from the active calibration, or
    None unless EVERY candidate is covered — measured seconds must never
    rank against heuristic row counts in one comparison."""
    cal = _calibration.current()
    if cal is None:
        return None
    costs = {}
    for cand, key in cand_keys.items():
        s = cal.engine_seconds(key, agg_cls, rows, groups)
        if s is None:
            return None
        costs[cand] = s
    return costs, {"kind": "measured", "backend": cal.backend,
                   "timestamp": cal.timestamp}


def _mesh_segments(mesh, row_axes) -> int:
    if mesh is None:
        return 1
    axes = tuple(row_axes or ("data",))
    return int(np.prod([mesh.shape[a] for a in axes]))


def scan_cost(engine: str, rows: int, segs: int = 1) -> float:
    """Estimated rows-moved cost of a one-pass scan.

    ``local`` folds every row in one program; a distributed table pays a
    gather first when it spans more than one segment.  ``sharded`` is the
    two-phase pattern: each segment folds its chunk, plus one merge
    collective per segment.  At ``segs == 1`` the tie breaks to local
    (the merge term), which is also the numerically identical choice.
    """
    if engine == "local":
        return float(rows) * (2.0 if segs > 1 else 1.0)
    if engine == "sharded":
        return math.ceil(rows / segs) + segs
    raise ValueError(f"scan_cost: unknown engine {engine!r}")


def grouped_cost(method: str, rows: int, groups: int, block: int,
                 segs: int = 1) -> float:
    """Estimated cost of a grouped pass: the segment layout scans the
    group-aligned blocks once (padding bounded by one partial block per
    group); the masked fallback scans the full table once per group."""
    if method == "segment":
        base = rows + groups * block
    elif method == "masked":
        base = rows * groups
    else:
        raise ValueError(f"grouped_cost: unknown method {method!r}")
    if segs > 1:  # chunked across segments + G partials per-leaf collective
        return math.ceil(base / segs) + groups * segs
    return float(base)


def join_cost(strategy: str, fact_rows: int, dim_rows: int) -> float:
    """Estimated rows-moved cost of resolving ``fact ⋈ dim`` (on top of
    the grouped pass that consumes it).

    ``sort-share`` is the planned strategy: one argsort of the dimension
    key (amortized to its row count — and FREE when a GROUP BY already
    paid it, via the ``sort_permutation`` memo) plus one searchsorted
    gather producing a single int32 gid column over the fact rows.
    ``gather-materialize`` is the naive alternative it is priced
    against: gather the dimension attribute onto every fact row AND
    write a fresh joined copy of the fact columns — 2x the fact's rows
    moved, plus the same dimension sort, with no sort/scan sharing
    downstream (every statement re-pays it)."""
    if strategy == "sort-share":
        return float(fact_rows + dim_rows)
    if strategy == "gather-materialize":
        return float(2 * fact_rows + dim_rows)
    raise ValueError(f"join_cost: unknown strategy {strategy!r}")


def _capable(engine: str, *, mask: bool = False, group_by: bool = False,
             stream: bool = False) -> bool:
    """Capability-matrix filter: can ``engine`` honor what the statement
    needs?  (``sharded-grouped[segment]`` looks up ``sharded-grouped``.)"""
    caps = ENGINE_CAPS[engine.split("[")[0]]
    return ((not mask or caps["mask"])
            and (not group_by or caps["group_by"])
            and (not stream or caps["stream"]))


def select_scan_engine(rows: int, mesh=None, row_axes=None, *,
                       mask: bool = False, forced: str = "auto",
                       agg_cls: str = "generic"
                       ) -> tuple[str, dict[str, float], dict]:
    """Pick local vs sharded for a one-pass scan: candidates filtered
    through :data:`ENGINE_CAPS` by what the statement needs (``mask``),
    ranked by measured seconds when an active calibration covers every
    candidate (``agg_cls`` selects its bucket), else by the heuristic
    cost model.  Returns ``(engine, candidate_costs, cost_source)``."""
    segs = _mesh_segments(mesh, row_axes)
    candidates = ["local"] + (["sharded"] if mesh is not None else [])
    costs = {e: scan_cost(e, rows, segs) for e in candidates
             if _capable(e, mask=mask)}
    source = _HEURISTIC
    measured = _measured_costs({e: e for e in costs}, agg_cls, rows)
    if measured is not None:
        costs, source = measured
    if forced != "auto":
        if forced not in ("local", "sharded"):
            raise ValueError(f"unknown scan engine {forced!r}")
        if forced == "sharded" and mesh is None:
            forced = "local"  # graceful degrade, like run_sharded itself
        return forced, costs, source
    return min(costs, key=lambda e: costs[e]), costs, source


def select_grouped_method(rows: int, groups: int, *, segment_ok: bool,
                          block_size: int | None = None, segs: int = 1,
                          mask: bool = False, forced: str = "auto",
                          agg_cls: str = "generic"
                          ) -> tuple[str, dict[str, float], dict]:
    """Pick segment vs masked for a grouped pass: both candidates must
    clear the capability matrix (group_by + the statement's mask need);
    the generic-merge fallback (``segment_ok=False``) removes the
    segment candidate.  Ranking prefers measured seconds (calibration
    keys ``[sharded-]grouped-<method>``) when available, like
    :func:`select_scan_engine`."""
    bs = segment_block_size(rows, groups, block_size)
    costs = {}
    for method in (("segment",) if segment_ok else ()) + ("masked",):
        if _capable(f"grouped-{method}", mask=mask, group_by=True):
            costs[method] = grouped_cost(method, rows, groups, bs, segs)
    source = _HEURISTIC
    prefix = "sharded-grouped-" if segs > 1 else "grouped-"
    measured = _measured_costs({m: prefix + m for m in costs}, agg_cls,
                               rows, groups)
    if measured is not None:
        costs, source = measured
    if forced != "auto":
        if forced == "segment" and not segment_ok:
            raise ValueError(
                "method='segment' forced on a generic-merge aggregate "
                "(agg.segment_ops() is None); use 'masked'")
        if forced not in ("segment", "masked"):
            raise ValueError(f"unknown grouped method {forced!r}")
        return forced, costs, source
    return min(costs, key=lambda m: costs[m]), costs, source


# ---------------------------------------------------------------------------
# Physical passes.
# ---------------------------------------------------------------------------

def _mask_key(mask) -> Any:
    """Fusion identity of a base mask.  Masks are compared by object
    identity — two equal-content arrays planned apart stay apart (safe:
    never fuses statements whose filters could differ)."""
    return None if mask is None else id(mask)


def node_tables(node) -> tuple[Table, ...]:
    """Every base :class:`Table` a statement READS — the structural
    multi-table check behind the result cache's single-table contract.
    A join reads two (fact first — the admission/windowing table); a
    prebuilt GroupedView resolves to its data table; streams read none.
    Any future multi-table node must surface all of its tables here, so
    the cache rejection in :func:`semantic_fingerprint` is inherited
    instead of re-discovered."""
    join = getattr(node, "join", None)
    if join is not None:
        return (join.fact, join.dim)
    t = getattr(node, "table", None)
    if isinstance(t, GroupedView):
        t = t.table
    return (t,) if isinstance(t, Table) else ()


def statement_fingerprint(node) -> tuple:
    """Stable identity of a retained statement's physical shape — what a
    :class:`~repro.core.materialize.MaterializedHandle` pins alongside
    the table version.  Two statements share a fingerprint iff refreshing
    one's retained state is valid for the other: same aggregate instance,
    projection, grouping, partitioning and engine knobs.  The table is
    deliberately NOT part of the fingerprint — the handle pins the table
    object itself and tracks its version separately."""
    proj = _normalize_projection(getattr(node, "columns", None))
    proj_key = None if proj is None else tuple(sorted(proj.items()))
    if isinstance(node, ScanAgg):
        return ("scan", id(node.agg), proj_key, _mask_key(node.mask),
                node.block_size, node.engine, node.jit)
    if isinstance(node, GroupedScanAgg):
        return ("grouped", id(node.agg), proj_key, node.group_col,
                node.num_groups, _mask_key(node.mask), node.block_size,
                node.method,
                id(node.mesh) if node.mesh is not None else None,
                tuple(node.row_axes) if node.row_axes else None, node.jit)
    raise TypeError(f"statement_fingerprint: not a retainable scan "
                    f"statement: {node!r}")


def semantic_fingerprint(node) -> tuple | None:
    """Cross-submitter identity of a statement's RESULT — the analytics
    server's cache key component (:mod:`repro.core.server`).

    Unlike :func:`statement_fingerprint` (which keys on aggregate object
    *identity* — right for a retained handle that owns its instances),
    this keys on the aggregate's :meth:`~Aggregate.cache_key`, so the
    same logical statement issued by two different sessions — each with
    its own freshly constructed aggregate — maps to ONE fingerprint.  Two
    statements share a semantic fingerprint iff executing either against
    the same (table id, table version) yields identical finalized
    results: same aggregate semantics, projection, grouping, block
    partitioning and engine knobs.  The table itself is NOT part of the
    fingerprint; the server keys its cache by
    ``(table id, table version, fingerprint)``.

    Returns ``None`` — never cache, always execute — when the statement
    cannot be identified semantically: an aggregate without a
    ``cache_key``, a masked statement (masks are session-local arrays,
    identity-keyed), a prebuilt :class:`GroupedView` (a snapshot with no
    version to track), a non-scan statement (fits and streams hold no
    cacheable table-version-addressed result), or — checked structurally
    via :func:`node_tables`, so future multi-table nodes inherit it — a
    statement reading MORE THAN ONE table.  The single-table restriction
    is a correctness wall, not a limitation to lift casually: the
    fingerprint is computed at SUBMIT time while the server probes its
    cache at DRAIN time against the base table's current version only,
    so version-keying a join on both tables at submit could still serve
    a result after the dimension alone mutated in between.  The refusal
    records a loud ``kind="cache_reject"`` trace event per statement
    (joined statements still execute — windowed by their fact table —
    they are just never cached or deduplicated).
    """
    tables = node_tables(node)
    if len(tables) > 1:
        _record("cache_reject", reason="multi-table",
                node=type(node).__name__,
                tables=tuple(id(t) for t in tables))
        return None
    if not isinstance(node, (ScanAgg, GroupedScanAgg)):
        return None
    agg_key = node.agg.cache_key()
    if agg_key is None or node.mask is not None:
        return None
    proj = _normalize_projection(node.columns)
    proj_key = None if proj is None else tuple(sorted(proj.items()))
    if isinstance(node, ScanAgg):
        return ("scan", agg_key, proj_key, node.block_size, node.engine,
                node.jit)
    if isinstance(node.table, GroupedView):
        return None
    return ("grouped", agg_key, proj_key, node.group_col, node.num_groups,
            node.block_size, node.method,
            id(node.mesh) if node.mesh is not None else None,
            tuple(node.row_axes) if node.row_axes else None, node.jit)


@dataclasses.dataclass
class PhysicalPass:
    """One physical engine execution covering >= 1 statements."""

    kind: str                       # "scan" | "grouped" | "fit" | "stream"
    engine: str
    members: list                   # [(statement index, node), ...]
    cost: float | None
    info: dict                      # rendering details (explain)
    run: Callable[[], dict]         # -> {statement index: result}


def fused_scan_pass(members: Sequence[tuple[int, ScanAgg]], *,
                    engine: str = "auto") -> PhysicalPass:
    """Build ONE shared-scan pass from compatible ScanAgg statements.

    This is the loud guard of the mixed-mask correctness trap: a fused
    ``run_many`` applies one base mask to every member, so members whose
    table, mask or block partitioning differ are rejected with an error —
    never silently folded together.
    """
    nodes = [n for _, n in members]
    base = nodes[0]
    if any(n.table is not base.table for n in nodes):
        raise ValueError(
            "fused_scan_pass: statements scan different tables — "
            "cross-table fusion is not a shared scan")
    masks = {_mask_key(n.mask) for n in nodes}
    if len(masks) > 1:
        raise ValueError(
            "fused_scan_pass: mixed-mask fusion rejected — run_many "
            "applies ONE base mask to every fused aggregate, so fusing "
            "statements with different mask= would silently apply one "
            "statement's filter to the others; plan them as separate "
            "passes")
    if len({n.block_size for n in nodes}) > 1:
        raise ValueError(
            "fused_scan_pass: members use different block_size values — "
            "fusing them would change their fold partitioning (and "
            "bit-exactness) vs solo execution")
    if len({n.jit for n in nodes}) > 1:
        raise ValueError("fused_scan_pass: members disagree on jit=")

    rows = base.table.n_rows
    idx = [i for i, _ in members]
    aggs = [_member_agg(n) for n in nodes]
    eng, costs, source = select_scan_engine(
        rows, base.table.mesh, base.table.row_axes,
        mask=base.mask is not None,
        forced=base.engine if engine == "auto" else engine,
        agg_cls=_agg_cost_class(aggs))

    def run():
        out = run_many(aggs, base.table, block_size=base.block_size,
                       mask=base.mask, jit=base.jit, engine=eng)
        return dict(zip(idx, out))

    return PhysicalPass(
        kind="scan", engine=eng, members=list(members),
        cost=costs[eng],
        info={"table": base.table, "rows": rows, "mask": base.mask,
              "block_size": base.block_size, "costs": costs,
              "cost_source": source},
        run=run)


def _grouped_view(node) -> GroupedView:
    if isinstance(node.table, GroupedView):
        return node.table
    if node.group_col is None:
        raise ValueError("GroupedScanAgg needs group_col (or a "
                         "prebuilt GroupedView)")
    return node.table.group_by(node.group_col, node.num_groups)


def _resolve_groups(node) -> int:
    if isinstance(node.table, GroupedView):
        return node.table.num_groups
    if node.num_groups is not None:
        return int(node.num_groups)
    # re-planning the same statement (explain + run, bench reps): reuse
    # the memoized view's count instead of re-reducing the id column.
    # Goes through the version-checked accessor, so a view outdated by
    # Table.append / invalidate can never leak into the plan — appended
    # rows may introduce new group ids.
    view = node.table.cached_group_by(node.group_col, None)
    if view is not None:
        return view.num_groups
    gids = node.table[node.group_col].astype(jnp.int32)
    return int(jax.device_get(jnp.max(gids))) + 1


def fused_grouped_pass(members: Sequence[tuple[int, GroupedScanAgg]]
                       ) -> PhysicalPass:
    """ONE grouped pass (one sort, one partitioned scan) for compatible
    grouped statements.  Same loud-rejection contract as
    :func:`fused_scan_pass`."""
    nodes = [n for _, n in members]
    base = nodes[0]
    if any(n.table is not base.table for n in nodes):
        raise ValueError("fused_grouped_pass: statements group different "
                         "tables/views")
    if any(n.group_col != base.group_col for n in nodes):
        raise ValueError("fused_grouped_pass: statements group by "
                         "different key columns")
    if len({_mask_key(n.mask) for n in nodes}) > 1:
        raise ValueError(
            "fused_grouped_pass: mixed-mask fusion rejected — one base "
            "mask applies to every fused grouped aggregate")
    if len({(n.num_groups, n.block_size, n.method, id(n.mesh), n.jit)
            for n in nodes}) > 1:
        raise ValueError("fused_grouped_pass: members disagree on "
                         "num_groups/block_size/method/mesh/jit")

    base_tbl = base.table.table if isinstance(base.table, GroupedView) \
        else base.table
    mesh = base.mesh if base.mesh is not None else base_tbl.mesh
    segs = _mesh_segments(mesh, base.row_axes or base_tbl.row_axes)
    groups = _resolve_groups(base)
    rows = base.table.n_rows

    # A fused grouped pass takes the segment path only when EVERY member
    # is segment-reducible (one generic-merge member poisons the fused
    # state, exactly as FusedAggregate.segment_ops declares).
    data_cols = dict(base_tbl.columns)
    data_cols.pop(base.group_col, None)
    member_aggs = [_member_agg(n) for n in nodes]
    segment_ok = True
    for a in member_aggs:
        try:
            ok = probe_segment_ops(a, data_cols) is not None
        except Exception:
            ok = False
        segment_ok = segment_ok and ok
    method, costs, source = select_grouped_method(
        rows, groups, segment_ok=segment_ok, block_size=base.block_size,
        segs=segs, mask=base.mask is not None, forced=base.method,
        agg_cls=_agg_cost_class(member_aggs))

    engine = ("sharded-grouped[%s]" % method) if mesh is not None \
        else f"grouped-{method}"
    idx = [i for i, _ in members]
    projections = [_normalize_projection(n.columns) for n in nodes]

    def run():
        view = _grouped_view(base)
        if all(p is not None for p in projections):
            union = sorted({src for p in projections for src in p.values()})
            view = view.select(*union)
        fused = _fused_for(member_aggs)
        out = run_grouped(fused, view, block_size=base.block_size,
                          mask=base.mask, method=method, mesh=base.mesh,
                          row_axes=base.row_axes, jit=base.jit)
        return dict(zip(idx, out))

    return PhysicalPass(
        kind="grouped", engine=engine, members=list(members),
        cost=costs[method],
        info={"table": base_tbl, "group_col": base.group_col,
              "groups": groups, "rows": rows, "mask": base.mask,
              "costs": costs, "cost_source": source,
              "view_key": (id(base_tbl), base.group_col)},
        run=run)


def fused_join_pass(members: Sequence[tuple[int, "JoinedGroupedScanAgg"]]
                    ) -> PhysicalPass:
    """ONE joined-grouped pass — shared sort-merge key resolution + one
    partitioned segment scan — for compatible joined statements (the
    planner's first multi-table fusion).  Same loud-rejection contract
    as :func:`fused_grouped_pass`; join compatibility means the SAME
    (fact, dim, fact_key, dim_key, attr, on_missing) spec, compared by
    table identity like every fusion key."""
    nodes = [n for _, n in members]
    base = nodes[0]
    j = base.join
    if any(n.join.spec_key() != j.spec_key() for n in nodes):
        raise ValueError(
            "fused_join_pass: statements join different (fact, dim, key, "
            "attr) triples — cross-join fusion would mix unrelated "
            "group-id columns")
    if len({_mask_key(n.mask) for n in nodes}) > 1:
        raise ValueError(
            "fused_join_pass: mixed-mask fusion rejected — one base mask "
            "applies to every fused joined aggregate")
    if len({(n.num_groups, n.block_size, n.method, id(n.mesh), n.jit)
            for n in nodes}) > 1:
        raise ValueError("fused_join_pass: members disagree on "
                         "num_groups/block_size/method/mesh/jit")

    mesh = base.mesh if base.mesh is not None else j.fact.mesh
    segs = _mesh_segments(mesh, base.row_axes or j.fact.row_axes)
    groups = int(base.num_groups) if base.num_groups is not None \
        else j.attr_groups()
    rows = j.fact.n_rows

    # Segment reducibility is probed on the FACT's columns — the joined
    # table is exactly them plus the (stripped-at-group_by) gid column.
    member_aggs = [_member_agg(n) for n in nodes]
    segment_ok = True
    for a in member_aggs:
        try:
            ok = probe_segment_ops(a, dict(j.fact.columns)) is not None
        except Exception:
            ok = False
        segment_ok = segment_ok and ok
    method, costs, source = select_grouped_method(
        rows, groups, segment_ok=segment_ok, block_size=base.block_size,
        segs=segs, mask=base.mask is not None, forced=base.method,
        agg_cls=_agg_cost_class(member_aggs))

    join_costs = {s: join_cost(s, rows, j.dim.n_rows)
                  for s in ("sort-share", "gather-materialize")}
    # candidate costs include the key-resolution term, so the pass cost
    # equals its chosen candidate and explain's rejected-list stays honest
    costs = {m: c + join_costs["sort-share"] for m, c in costs.items()}
    engine = ("sharded-grouped[%s]" % method) if mesh is not None \
        else f"grouped-{method}"
    idx = [i for i, _ in members]
    projections = [_normalize_projection(n.columns) for n in nodes]

    def run():
        res = j.resolve()
        view = res.table.group_by(res.gid_col, groups)
        if all(p is not None for p in projections):
            union = sorted({src for p in projections for src in p.values()})
            view = view.select(*union)
        fused = _fused_for(member_aggs)
        out = run_grouped(fused, view, block_size=base.block_size,
                          mask=base.mask, method=method, mesh=base.mesh,
                          row_axes=base.row_axes, jit=base.jit)
        return dict(zip(idx, out))

    return PhysicalPass(
        kind="join", engine=engine, members=list(members),
        cost=costs[method],
        info={"table": j.fact, "group_col": j.attr_col, "groups": groups,
              "rows": rows, "mask": base.mask, "costs": costs,
              "cost_source": source,
              "join": {"dim": j.dim, "on": f"{j.fact_key}={j.dim_key}",
                       "on_missing": j.on_missing, "costs": join_costs},
              # one logical partitioning sort per star triple: joined
              # passes over the same spec share it (and explain counts
              # it once), exactly like grouped passes share a view_key
              "view_key": ("join",) + j.spec_key()},
        run=run)


def _fit_pass(index: int, node: IterativeFit) -> PhysicalPass:
    run_layout = node.layout  # what run() hands to fit_grouped
    if node.blocks is not None:
        engine, info = "stream", {}
    elif node.group_col is not None:
        layout = node.layout
        if layout == "auto":
            # Resolve the grouped layout once, at plan time (EXPLAIN
            # consults the task the way a DB consults statistics) and
            # hand the decision to fit_grouped so execution doesn't
            # re-probe.  A failing probe stays "auto": the plan renders
            # the layout as undecided and execution surfaces the real
            # error from fit_grouped instead of a masked mislabel.
            cols = {k: v for k, v in node.table.columns.items()
                    if k != node.group_col}
            try:
                s0 = jax.tree.map(jnp.asarray, node.task.init_state(cols))
                states0 = jax.tree.map(
                    lambda x: jnp.broadcast_to(x[None], (1,) + x.shape), s0)
                layout = "segment" if _segment_task_ok(
                    node.task, states0, cols) else "masked"
                run_layout = layout
            except Exception:
                layout = "auto"
        mesh = node.mesh if node.mesh is not None else node.table.mesh
        engine = ("sharded-grouped[%s]" % layout) if mesh is not None \
            else f"grouped-{layout}"
        info = {"table": node.table, "group_col": node.group_col,
                "groups": _resolve_groups(node),
                "view_key": (id(node.table), node.group_col)
                if layout == "segment" else None}
    else:
        mesh = node.mesh if node.mesh is not None else node.table.mesh
        engine = node.engine
        if engine == "auto":
            engine = "sharded" if mesh is not None else "local"
        info = {"table": node.table}

    rows = None if node.table is None else node.table.n_rows
    cost = None if rows is None else node.max_iters * float(rows)

    def run():
        if node.blocks is not None:
            res = fit_stream(node.task, node.blocks,
                             max_iters=node.max_iters, tol=node.tol,
                             warm_start=node.warm_start)
        elif node.group_col is not None:
            res = fit_grouped(node.task, node.table, node.group_col,
                              node.num_groups, max_iters=node.max_iters,
                              tol=node.tol, block_size=node.block_size,
                              mask=node.mask, warm_start=node.warm_start,
                              layout=run_layout, mesh=node.mesh,
                              row_axes=node.row_axes, jit=node.jit)
        else:
            res = fit(node.task, node.table, max_iters=node.max_iters,
                      tol=node.tol, engine=node.engine, mode=node.mode,
                      block_size=node.block_size, mask=node.mask,
                      warm_start=node.warm_start, mesh=node.mesh,
                      row_axes=node.row_axes, jit=node.jit)
        return {index: res}

    return PhysicalPass(
        kind="fit", engine=engine, members=[(index, node)], cost=cost,
        info=dict(info, rows=rows, max_iters=node.max_iters, tol=node.tol,
                  cost_source=_HEURISTIC),
        run=run)


def fused_stream_pass(members: Sequence[tuple[int, StreamAgg]]
                      ) -> PhysicalPass:
    nodes = [n for _, n in members]
    base = nodes[0]
    if any(n.blocks is not base.blocks for n in nodes):
        raise ValueError("fused_stream_pass: statements fold different "
                         "block streams")
    idx = [i for i, _ in members]

    def run():
        blocks = base.blocks() if callable(base.blocks) else base.blocks
        out = run_stream(_fused_for([_member_agg(n) for n in nodes]),
                         blocks)
        return dict(zip(idx, out))

    return PhysicalPass(kind="stream", engine="stream",
                        members=list(members), cost=None, info={}, run=run)


# ---------------------------------------------------------------------------
# The planner.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PhysicalPlan:
    passes: list[PhysicalPass]
    n_statements: int

    def execute(self) -> list:
        """Run every pass; results come back in statement order."""
        out: dict[int, Any] = {}
        for p in self.passes:
            out.update(p.run())
        return [out[i] for i in range(self.n_statements)]

    # -- EXPLAIN ----------------------------------------------------------
    def explain(self) -> str:
        tables: dict[int, str] = {}

        def tname(tbl) -> str:
            if tbl is None:
                return "-"
            return tables.setdefault(id(tbl), f"t{len(tables)}")

        # label tables in statement order for stable goldens (a join
        # pass names its dimension right after its fact)
        for p in self.passes:
            tname(p.info.get("table"))
            join = p.info.get("join")
            if join is not None:
                tname(join["dim"])

        shared_sorts = {}
        for p in self.passes:
            vk = p.info.get("view_key")
            if vk is not None:
                shared_sorts.setdefault(vk, []).append(p)
        n_sorts = len(shared_sorts)

        lines = [f"plan: {self.n_statements} statement"
                 f"{'s' if self.n_statements != 1 else ''} -> "
                 f"{len(self.passes)} pass"
                 f"{'es' if len(self.passes) != 1 else ''}"
                 + (f", {n_sorts} sort{'s' if n_sorts != 1 else ''}"
                    if n_sorts else "")]
        sort_ids = {vk: f"v{i}" for i, vk in enumerate(shared_sorts)}
        for k, p in enumerate(self.passes):
            info = p.info
            bits = [f"pass {k}: {_KIND_NAMES[p.kind]} [{p.engine}]"]
            if info.get("table") is not None:
                bits.append(tname(info["table"]))
            join = info.get("join")
            if join is not None:
                bits.append(f"JOIN {tname(join['dim'])} "
                            f"on {join['on']}"
                            + (f" on_missing={join['on_missing']}"
                               if join["on_missing"] != "error" else ""))
            if info.get("group_col"):
                bits.append(f"by {info['group_col']} "
                            f"groups={info['groups']}")
                vk = info.get("view_key")
                if vk is not None:
                    shared = len(shared_sorts[vk]) > 1
                    bits.append(f"sort={sort_ids[vk]}"
                                + ("(shared)" if shared else ""))
            if info.get("rows") is not None:
                bits.append(f"rows={info['rows']}")
            if p.kind == "fit":
                tol = info.get("tol")
                bits.append(f"max_iters={info['max_iters']} "
                            f"tol={'none' if tol is None else f'{tol:g}'}")
            if info.get("mask") is not None:
                bits.append("mask=yes")
            if info.get("block_size") is not None:
                bits.append(f"block={info['block_size']}")
            if p.cost is not None:
                src = info.get("cost_source") or _HEURISTIC
                measured = src.get("kind") == "measured"
                rejected = {e: c for e, c in info.get("costs", {}).items()
                            if c != p.cost}
                bits.append(f"cost={_fmt_cost(p.cost, measured)}")
                bits.append(f"[measured {src['backend']}@{src['timestamp']}]"
                            if measured else "[heuristic]")
                if rejected:
                    bits.append("(rejected: " + " ".join(
                        f"{e}={_fmt_cost(c, measured)}" for e, c in sorted(
                            rejected.items())) + ")")
                if join is not None:
                    jc = join["costs"]
                    bits.append(
                        "(join: sort-share="
                        f"{_fmt_cost(jc['sort-share'], False)} rejected "
                        "gather-materialize="
                        f"{_fmt_cost(jc['gather-materialize'], False)})")
            lines.append("  " + " ".join(bits))
            for i, n in p.members:
                label = n.label or f"s{i}"
                lines.append(f"    {label}: {type(n.agg).__name__}"
                             if hasattr(n, "agg") else
                             f"    {label}: {type(n.task).__name__}")
        return "\n".join(lines)


_KIND_NAMES = {"scan": "shared-scan", "grouped": "grouped-scan",
               "join": "join-grouped-scan", "fit": "fit",
               "stream": "stream-scan"}


def _fmt_cost(c: float, measured: bool) -> str:
    """Heuristic costs are dimensionless row counts (integers); measured
    costs are seconds and render with a unit."""
    if not measured:
        return str(int(c))
    return f"{c:.2f}s" if c >= 1.0 else f"{c * 1e3:.2f}ms"


def plan(statements: Sequence[Any]) -> PhysicalPlan:
    """Compile logical statements into a physical plan: fuse compatible
    scans, dedup sorts, select engines.  Pass order follows each pass's
    first statement; results are returned in statement order."""
    statements = list(statements)
    groups: dict[Any, list] = {}
    order: list[Any] = []
    for i, node in enumerate(statements):
        if isinstance(node, ScanAgg):
            key = ("scan", id(node.table), _mask_key(node.mask),
                   node.block_size, node.engine, node.jit)
        elif isinstance(node, GroupedScanAgg):
            key = ("grouped", id(node.table), node.group_col,
                   node.num_groups, _mask_key(node.mask), node.block_size,
                   node.method, id(node.mesh) if node.mesh is not None
                   else None, node.jit)
        elif isinstance(node, JoinedGroupedScanAgg):
            # multi-table fusion: keyed on the join SPEC (both tables by
            # identity + keys/attr/policy), so joined statements built
            # independently — even with distinct Join instances — fuse
            # into one shared-resolution pass
            key = (("join",) + node.join.spec_key()
                   + (node.num_groups, _mask_key(node.mask),
                      node.block_size, node.method,
                      id(node.mesh) if node.mesh is not None else None,
                      node.jit))
        elif isinstance(node, StreamAgg):
            key = ("stream", id(node.blocks))
        elif isinstance(node, IterativeFit):
            key = ("fit", i)  # fits never fuse
        else:
            raise TypeError(f"not a logical plan node: {node!r}")
        if key not in groups:
            order.append(key)
        groups.setdefault(key, []).append((i, node))

    passes = []
    for key in order:
        members = groups[key]
        kind = key[0]
        if kind == "scan":
            passes.append(fused_scan_pass(members))
        elif kind == "grouped":
            passes.append(fused_grouped_pass(members))
        elif kind == "join":
            passes.append(fused_join_pass(members))
        elif kind == "stream":
            passes.append(fused_stream_pass(members))
        else:
            (i, node), = members
            passes.append(_fit_pass(i, node))
    return PhysicalPlan(passes, len(statements))


def execute(node) -> Any:
    """Eagerly execute one logical statement through the planner — the
    single-statement path every method wrapper uses.  Engine selection
    (and the ``group_by`` sort memo) work exactly as in a batch."""
    return plan([node]).execute()[0]


def explain(statements) -> str:
    """``EXPLAIN`` for one statement or a batch — the physical plan the
    optimizer would run, without running it."""
    if not isinstance(statements, (list, tuple)):
        statements = [statements]
    return plan(statements).explain()
