"""User-defined aggregates — the core MADlib design pattern (§3.1.1, §4.1).

A MADlib method is, at its heart, a ``(init, transition, merge, final)``
quadruple.  The *transition* folds data into a running state, *merge*
combines states from parallel workers (associativity is the parallelization
contract), and *final* turns the merged state into the answer.

TPU adaptation (recorded in DESIGN.md §2): Greenplum feeds the transition
function one tuple at a time; a systolic array wants tiles.  Our transition
contract is **block-at-a-time** — it receives a block of rows ``(B, ...)``
plus a validity mask, so e.g. the OLS ``x xᵀ`` rank-1 update becomes a
``(k, B) @ (B, k)`` MXU matmul (the paper's own v0.3 Eigen lesson, §4.4).

Execution engines provided here:

* :func:`run_local`       — single-shard blocked fold (``lax.scan``).
* :func:`run_sharded`     — ``shard_map`` over the mesh's row axes; local
  fold then mesh-wide merge via ``psum``/``pmax``/``pmin`` (or an
  all-gather fold for non-arithmetic merges).  This is the Greenplum
  segment model, and the engine whose speedup the paper measures.
* :func:`run_stream`      — host-side streaming fold with donated device
  state (the out-of-core path; §2.1's "entire data sets" argument).
* :func:`run_grouped`     — GROUP BY execution (the paper's grouped
  linregr) on the partitioned grouped-scan core: rows are sorted into
  group-aligned blocks once and ALL groups fold in a single O(n) scan
  (:func:`segment_fold`), with a masked-vmap fallback for generic-merge
  aggregates.

Shared-scan composition: :class:`FusedAggregate` packs N heterogeneous
aggregates (each with its own merge combinators, including generic-merge)
into ONE state pytree, so any engine above executes all of them in a
single data pass — the paper's ``profile`` trick (§Table 1: every
column's statistics in one table scan) generalized to arbitrary UDA sets.
:func:`run_many` is the convenience front-end.

Multipass methods wrap these one-pass engines in the unified iterative
executor (:mod:`repro.core.iterative`), which re-executes an aggregate
per driver round under a compiled loop — see ``IterativeTask``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Generic, Iterable, Mapping, TypeVar

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..distributed.sharding import distribute_rows, row_pspec
from . import calibration as _calibration
from .compat import shard_map as _compat_shard_map
from .table import GroupedView, Table, Columns
from .trace import record as _record

S = TypeVar("S")  # transition state pytree
R = TypeVar("R")  # result pytree

# Merge combinators, per state leaf.  "sum" covers counts/moments/sketch
# counters; "max"/"min" cover extremes and bitwise-OR over {0,1} bitmaps
# (Flajolet-Martin); "generic" falls back to an all-gather fold using the
# aggregate's own ``merge``.
MERGE_SUM = "sum"
MERGE_MAX = "max"
MERGE_MIN = "min"


class Aggregate:
    """Base class for user-defined aggregates.

    Subclasses implement ``init``/``transition``/``final`` and declare
    ``merge_ops`` — either a single combinator string applied to every state
    leaf, or a pytree of strings matching the state structure.  Aggregates
    whose merge is not expressible leaf-wise override :meth:`merge` and set
    ``merge_ops = None``.
    """

    merge_ops: Any = MERGE_SUM

    # -- registered segment-fold kernel hook ---------------------------------
    # Aggregates with a hand-tiled grouped kernel name it here (a key in
    # kernels/registry.py, e.g. "segment_linregr"); ``kernel_impl`` is the
    # resolved dispatch policy from the method layer's ``use_kernel`` flag
    # (None = inline jnp segment fold, the default).  ``cost_class`` names
    # the calibration bucket the planner prices this aggregate under.
    segment_kernel: str | None = None
    kernel_impl: str | None = None
    cost_class: str = "generic"

    def segment_kernel_args(self, columns: Columns, valid, block_gids,
                            num_groups: int):
        """(args, kwargs) for this aggregate's registered segment kernel —
        pure extraction from the group-aligned layout, so it also runs on
        ``ShapeDtypeStruct`` columns for host-side resolution."""
        raise NotImplementedError

    def segment_kernel_fold(self, columns: Columns, valid, block_gids,
                            num_groups: int, impl: str):
        """Whole-fold (G, ...) state stack via the registered kernel
        (fold-from-zero; the caller merges with the per-group inits)."""
        from ..kernels import registry as _kernels
        args, kwargs = self.segment_kernel_args(columns, valid, block_gids,
                                                num_groups)
        return _kernels.dispatch(self.segment_kernel, *args, impl=impl,
                                 _record=False, **kwargs)

    # -- result-cache identity ----------------------------------------------
    def cache_key(self):
        """Semantic identity of this aggregate for cross-submitter result
        caching (the analytics server): a hashable value that is equal for
        two instances iff they compute the same function of their input —
        i.e. identical finalized results on identical rows.  ``None`` (the
        default) opts out: the statement always executes.  Aggregates
        whose behavior is fully determined by constructor parameters
        should return ``(class tag, *params)``; anything carrying arrays
        or closures in its configuration must stay ``None`` (array-valued
        params have no cheap hashable identity)."""
        return None

    # -- to implement --------------------------------------------------------
    def init(self, block: Columns) -> S:  # block may hold tracers; use shapes only
        raise NotImplementedError

    def transition(self, state: S, block: Columns, mask: jax.Array) -> S:
        raise NotImplementedError

    def final(self, state: S) -> R:
        return state

    # -- default leaf-wise merge ---------------------------------------------
    def merge(self, a: S, b: S) -> S:
        ops = self._merge_ops_tree(a)
        return jax.tree.map(_combine_leaf, ops, a, b)

    def _merge_ops_tree(self, state: S):
        if self.merge_ops is None:
            raise NotImplementedError("generic-merge aggregate must override merge()")
        if isinstance(self.merge_ops, str):
            return jax.tree.map(lambda _: self.merge_ops, state)
        return self.merge_ops

    def segment_ops(self, state: S):
        """Per-leaf merge-combinator tree for segment (scatter) reduction,
        or None when this aggregate is only mergeable through its generic
        ``merge`` and cannot take the partitioned grouped path.  Consult
        AFTER ``init`` has run — schema-templated aggregates (e.g.
        ``ProfileAggregate``) synthesize ``merge_ops`` there."""
        if self.merge_ops is None:
            return None
        return self._merge_ops_tree(state)

    # Mesh-wide merge inside shard_map.
    def mesh_merge(self, state: S, axes: tuple[str, ...]) -> S:
        if self.merge_ops is not None:
            ops = self._merge_ops_tree(state)
            return jax.tree.map(partial(_collective_leaf, axes=axes), ops, state)
        # Generic path: gather every shard's state and fold sequentially.
        return _all_gather_merge_fold(self.merge, state, axes)


def _all_gather_merge_fold(merge_fn, state, axes: tuple[str, ...]):
    """Generic cross-segment merge inside ``shard_map``: all-gather every
    segment's state pytree and fold them sequentially with ``merge_fn``."""
    gathered = jax.tree.map(
        lambda x: jax.lax.all_gather(x, axes, tiled=False), state
    )
    # leading axis length is the product of the gathered axes
    lead = jax.tree.leaves(gathered)[0].shape[0]
    first = jax.tree.map(lambda x: x[0], gathered)

    def body(i, acc):
        nxt = jax.tree.map(lambda x: x[i], gathered)
        return merge_fn(acc, nxt)

    return jax.lax.fori_loop(1, lead, body, first)


class FusedAggregate(Aggregate):
    """Shared-scan combinator: N aggregates, ONE data pass.

    The fused state is a tuple of the member states; ``transition`` feeds
    the same block/mask to every member, so the engines above fold all of
    them in a single ``lax.scan`` / one ``shard_map`` round instead of N
    table scans.  ``merge``/``mesh_merge`` delegate member-wise, which
    preserves each member's own combinators — sum-merge, min/max-merge and
    generic (all-gather fold) members co-exist in one fused pass.

    ``aggs`` may be a sequence (results come back as a tuple) or a mapping
    (results come back as a dict keyed the same way).
    """

    merge_ops = None  # member-wise delegation; never consulted

    def __init__(self, aggs):
        if isinstance(aggs, Mapping):
            self.names: tuple[str, ...] | None = tuple(aggs)
            self.aggs: tuple[Aggregate, ...] = tuple(aggs[k] for k in self.names)
        else:
            self.names = None
            self.aggs = tuple(aggs)
        if not self.aggs:
            raise ValueError("FusedAggregate needs at least one aggregate")

    def init(self, block):
        return tuple(a.init(block) for a in self.aggs)

    def transition(self, state, block, mask):
        return tuple(a.transition(s, block, mask)
                     for a, s in zip(self.aggs, state))

    def merge(self, a, b):
        return tuple(agg.merge(sa, sb)
                     for agg, sa, sb in zip(self.aggs, a, b))

    def mesh_merge(self, state, axes):
        return tuple(a.mesh_merge(s, axes)
                     for a, s in zip(self.aggs, state))

    def segment_ops(self, state):
        ops = tuple(a.segment_ops(s) for a, s in zip(self.aggs, state))
        if any(o is None for o in ops):
            return None  # one generic-merge member poisons the fused pass
        return ops

    # A single-member fusion (what the plan layer builds for a lone
    # grouped statement) forwards its member's kernel hook, so the fused
    # wrapper doesn't hide the fast path.  Multi-member fusions fold
    # heterogeneous states in one scan — no single kernel covers them.
    @property
    def segment_kernel(self):
        return self.aggs[0].segment_kernel if len(self.aggs) == 1 else None

    @property
    def kernel_impl(self):
        return self.aggs[0].kernel_impl if len(self.aggs) == 1 else None

    @property
    def cost_class(self):
        return self.aggs[0].cost_class if len(self.aggs) == 1 else "generic"

    def segment_kernel_args(self, columns, valid, block_gids, num_groups):
        return self.aggs[0].segment_kernel_args(columns, valid, block_gids,
                                                num_groups)

    def segment_kernel_fold(self, columns, valid, block_gids, num_groups,
                            impl):
        return (self.aggs[0].segment_kernel_fold(
            columns, valid, block_gids, num_groups, impl),)

    def final(self, state):
        outs = tuple(a.final(s) for a, s in zip(self.aggs, state))
        if self.names is not None:
            return dict(zip(self.names, outs))
        return outs


def run_many(aggs, table: Table, *, block_size: int | None = None,
             mask: jax.Array | None = None, jit: bool = True,
             engine: str = "auto", finalize: bool = True,
             trace_kind: str = "scan") -> Any:
    """Execute several aggregates over ``table`` in ONE shared scan.

    ``engine="auto"`` picks the sharded engine when the table is
    distributed, the local one otherwise; ``"local"``/``"sharded"`` force
    one — the hook the plan layer's cost-based selection drives (its
    choice must be what executes, not re-derived here).  Returns a dict
    when ``aggs`` is a mapping, else a tuple, ordered like the input.

    ``finalize=False`` returns the raw fused fold state (a tuple of
    member states) instead of finalized results — the retained-state
    form materialized views pin and later merge with the members' own
    combinators (see :mod:`repro.core.materialize`).
    """
    fused = _fused_for(aggs)
    if engine == "auto":
        engine = "sharded" if table.mesh is not None else "local"
    if engine == "sharded":
        return run_sharded(fused, table, block_size=block_size, mask=mask,
                           jit=jit, finalize=finalize, trace_kind=trace_kind)
    if engine != "local":
        raise ValueError(f"unknown engine {engine!r} "
                         "(use 'auto', 'local' or 'sharded')")
    return run_local(fused, table, block_size=block_size, mask=mask, jit=jit,
                     finalize=finalize, trace_kind=trace_kind)


# Prepared-statement memo: re-executing the same aggregate set reuses
# ONE FusedAggregate instance, so the local engine's program cache
# (static on the aggregate) hits instead of recompiling per call.  Keys
# are member object ids; every entry pins its members, so a live entry's
# ids can never be reused by new objects.  Bounded FIFO.
_FUSED_CACHE: dict[tuple, FusedAggregate] = {}
_FUSED_CACHE_MAX = 256


def _fused_for(aggs) -> FusedAggregate:
    if isinstance(aggs, Mapping):
        key = tuple((k, id(a)) for k, a in aggs.items())
    else:
        key = tuple(id(a) for a in aggs)
    fused = _FUSED_CACHE.get(key)
    if fused is None:
        fused = FusedAggregate(aggs)
        if len(_FUSED_CACHE) >= _FUSED_CACHE_MAX:
            _FUSED_CACHE.pop(next(iter(_FUSED_CACHE)))
        _FUSED_CACHE[key] = fused
    return fused


def _combine_leaf(op: str, a, b):
    if op == MERGE_SUM:
        return a + b
    if op == MERGE_MAX:
        return jnp.maximum(a, b)
    if op == MERGE_MIN:
        return jnp.minimum(a, b)
    raise ValueError(f"unknown merge op {op!r}")


def _collective_leaf(op: str, x, *, axes):
    if op == MERGE_SUM:
        return jax.lax.psum(x, axes)
    if op == MERGE_MAX:
        return jax.lax.pmax(x, axes)
    if op == MERGE_MIN:
        return jax.lax.pmin(x, axes)
    raise ValueError(f"unknown merge op {op!r}")


# ---------------------------------------------------------------------------
# Local (single-shard) blocked fold.
# ---------------------------------------------------------------------------

def _blocked_fold(agg: Aggregate, columns: Columns, mask: jax.Array | None,
                  block_size: int | None) -> Any:
    """Fold ``transition`` over row blocks of ``columns`` on one shard."""
    n = next(iter(columns.values())).shape[0]
    if mask is None:
        mask = jnp.ones((n,), jnp.bool_)
    state = agg.init(columns)
    if block_size is None or block_size >= n:
        return agg.transition(state, columns, mask)

    bs = block_size
    nb = -(-n // bs)  # ceil
    padded = nb * bs
    if padded != n:
        pad = padded - n
        columns = {k: jnp.pad(v, [(0, pad)] + [(0, 0)] * (v.ndim - 1))
                   for k, v in columns.items()}
        mask = jnp.pad(mask, (0, pad))

    blocks = {k: v.reshape((nb, bs) + v.shape[1:]) for k, v in columns.items()}
    masks = mask.reshape(nb, bs)

    def step(state, xs):
        blk, m = xs
        return agg.transition(state, blk, m), None

    state, _ = jax.lax.scan(step, state, (blocks, masks))
    return state


# Prepared-statement program cache for the local engine: the jitted pass
# is memoized per aggregate INSTANCE (and block size), so re-executing a
# retained statement — a prepared statement, a driver re-running its
# pass, a bench rep — reuses the compiled program instead of re-tracing.
# Bounded FIFO: evicting an entry drops its jit closure (and with it the
# compiled executable), so one-shot aggregates don't accumulate; a live
# entry pins its aggregate, so ids can't collide.
_LOCAL_JIT_CACHE: dict[tuple, tuple[Aggregate, Callable]] = {}
_LOCAL_JIT_MAX = 256


def _local_jit(agg: Aggregate, block_size, finalize: bool = True):
    key = (id(agg), block_size, finalize)
    hit = _LOCAL_JIT_CACHE.get(key)
    if hit is not None:
        return hit[1]

    def go(columns, mask):
        state = _blocked_fold(agg, columns, mask, block_size)
        return agg.final(state) if finalize else state

    fn = jax.jit(go)
    if len(_LOCAL_JIT_CACHE) >= _LOCAL_JIT_MAX:
        _LOCAL_JIT_CACHE.pop(next(iter(_LOCAL_JIT_CACHE)))
    _LOCAL_JIT_CACHE[key] = (agg, fn)
    return fn


def run_local(agg: Aggregate, table: Table, *, block_size: int | None = None,
              mask: jax.Array | None = None, jit: bool = True,
              finalize: bool = True, trace_kind: str = "scan") -> Any:
    """Execute an aggregate on a single shard (PostgreSQL single-node
    mode).  Compiled programs are reused across calls with the same
    aggregate instance (see ``_LOCAL_JIT_CACHE``).

    ``finalize=False`` returns the raw fold state instead of
    ``agg.final(state)`` — retained states stay mergeable with the
    aggregate's combinators.  ``trace_kind`` labels the recorded event
    ("scan" normally; the materialize layer passes "delta" when this
    pass folds only appended rows)."""
    _record(trace_kind, engine="local", rows=table.n_rows)
    if not jit:
        state = _blocked_fold(agg, dict(table.columns), mask, block_size)
        return agg.final(state) if finalize else state
    return _local_jit(agg, block_size, finalize)(dict(table.columns), mask)


# ---------------------------------------------------------------------------
# Sharded execution (the Greenplum segment model).
# ---------------------------------------------------------------------------

def run_sharded(agg: Aggregate, table: Table, *, mesh: Mesh | None = None,
                row_axes: tuple[str, ...] | None = None,
                block_size: int | None = None,
                mask: jax.Array | None = None, jit: bool = True,
                finalize: bool = True, trace_kind: str = "scan") -> Any:
    """Execute an aggregate in parallel across the mesh's row axes.

    Each shard folds its local rows (transition), states are merged across
    segments with the aggregate's merge combinators (second-phase
    aggregation), and ``final`` runs replicated.  This function is the
    paper's Figure-4 engine.  ``mask`` is a base row filter in table row
    order, sharded alongside the rows and applied at the fold level — the
    same contract as ``run_local``.
    """
    mesh = mesh or table.mesh
    row_axes = tuple(row_axes or table.row_axes or ("data",))
    if mesh is None:
        return run_local(agg, table, block_size=block_size, mask=mask,
                         jit=jit, finalize=finalize, trace_kind=trace_kind)
    _record(trace_kind, engine="sharded", rows=table.n_rows)

    in_spec = jax.tree.map(
        lambda v: row_pspec(row_axes, v.ndim), dict(table.columns)
    )
    if mask is None:
        mask = jnp.ones((table.n_rows,), jnp.bool_)

    def shard_fn(columns, mask):
        local = _blocked_fold(agg, columns, mask, block_size)
        merged = agg.mesh_merge(local, row_axes)
        return agg.final(merged) if finalize else merged

    mapped = _compat_shard_map(
        shard_fn, mesh=mesh, in_specs=(in_spec, row_pspec(row_axes)),
        out_specs=P(),  # replicated result
        check_vma=False,
    )
    fn = jax.jit(mapped) if jit else mapped
    return fn(dict(table.columns), jnp.asarray(mask))


# ---------------------------------------------------------------------------
# Streaming / out-of-core execution.
# ---------------------------------------------------------------------------

# Same prepared-statement memo for the stream engine's per-block
# programs (step / init-step / final), bounded like _LOCAL_JIT_CACHE.
_STREAM_JIT_CACHE: dict[int, tuple] = {}
_STREAM_JIT_MAX = 128


def _stream_jit(agg: Aggregate):
    hit = _STREAM_JIT_CACHE.get(id(agg))
    if hit is not None:
        return hit[1:]

    @partial(jax.jit, donate_argnums=(0,))
    def step(state, block, mask):
        return agg.transition(state, block, mask)

    @jax.jit
    def init_step(block, mask):
        return agg.transition(agg.init(block), block, mask)

    final = jax.jit(agg.final)
    if len(_STREAM_JIT_CACHE) >= _STREAM_JIT_MAX:
        _STREAM_JIT_CACHE.pop(next(iter(_STREAM_JIT_CACHE)))
    _STREAM_JIT_CACHE[id(agg)] = (agg, step, init_step, final)
    return step, init_step, final


def run_stream(agg: Aggregate, blocks: Iterable[Columns]) -> Any:
    """Fold an aggregate over a host-side stream of row blocks.

    The device-resident state is donated between calls — the analogue of the
    paper's temp-table pattern: all large state stays "in the engine", the
    host only schedules.  Like :func:`run_local`, the per-block programs
    are cached static on the aggregate instance, so re-streaming a
    retained statement re-dispatches compiled steps instead of re-tracing.
    """
    it = iter(blocks)
    try:
        first = next(it)
    except StopIteration:
        raise ValueError("run_stream: empty block stream — at least one "
                         "block is required to seed the fold state") from None
    _record("scan", engine="stream")
    first = {k: jnp.asarray(v) for k, v in first.items()}

    step, init_step, final = _stream_jit(agg)
    n0 = next(iter(first.values())).shape[0]
    state = init_step(first, jnp.ones((n0,), jnp.bool_))
    for block in it:
        block = {k: jnp.asarray(v) for k, v in block.items()}
        n = next(iter(block.values())).shape[0]
        state = step(state, block, jnp.ones((n,), jnp.bool_))
    return final(state)


# ---------------------------------------------------------------------------
# GROUP BY execution — the partitioned grouped-scan core.
# ---------------------------------------------------------------------------

# Default row-block size for the segment path: bounds the (block, state)
# per-row intermediates the singleton transitions materialize.
_SEGMENT_BLOCK = 4096


def _scatter_leaf(op: str, acc, idx, vals):
    """Segment-merge one state leaf: fold the per-row states ``vals``
    (leading row axis, aligned with segment ids ``idx``) into the
    per-group accumulator ``acc`` with the leaf's merge combinator."""
    if op == MERGE_SUM:
        return acc.at[idx].add(vals)
    if op == MERGE_MAX:
        return acc.at[idx].max(vals)
    if op == MERGE_MIN:
        return acc.at[idx].min(vals)
    raise ValueError(f"unknown merge op {op!r}")


def probe_segment_ops(agg: Aggregate, columns: Columns):
    """Merge-combinator tree of ``agg`` over ``columns``' schema, or None
    when the aggregate is not segment-reducible (generic merge).  Runs
    ``init`` abstractly so schema-templated aggregates synthesize their
    ops without touching data."""
    spec = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
            for k, v in columns.items()}
    state_s = jax.eval_shape(agg.init, spec)
    return agg.segment_ops(state_s)


def segment_block_size(n_rows: int, num_groups: int,
                       block_size: int | None = None) -> int:
    """Block size for the group-aligned layout: near the average segment
    (padding overhead is one partial block per group), power-of-two,
    clamped to [64, _SEGMENT_BLOCK].  An explicit ``block_size`` wins;
    an ACTIVE measured calibration's best block for this shape bucket
    beats the heuristic (see :mod:`repro.core.calibration`)."""
    if block_size is not None:
        return max(1, int(block_size))
    cal = _calibration.current()
    if cal is not None:
        b = cal.grouped_block_size(n_rows, num_groups)
        if b:
            return max(1, int(b))
    avg = max(1, -(-n_rows // max(1, num_groups)))
    return max(64, min(_SEGMENT_BLOCK, 1 << (avg - 1).bit_length()))


def segment_block_update(make_agg, group_states, ops, blk: Columns,
                         bm: jax.Array, g: jax.Array, acc) -> Any:
    """Fold ONE group-aligned block into the stacked per-group
    accumulators: run the (possibly group-parameterized) aggregate's real
    block transition from init, then scatter-merge the block state into
    group ``g``'s slot with each leaf's combinator.  Shared by the
    one-pass scan (:func:`segment_fold`) and the iterative engine's
    compacted block loop — the single definition of the segment-merge
    contract."""
    s_g = jax.tree.map(lambda s: s[g], group_states)
    a = make_agg(s_g)
    bstate = a.transition(a.init(blk), blk, bm)
    return jax.tree.map(
        lambda op, al, bl: _scatter_leaf(op, al, g[None], bl[None]),
        ops, acc, bstate)


def segment_fold(make_agg, group_states, ops, columns: Columns,
                 valid: jax.Array, block_gids: jax.Array,
                 num_groups: int, *, agg: Aggregate | None = None,
                 kernel_impl: str | None = None) -> Any:
    """Fold EVERY group's state in ONE O(n) blocked scan (jit-traceable).

    Consumes the group-aligned layout of
    :meth:`~repro.core.table.GroupedView.aligned_blocks`: each block holds
    rows of exactly one group, so the aggregate's REAL block transition
    runs per block (the same MXU-shaped update as the solo fold, with
    padding rows masked out) and the block state is segment-merged into
    the stacked ``(num_groups, ...)`` accumulators with each leaf's merge
    combinator (``ops``, from :meth:`Aggregate.segment_ops`).  Correctness
    rests on exactly the contract :func:`run_sharded` already imposes:
    folding a row partition from init and merging leaf-wise must equal the
    sequential fold, with init the merge identity (so empty groups keep
    their init state).

    ``make_agg(state_g)`` builds the (possibly per-group-parameterized)
    aggregate; pass ``lambda _: agg`` with dummy states for a uniform
    aggregate.

    ``agg`` + ``kernel_impl`` engage the aggregate's registered
    segment-fold kernel (resolved host-side, see
    :func:`_resolve_segment_kernel`): the whole fold runs as ONE fused
    Pallas grid loop (or its jnp ref oracle) computing the fold-from-zero
    state stack, then merges with the vmapped per-group inits under the
    leaf combinators — bit-identical to the generic scan for exact-state
    aggregates because init is the merge identity.
    """
    lead = jax.tree.leaves(group_states)[0].shape[0]
    if lead != num_groups:
        raise ValueError(f"segment_fold: group_states lead axis {lead} "
                         f"!= num_groups={num_groups}")
    inits = jax.vmap(lambda s: make_agg(s).init(columns))(group_states)
    nb = block_gids.shape[0]
    if nb == 0:
        return inits
    if kernel_impl is not None and agg is not None \
            and getattr(agg, "segment_kernel", None):
        kstates = agg.segment_kernel_fold(columns, valid, block_gids,
                                          num_groups, kernel_impl)
        return jax.tree.map(_combine_leaf, ops, inits, kstates)
    n2 = next(iter(columns.values())).shape[0]
    bs = n2 // nb
    blocks = {k: v.reshape((nb, bs) + v.shape[1:]) for k, v in columns.items()}
    vmask = valid.reshape(nb, bs)

    def step(acc, xs):
        blk, bm, g = xs
        return segment_block_update(make_agg, group_states, ops, blk, bm,
                                    g, acc), None

    acc, _ = jax.lax.scan(step, inits, (blocks, vmask, block_gids))
    return acc


def merge_group_states(agg: Aggregate, ops, states, axes: tuple[str, ...]):
    """Cross-segment merge of stacked ``(G, ...)`` per-group states inside
    ``shard_map``: leaf-wise collectives when the aggregate declares merge
    combinators (``ops`` from :meth:`Aggregate.segment_ops`), else an
    all-gather of every segment's group-state stack folded with the
    aggregate's own generic ``merge`` (vmapped over the group axis)."""
    if ops is not None:
        return jax.tree.map(partial(_collective_leaf, axes=axes), ops,
                            states)
    return _all_gather_merge_fold(jax.vmap(agg.merge), states, axes)


def _mesh_segments(mesh: Mesh, row_axes: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in row_axes]))


# Prepared-statement memo for the local segment path, keyed like
# _LOCAL_JIT_CACHE (the jit object retraces by itself when block shapes
# change, so block size is not part of the key).  Without it every
# grouped pass re-traced from scratch — a fixed per-call cost that
# dwarfed small folds such as a living view's delta refresh.
_SEGMENT_JIT_CACHE: dict[tuple, tuple[Aggregate, Callable]] = {}
_SEGMENT_JIT_MAX = 256


def _resolve_segment_kernel(agg: Aggregate, columns, valid, bgids,
                            num_groups: int) -> str | None:
    """Host-side kernel resolution for ONE physical grouped execution:
    which implementation of the aggregate's registered segment kernel
    runs (``"ref"``/``"pallas"``), or None for the inline jnp segment
    fold (no kernel requested).  Runs the registry's resolve on the
    concrete shapes (``ShapeDtypeStruct`` works) BEFORE tracing, so a
    forced ``impl="pallas"`` an unsupported backend/shape cannot take
    fails loudly here, and records the resolved impl on active traces —
    once per execution, not per traced dispatch."""
    name = getattr(agg, "segment_kernel", None)
    impl = getattr(agg, "kernel_impl", None)
    if name is None or impl is None:
        return None
    from ..kernels import registry as _kernels
    args, kwargs = agg.segment_kernel_args(columns, valid, bgids, num_groups)
    resolved, _tuned = _kernels.get(name).resolve(impl, *args, **kwargs)
    _record("kernel", engine=resolved, name=name, requested=impl)
    return resolved


def _segment_jit(agg: Aggregate, ops, G: int, finalize: bool, schema,
                 seg_impl: str | None = None):
    # schema is part of the key because templated aggregates derive their
    # state tree (and thus ops) from the column set, not just the
    # instance; seg_impl because the resolved kernel changes the program
    key = (id(agg), G, finalize, schema, seg_impl)
    hit = _SEGMENT_JIT_CACHE.get(key)
    if hit is not None:
        return hit[1]
    dummy_states = jnp.zeros((G,), jnp.int32)
    group_final = jax.vmap(agg.final) if finalize else (lambda s: s)

    def go_segment(columns, valid, bgids):
        states = segment_fold(lambda _s: agg, dummy_states, ops,
                              columns, valid, bgids, G,
                              agg=agg, kernel_impl=seg_impl)
        return group_final(states)

    fn = jax.jit(go_segment)
    if len(_SEGMENT_JIT_CACHE) >= _SEGMENT_JIT_MAX:
        _SEGMENT_JIT_CACHE.pop(next(iter(_SEGMENT_JIT_CACHE)))
    _SEGMENT_JIT_CACHE[key] = (agg, fn)
    return fn


def run_grouped(agg: Aggregate, table, group_col: str | None = None,
                num_groups: int | None = None, *,
                block_size: int | None = None,
                mask: jax.Array | None = None,
                method: str = "auto", mesh: Mesh | None = None,
                row_axes: tuple[str, ...] | None = None,
                jit: bool = True, finalize: bool = True,
                trace_kind: str = "scan") -> Any:
    """Grouped aggregation (``SELECT ..., agg(...) GROUP BY g``).

    ``table`` is either a :class:`Table` — grouped by its ``group_col``
    column — or a prebuilt :class:`~repro.core.table.GroupedView`
    (``group_col`` ignored), so multi-pass grouped methods pay the
    partitioning sort once and share it across scans.  Star-schema
    joined aggregation reaches this engine UNCHANGED: the join layer
    (:mod:`repro.core.join`) resolves ``fact JOIN dim`` to a fact-
    aligned integer group-id column and this function grouped-scans it
    like any other key — out-of-range ids (``-1`` for dropped dangling
    foreign keys) fall outside every segment by :meth:`Table.group_by`'s
    documented semantics.

    Two execution strategies share the engine:

    * ``method="segment"`` — the partitioned grouped-scan core: rows are
      permuted into group-aligned blocks once (:meth:`Table.group_by` +
      ``aligned_blocks``) and ALL groups fold in a single O(n) blocked
      scan with a per-block segment merge (:func:`segment_fold`).
      Requires leaf-wise merge combinators (``agg.segment_ops``).
    * ``method="masked"`` — the fallback for generic-merge aggregates:
      vmap the blocked masked fold over group ids; every group scans the
      full table (O(G·n)), exact for any aggregate honoring the mask
      contract.

    ``method="auto"`` picks segment whenever the aggregate supports it.
    ``mask`` is a base row filter applied before grouping (like
    ``run_local``), always given in the ORIGINAL table's row order;
    ``num_groups`` defaults to ``max(gid) + 1`` (the view's group count).

    ``mesh`` (defaulting to the table's) engages the SHARDED grouped
    engine — MADlib's two-phase GROUP BY (§4.1) across the mesh's row
    axes: the group-aligned blocks are distributed in whole-block chunks,
    every segment runs the real per-block transition locally
    (:func:`segment_fold` on its chunk), and the G per-segment partial
    states merge with each leaf's combinator collective — one data pass,
    ``G x num_segments`` partial states, bit-identical to the local
    segment fold for exact-state aggregates.  Generic-merge aggregates
    take a sharded masked path instead (local masked folds, all-gather
    generic merge).

    ``finalize=False`` returns the stacked ``(G, ...)`` fold states
    instead of ``vmap(final)`` results (the retained form materialized
    grouped views merge group-wise); ``trace_kind`` labels the recorded
    event as in :func:`run_local`.
    """
    view = table if isinstance(table, GroupedView) else None
    base_tbl = view.table if view is not None else table
    if mesh is None:
        mesh = base_tbl.mesh
    row_axes = tuple(row_axes or base_tbl.row_axes or ("data",))
    if view is not None:
        if num_groups is not None and num_groups != view.num_groups:
            raise ValueError(f"run_grouped: num_groups={num_groups} "
                             f"disagrees with the view's {view.num_groups}")
        num_groups = view.num_groups
        data = dict(view.table.columns)
    else:
        if group_col is None:
            raise ValueError("run_grouped: group_col is required when "
                             "grouping a Table (or pass a GroupedView)")
        if num_groups is None:
            num_groups = int(jax.device_get(
                jnp.max(table[group_col].astype(jnp.int32)))) + 1
        data = {k: v for k, v in table.columns.items() if k != group_col}
    G = num_groups

    if method in ("auto", "segment"):
        ops = probe_segment_ops(agg, data)
    elif mesh is not None:
        # forced masked + sharded: ops only optimize the cross-shard
        # merge, so an un-probe-able init (abstract-eval failure in a
        # generic-merge aggregate) must not be fatal
        try:
            ops = probe_segment_ops(agg, data)
        except Exception:
            ops = None
    else:
        ops = None  # forced masked, local: ops never consulted
    if method == "auto":
        method = "segment" if ops is not None else "masked"
    _record(trace_kind, engine=f"grouped-{method}", sharded=mesh is not None,
            groups=G)
    group_final = jax.vmap(agg.final) if finalize else (lambda s: s)

    if method == "segment":
        if ops is None:
            raise ValueError(
                "run_grouped: method='segment' needs leaf-wise merge "
                "combinators (agg.segment_ops() returned None); use "
                "method='masked' for generic-merge aggregates")
        if view is None:
            view = table.group_by(group_col, G)
        pmask = None if mask is None else view.permute(mask)
        bs = segment_block_size(view.n_rows, G, block_size)
        dummy_states = jnp.zeros((G,), jnp.int32)

        if mesh is None:
            cols_a, valid_a, bgids = view.aligned_blocks(bs, pmask)
            seg_impl = _resolve_segment_kernel(agg, cols_a, valid_a,
                                               bgids, G)
            if jit:
                schema = tuple(sorted(
                    (k, str(v.dtype), tuple(v.shape[1:]))
                    for k, v in data.items()))
                return _segment_jit(agg, ops, G, finalize, schema,
                                    seg_impl)(cols_a, valid_a, bgids)

            def go_segment(columns, valid, bgids):
                states = segment_fold(lambda _s: agg, dummy_states, ops,
                                      columns, valid, bgids, G,
                                      agg=agg, kernel_impl=seg_impl)
                return group_final(states)

            return go_segment(cols_a, valid_a, bgids)

        # Sharded segment path: each segment folds its local chunk of
        # group-aligned blocks, per-group partials merge leaf-wise.
        cols_a, valid_a, bgids = view.sharded_blocks(mesh, row_axes, bs,
                                                     pmask)
        # kernel resolution sees the SHARD-LOCAL shapes the kernel will
        # run on inside shard_map (sharded_blocks pads every segment to
        # whole blocks, so the division is exact)
        segs = _mesh_segments(mesh, row_axes)
        _local = lambda v: jax.ShapeDtypeStruct(
            (v.shape[0] // segs,) + v.shape[1:], v.dtype)
        seg_impl = _resolve_segment_kernel(
            agg, jax.tree.map(_local, dict(cols_a)), _local(valid_a),
            _local(bgids), G)
        in_spec = jax.tree.map(
            lambda v: row_pspec(row_axes, v.ndim), cols_a)

        def shard_segment(columns, valid, bgids):
            states = segment_fold(lambda _s: agg, dummy_states, ops,
                                  columns, valid, bgids, G,
                                  agg=agg, kernel_impl=seg_impl)
            merged = merge_group_states(agg, ops, states, row_axes)
            return group_final(merged)

        mapped = _compat_shard_map(
            shard_segment, mesh=mesh,
            in_specs=(in_spec, row_pspec(row_axes), row_pspec(row_axes)),
            out_specs=P(), check_vma=False)
        fn = jax.jit(mapped) if jit else mapped
        return fn(cols_a, valid_a, bgids)

    if method != "masked":
        raise ValueError(f"unknown method {method!r} "
                         "(use 'auto', 'segment' or 'masked')")

    if view is not None:
        gids = view.gids
        base_mask = None if mask is None else view.permute(mask)
    else:
        gids = table[group_col].astype(jnp.int32)
        base_mask = mask

    if mesh is not None:
        return _run_grouped_masked_sharded(
            agg, ops, data, gids, base_mask, G, block_size, mesh, row_axes,
            jit, group_final)

    def go_masked(data, gids, mask):
        base = jnp.ones(gids.shape, jnp.bool_) if mask is None else mask

        def per_group(g):
            return _blocked_fold(agg, data, (gids == g) & base, block_size)

        return group_final(jax.vmap(per_group)(jnp.arange(G)))

    fn = jax.jit(go_masked) if jit else go_masked
    return fn(data, gids, base_mask)


def _run_grouped_masked_sharded(agg, ops, data, gids, base_mask, G,
                                block_size, mesh, row_axes, jit_,
                                group_final):
    """Sharded masked path: every segment folds its LOCAL rows once per
    group (mask contract), per-group partial states merge across segments
    — leaf-wise collectives when available, the all-gather generic fold
    otherwise.  Rows are padded (masked invalid) to divide the segment
    count, so any local table works with an explicit ``mesh=``."""
    segs = _mesh_segments(mesh, row_axes)
    n = next(iter(data.values())).shape[0]
    valid = jnp.ones((n,), jnp.bool_) if base_mask is None \
        else jnp.asarray(base_mask)
    pad = -n % segs
    if pad:
        data = {k: jnp.pad(v, [(0, pad)] + [(0, 0)] * (v.ndim - 1))
                for k, v in data.items()}
        gids = jnp.pad(gids, (0, pad), constant_values=-1)
        valid = jnp.pad(valid, (0, pad))  # padding rows: invalid
    placed = distribute_rows(mesh, row_axes,
                             dict(data, __gid__=gids, __valid__=valid))
    gids = placed.pop("__gid__")
    valid = placed.pop("__valid__")
    in_spec = jax.tree.map(lambda v: row_pspec(row_axes, v.ndim), placed)

    def shard_masked(data, gids, valid):
        def per_group(g):
            return _blocked_fold(agg, data, (gids == g) & valid, block_size)

        states = jax.vmap(per_group)(jnp.arange(G))
        merged = merge_group_states(agg, ops, states, row_axes)
        return group_final(merged)

    mapped = _compat_shard_map(
        shard_masked, mesh=mesh,
        in_specs=(in_spec, row_pspec(row_axes), row_pspec(row_axes)),
        out_specs=P(),
        check_vma=False)
    fn = jax.jit(mapped) if jit_ else mapped
    return fn(placed, gids, valid)
