"""Analytics server — cross-session scan sharing behind admission windows.

The PR-5 planner proves statement fusion works inside ONE analyst's
batch; production is thousands of concurrent analysts hitting the same
tables, where N users profiling a table should cost ONE fused scan.
MADlib's premise (§2, §3.2) — analytics run *inside* the engine so
concurrent submitters share the database's data movement — and Feng et
al. / sql4ml's declarative argument make that sharing legal: logical
statements can be re-grouped, fused, deduplicated and cached across
submitters without changing their semantics.  This module points the
existing planner at a statement *queue* instead of a batch:

* :class:`AnalyticsServer` is the long-lived serving front-end.  Many
  :class:`~repro.core.session.Session`\\ s (constructed with
  ``Session(server=...)``) submit logical plan nodes; each submit
  returns an async-style :class:`ServerHandle` immediately.
* Submitted statements sit in short **per-table admission windows**:
  statements partition by base table, and each table's window drains
  independently (count threshold ``window_size``, age
  ``window_timeout``, explicit :meth:`flush`, or on demand when a
  handle's ``result()`` is read) — a slow statement on table A never
  delays table B's drain.  The drain plans *across* sessions with
  :func:`repro.core.plan.plan` unchanged: compatible ``ScanAgg``\\ s
  over one (table, mask, block size) fuse into ONE ``run_many`` pass
  and compatible grouped statements into ONE ``run_grouped`` pass,
  regardless of which session submitted them.  Results route back
  per-handle via each statement's projection isolation, exactly as in a
  single-session batch.
* With ``drain="thread"`` a dedicated **background drainer** owns
  liveness: ``window_timeout`` fires with NO traffic (no
  submit/poll/result call is ever needed for a submitted statement to
  resolve), and each due window drains on its own short-lived worker so
  unrelated tables' drains overlap.  The default ``drain="demand"``
  preserves the synchronous PR-8 contract (drains happen on the
  submitting / polling / reading thread) for tests and single-threaded
  embedding.
* **Execution runs outside the admission lock.**  A drain snapshots its
  window under the lock, then runs cache probes, view refreshes,
  ``plan()`` and ``execute()`` *off* it — submits and cache probes on
  other threads stay responsive during a scan.  A per-table drain lock
  serializes two drains of ONE table (window snapshot plus any
  in-flight execution) while different tables' drains overlap freely.
* Statements whose :func:`~repro.core.plan.semantic_fingerprint` match
  within one window are **deduplicated**: the fold runs once and every
  submitter's handle receives the same result — N identical profile
  statements cost one member in one fused pass, not N.
* In front of planning sits a **byte-budgeted result cache**:
  ``(table id, table version, semantic fingerprint) -> finalized raw
  result``.  A repeated statement against an unchanged table is answered
  with ZERO scans, bit-identical for exact-state aggregates by the same
  argument as delta folds (it IS the previously computed state).
  Admission/eviction is size- and cost-aware (GDSF: entries are
  prioritized by ``cost / bytes`` over an aging clock, with the pytree
  byte size measured via ``jax.tree_util`` and the cost hint taken from
  the planner's measured/heuristic pass cost), so one huge grouped
  state cannot evict a thousand cheap profile results; ``cache_entries``
  still bounds the entry count.  The cache is probed at window-drain
  time — never at admission — so a table mutated between admission and
  execution can never satisfy a stale entry: ``Table.append`` /
  ``invalidate`` bump the version (missing every old key) AND fire the
  table's mutation hooks, which evict the dead entries eagerly.
* Materialized living views (:func:`repro.core.materialize.materialize`)
  **register as cache fillers** (:meth:`register_view`): a statement
  matching a registered view's fingerprint is answered from the view's
  retained fold state and the finalized result is pushed into the cache
  at the version the view pins.  The refresh KIND is surfaced honestly:
  a pure append delta-folds (``refresh="delta"`` — still zero scans),
  but a view whose table was ``invalidate``\\ d performs a full rescan
  inside the hit path (``refresh="rescan"``) and is NOT counted as a
  scan saved.

Observability: every drain records a ``kind="admission"`` trace event
for ITS table (window size, statements planned after dedup/cache,
physical passes, ``scans_saved``, plus ``opened_at`` / ``drained_at``
monotonic timestamps and the window's queue ``latency`` so per-table
isolation is asserted from trace data, never wall-clock heuristics) and
every cache answer a ``kind="cache_hit"`` event carrying its refresh
kind; :meth:`repro.core.trace.Trace.summary` rolls totals AND a
per-table breakdown up from these events.

Thread safety: submits, flushes and reads may come from any thread.
The admission lock guards only window/cache/registry *state* and is
never held across planning, execution or view refresh; per-table drain
locks serialize same-table drains.  Hooked tables are held via
``weakref`` with a finalizer that purges the dead table's cache/view/
window entries the moment it is collected — a long-lived server never
pins transient tables (or their device arrays), and live cache keys
keep the documented ``id()``-stability contract because a table's
entries cannot outlive the table whose ``id`` keyed them.  Mutating a
table concurrently with a drain that scans it is the caller's race,
exactly as with direct engine calls — the server only guarantees it
will never *cache* across such a mutation (the fill re-checks the
version after execution).
"""

from __future__ import annotations

import threading
import time
import weakref
from dataclasses import dataclass
from typing import Any, Callable

import jax

from .plan import (
    GroupedScanAgg, JoinedGroupedScanAgg, ScanAgg, plan,
    semantic_fingerprint, node_tables as _node_tables,
)
from .table import Table
from .trace import record as _record

__all__ = ["AnalyticsServer", "ServerHandle"]

_UNSET = object()
_MISS = object()


class ServerHandle:
    """Async-style result of one submitted statement.

    Returned immediately by :meth:`AnalyticsServer.submit`;
    :meth:`result` drains the admission window holding the statement on
    demand, while :meth:`wait` blocks passively (no drain — the way to
    observe a background drainer doing its job).  Handles are resolved
    exactly once; repeated reads return the same value.
    """

    def __init__(self, label: str, server: "AnalyticsServer"):
        self.label = label
        self._server = server
        self._event = threading.Event()
        self._value: Any = _UNSET
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        """Block until the statement resolves WITHOUT triggering a drain
        (unlike :meth:`result`); returns whether it did.  Only useful
        when something else drains — a background drain thread, another
        session's flush."""
        return self._event.wait(timeout)

    def _resolve(self, value: Any) -> None:
        self._value = value
        self._event.set()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._event.set()

    def result(self, timeout: float | None = None) -> Any:
        """The statement's value, draining its window on demand.

        An already-resolved handle returns immediately — no drain is
        triggered for other statements' benefit.  ``timeout`` bounds the
        WHOLE call: the demand drain (including waiting out another
        thread's in-flight drain of the same table) and the final wait
        share one deadline, so ``result(timeout=t)`` returns or raises
        :class:`TimeoutError` within ~``t`` seconds even when the server
        is busy executing.
        """
        if not self._event.is_set():
            if timeout is None:
                self._server.flush()
                self._event.wait()
            else:
                deadline = time.monotonic() + timeout
                self._server.flush(timeout=timeout)
                remaining = deadline - time.monotonic()
                if not self._event.wait(max(0.0, remaining)):
                    raise TimeoutError(
                        f"statement {self.label!r} still pending after "
                        f"{timeout}s")
        if self._error is not None:
            raise RuntimeError(
                f"statement {self.label!r} failed in its admission "
                f"window") from self._error
        return self._value


@dataclass
class _Pending:
    """One admitted statement awaiting its window drain."""

    node: Any                       # ScanAgg | GroupedScanAgg | fit | stream
    post: Callable | None
    handle: ServerHandle
    fp: tuple | None                # semantic fingerprint (None = opaque)
    table: Table | None             # base table (None for streams)


def _node_table(node) -> Table | None:
    """The statement's ADMISSION table — what its window keys on.  A
    joined statement windows by its FACT table (the scan side; the small
    dimension only shapes the group-id column), so fact appends drain it
    like any single-table statement.  Dimension-mutation staleness is
    handled one layer down: ``semantic_fingerprint`` refuses to cache
    any multi-table statement, so a join can never be answered from the
    result cache after only the dimension moved."""
    tables = _node_tables(node)
    return tables[0] if tables else None


class _Window:
    """One table's admission window: its queued statements, the time the
    oldest was admitted, and the drain lock that serializes this table's
    drains (snapshot + off-lock execution) against each other."""

    __slots__ = ("items", "opened", "drain_lock")

    def __init__(self):
        self.items: list[_Pending] = []
        self.opened: float | None = None
        self.drain_lock = threading.Lock()


@dataclass
class _CacheEntry:
    """One cached result with its GDSF accounting."""

    value: Any
    nbytes: int
    cost: float                     # planner cost hint (pass cost / members)
    prio: float                     # GDSF priority: clock + cost / nbytes


def _tree_nbytes(value) -> int:
    """Device-memory footprint of a cached result: summed ``nbytes``
    over the pytree's array leaves (scalars count a word)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(value):
        nb = getattr(leaf, "nbytes", None)
        total += int(nb) if nb is not None else 8
    return max(total, 1)


class AnalyticsServer:
    """Long-lived cross-session statement service (see module docstring).

    ``window_size`` — per-table pending-statement count that auto-drains
    a window; ``window_timeout`` — seconds after which an open window
    drains (``None`` = count/demand only); ``drain`` — ``"demand"``
    (default: drains run on the submitting/polling/reading thread, the
    PR-8 contract) or ``"thread"`` (a background drainer fires timeouts
    without traffic and dispatches each due window to its own worker);
    ``cache_bytes`` / ``cache_entries`` — result-cache budget in pytree
    bytes and entry count.

    ``stats`` tallies lifetime counters (submitted / windows / planned /
    deduped / cache_hits / view_hits / scans_saved / evicted /
    cache_evicted / cache_rejected / drain_errors) for serving
    dashboards; per-execution assertions should use the trace events
    instead.
    """

    def __init__(self, *, window_size: int = 32,
                 window_timeout: float | None = None,
                 drain: str = "demand",
                 cache_entries: int = 1024,
                 cache_bytes: int = 256 << 20):
        if window_size < 1:
            raise ValueError("window_size must be >= 1")
        if drain not in ("demand", "thread"):
            raise ValueError(f"drain must be 'demand' or 'thread', "
                             f"got {drain!r}")
        self.window_size = int(window_size)
        self.window_timeout = window_timeout
        self.drain = drain
        self.cache_entries = int(cache_entries)
        self.cache_bytes = int(cache_bytes)
        self._lock = threading.RLock()
        # per-table admission windows: id(table) (or None for tableless
        # statements) -> _Window
        self._windows: dict[Any, _Window] = {}
        self._seq = 0
        # (table id, table version, fingerprint) -> _CacheEntry
        self._cache: dict[tuple, _CacheEntry] = {}
        self._cache_used = 0            # bytes resident
        self._clock = 0.0               # GDSF aging clock
        # (table id, fingerprint) -> (MaterializedHandle, statement index)
        self._views: dict[tuple, tuple] = {}
        # weak refs to hooked tables: a long-lived server must not pin
        # transient tables; the finalizer purges a dead table's cache /
        # view / window entries (and the weakref bookkeeping) so its id
        # can never be recycled into a live cache key
        self._hooked: dict[int, weakref.ref] = {}
        self._finalizers: dict[int, weakref.finalize] = {}
        self.stats = {"submitted": 0, "windows": 0, "planned": 0,
                      "deduped": 0, "cache_hits": 0, "view_hits": 0,
                      "scans_saved": 0, "evicted": 0, "cache_evicted": 0,
                      "cache_rejected": 0, "drain_errors": 0}
        self._closing = False
        self._wake = threading.Event()
        self._workers: list[threading.Thread] = []
        self._drainer: threading.Thread | None = None
        if drain == "thread":
            self._drainer = threading.Thread(
                target=self._drain_loop, daemon=True,
                name="analytics-drainer")
            self._drainer.start()

    # -- admission ---------------------------------------------------------
    def submit(self, node, *, post: Callable | None = None,
               label: str | None = None) -> ServerHandle:
        """Admit one logical plan node; returns its handle immediately.
        The statement executes when ITS TABLE's window drains (count
        threshold, timeout, explicit :meth:`flush`, a demanded
        ``result()``, or the background drainer).  The admission itself
        never blocks on an in-flight drain — at most it performs a
        demand-mode drain of a window that just became due."""
        table = _node_table(node)
        key = id(table) if table is not None else None
        fp = semantic_fingerprint(node)
        with self._lock:
            name = label or getattr(node, "label", None) or f"q{self._seq}"
            self._seq += 1
            handle = ServerHandle(name, self)
            if fp is not None and table is not None:
                self._hook_table(table)
            win = self._windows.setdefault(key, _Window())
            now = time.monotonic()
            opened_now = not win.items
            if opened_now:
                win.opened = now
            win.items.append(_Pending(node, post, handle, fp, table))
            self.stats["submitted"] += 1
            due = (len(win.items) >= self.window_size
                   or (self.window_timeout is not None
                       and now - win.opened >= self.window_timeout))
        threaded = self._drainer is not None and self._drainer.is_alive()
        if due:
            if threaded:
                self._wake.set()
            else:
                # nowait: if this table's drain is in-flight on another
                # thread, ITS refill loop picks these statements up — a
                # submit never blocks behind an executing drain
                self._drain_key(key, nowait=True)
        elif threaded and opened_now and self.window_timeout is not None:
            self._wake.set()        # new window: recompute the deadline
        if not threaded and self.window_timeout is not None:
            self.poll()             # other tables' overdue windows
        return handle

    def poll(self) -> int:
        """Drain every window whose timeout has expired (demand-mode
        serving loops call this between accepts; with ``drain="thread"``
        the background drainer makes it redundant); returns statements
        drained."""
        if self.window_timeout is None:
            return 0
        with self._lock:
            now = time.monotonic()
            due = [k for k, w in self._windows.items()
                   if w.items and w.opened is not None
                   and now - w.opened >= self.window_timeout]
        return sum(self._drain_key(k, nowait=True) for k in due)

    @property
    def pending(self) -> int:
        with self._lock:
            return sum(len(w.items) for w in self._windows.values())

    # -- the drain ---------------------------------------------------------
    def flush(self, timeout: float | None = None) -> int:
        """Drain EVERY admission window: answer what the cache (or a
        registered view) can, dedup same-fingerprint statements, plan
        each window as ONE cross-session batch, execute, route results
        to their handles, and fill the cache.  Waits out in-flight
        drains (their statements are resolved when this returns), so a
        plain ``flush()`` still means "everything admitted before this
        call has settled".  ``timeout`` bounds the whole call — windows
        whose drain lock cannot be acquired before the deadline are
        skipped.  Returns the number of statements drained by THIS
        call."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            keys = [k for k, w in self._windows.items()
                    if w.items or w.drain_lock.locked()]
        return sum(self._drain_key(k, deadline=deadline) for k in keys)

    def _drain_key(self, key, deadline: float | None = None,
                   nowait: bool = False) -> int:
        """Drain one table's window (and any count-due refill that
        accumulated while its execution ran off-lock).  Serializes with
        other drains of the SAME table via the window's drain lock;
        different tables' drains overlap freely.  ``nowait`` skips
        instead of waiting for an in-flight drain — safe for submit/poll
        triggers because the in-flight drain's refill loop re-checks the
        window AFTER releasing the lock, so it picks these items up."""
        win = self._windows.get(key)
        drained = 0
        while win is not None:
            if nowait:
                if not win.drain_lock.acquire(blocking=False):
                    return drained
            elif deadline is None:
                win.drain_lock.acquire()
            elif not win.drain_lock.acquire(
                    timeout=max(0.0, deadline - time.monotonic())):
                return drained
            try:
                with self._lock:
                    batch = win.items
                    win.items = []
                    opened = win.opened
                    win.opened = None
                if not batch:
                    return drained
                drained += self._run_window(key, batch, opened)
            finally:
                win.drain_lock.release()
            # A window may have refilled PAST a drain trigger while we
            # executed (submits stay non-blocking during a drain); loop
            # so count/timeout-due statements never strand.
            with self._lock:
                now = time.monotonic()
                refilled = bool(win.items) and (
                    len(win.items) >= self.window_size
                    or (self.window_timeout is not None
                        and win.opened is not None
                        and now - win.opened >= self.window_timeout))
            if not refilled:
                return drained
        return drained

    def _run_window(self, key, batch: list[_Pending],
                    opened: float | None) -> int:
        """Execute one snapshotted window OFF the admission lock (the
        caller holds only the window's drain lock)."""
        t_drain = time.monotonic()
        with self._lock:
            self.stats["windows"] += 1

        to_plan: list[_Pending] = []
        rep_of: dict[tuple, int] = {}    # dedup key -> to_plan index
        routes: list[tuple[_Pending, int]] = []
        hits = deduped = view_rescans = 0
        for p in batch:
            if p.fp is not None and p.table is not None:
                tid = id(p.table)
                # version re-check happens HERE, at execute time: the
                # key carries the table's *current* version, so an
                # entry probed against a table mutated mid-window can
                # only miss — the statement replans below.
                val, rescans = self._answer(tid, p.table, p.fp)
                if val is not _MISS:
                    hits += 1
                    view_rescans += rescans
                    self._resolve(p, val)
                    continue
                dkey = (tid, p.fp)
                if dkey in rep_of:
                    deduped += 1
                    with self._lock:
                        self.stats["deduped"] += 1
                    routes.append((p, rep_of[dkey]))
                    continue
                rep_of[dkey] = len(to_plan)
            routes.append((p, len(to_plan)))
            to_plan.append(p)

        # versions at plan time, for the post-execution cache fill
        fill = [(j, p, id(p.table), p.table.version)
                for j, p in enumerate(to_plan)
                if p.fp is not None and p.table is not None]
        n_scan_stmts = sum(
            isinstance(p.node,
                       (ScanAgg, GroupedScanAgg, JoinedGroupedScanAgg))
            for p in batch)
        try:
            pl = plan([p.node for p in to_plan])
            scan_passes = sum(1 for ps in pl.passes
                              if ps.kind in ("scan", "grouped", "join"))
            # a view answer that had to RESCAN is not a scan saved —
            # the data movement happened, just inside the hit path
            scans_saved = max(
                0, n_scan_stmts - scan_passes - view_rescans)
            _record("admission", None, table=key, window=len(batch),
                    planned=len(to_plan), deduped=deduped,
                    cache_hits=hits, passes=len(pl.passes),
                    scans_saved=scans_saved, view_rescans=view_rescans,
                    opened_at=opened, drained_at=t_drain,
                    latency=0.0 if opened is None else t_drain - opened)
            with self._lock:
                self.stats["planned"] += len(to_plan)
                self.stats["scans_saved"] += scans_saved
            # planner cost hints, amortized per member — the cache
            # admission policy's "how expensive is this to recompute"
            cost_of: dict[int, float] = {}
            for ps in pl.passes:
                if ps.cost is None:
                    continue
                share = float(ps.cost) / max(len(ps.members), 1)
                for i, _ in ps.members:
                    cost_of[i] = share
            results = pl.execute()
        except BaseException as e:
            # an execution/planning error belongs to the WHOLE batch:
            # every handle fails with it (and a synchronous flush caller
            # sees it re-raised; the background drainer counts it)
            for p, _ in routes:
                p.handle._fail(e)
            raise
        with self._lock:
            for j, p, tid, version in fill:
                # fill only if the table did not move during execution —
                # a mid-flight mutation makes the scanned rows ambiguous
                if p.table.version == version:
                    self._cache_put((tid, version, p.fp), results[j],
                                    cost=cost_of.get(j, 1.0))
        for p, j in routes:
            self._resolve(p, results[j])
        return len(batch)

    def _resolve(self, p: _Pending, raw: Any) -> None:
        """Apply the submitter's post and settle the handle.  A failing
        post fails ONLY its own handle — it is the submitter's callback,
        so its exception surfaces on the submitter's ``result()``, never
        on whoever happened to trigger the drain, and never on the other
        handles in the window."""
        try:
            value = p.post(raw) if p.post is not None else raw
        except BaseException as e:
            p.handle._fail(e)
            return
        p.handle._resolve(value)

    # -- the background drainer --------------------------------------------
    def _drain_loop(self) -> None:
        """Dedicated drain thread: sleeps until the earliest open
        window's deadline (or a wake signal: new window, count-due
        submit, close), then dispatches each due window to its own
        worker so one table's slow drain never delays another's."""
        while not self._closing:
            timeout = None
            if self.window_timeout is not None:
                with self._lock:
                    opens = [w.opened for w in self._windows.values()
                             if w.items and w.opened is not None]
                if opens:
                    timeout = max(
                        0.0,
                        min(opens) + self.window_timeout - time.monotonic())
            self._wake.wait(timeout)
            self._wake.clear()
            if self._closing:
                return
            with self._lock:
                now = time.monotonic()
                due = [k for k, w in self._windows.items()
                       if w.items and not w.drain_lock.locked()
                       and (len(w.items) >= self.window_size
                            or (self.window_timeout is not None
                                and w.opened is not None
                                and now - w.opened >= self.window_timeout))]
            for k in due:
                self._spawn_drain(k)

    def _spawn_drain(self, key) -> None:
        def work():
            try:
                self._drain_key(key)
            except Exception:
                # already routed to every handle in the failed window;
                # the drainer itself must survive a poisoned statement
                with self._lock:
                    self.stats["drain_errors"] += 1

        th = threading.Thread(target=work, daemon=True,
                              name=f"analytics-drain-{key}")
        with self._lock:
            self._workers = [w for w in self._workers if w.is_alive()]
            self._workers.append(th)
        th.start()

    # -- the result cache --------------------------------------------------
    def _answer(self, tid: int, table: Table, fp: tuple):
        """Cache-or-view answer for (table @ current version, fp) as
        ``(value, rescans)``, or ``(_MISS, 0)``.  View refreshes run OFF
        the admission lock (they may delta-fold or rescan); ``rescans``
        is 1 when the view had to fully rescan — the honest input to the
        ``scans_saved`` accounting.  Records the ``cache_hit`` trace
        event (with its refresh kind) on a hit."""
        with self._lock:
            ent = self._cache.get((tid, table.version, fp))
            if ent is not None:
                ent.prio = self._clock + ent.cost / ent.nbytes
                self.stats["cache_hits"] += 1
                _record("cache_hit", None, source="cache", refresh="none",
                        table_version=table.version)
                return ent.value, 0
            view = self._views.get((tid, fp))
        if view is None:
            return _MISS, 0
        handle, idx = view
        # refresh + finalize OFF the lock: appends delta-fold
        # (kind="delta" in the trace — still zero scans); an invalidated
        # table forces a FULL RESCAN inside the handle.  Either way the
        # answer is current and gets cached at the version the handle
        # pins — and the refresh kind is surfaced, not laundered.
        kind = handle.refresh()
        vals = handle.result(refresh=False)
        vals = vals if isinstance(vals, list) else [vals]
        val = vals[idx]
        with self._lock:
            self._cache_put((tid, handle.version, fp), val,
                            cost=float(handle.table.n_rows))
            self.stats["cache_hits"] += 1
            self.stats["view_hits"] += 1
        _record("cache_hit", None, source="view", refresh=kind,
                table_version=handle.version)
        return val, (1 if kind == "rescan" else 0)

    def _cache_put(self, key: tuple, value: Any, *,
                   cost: float = 1.0) -> None:
        """Size/cost-aware admission (GDSF): an entry's priority is the
        aging clock plus ``cost / bytes``, evictions pop the minimum
        priority and advance the clock to it.  A cheap-to-recompute
        giant therefore evicts FIRST (often immediately — effectively
        refused admission) instead of flushing many small expensive
        results; anything larger than the whole budget is rejected
        outright.  Caller holds the admission lock."""
        nbytes = _tree_nbytes(value)
        if nbytes > self.cache_bytes:
            self.stats["cache_rejected"] += 1
            return
        old = self._cache.pop(key, None)
        if old is not None:
            self._cache_used -= old.nbytes
        self._cache[key] = _CacheEntry(
            value, nbytes, float(cost), self._clock + float(cost) / nbytes)
        self._cache_used += nbytes
        while (self._cache_used > self.cache_bytes
               or len(self._cache) > self.cache_entries):
            victim = min(self._cache, key=lambda k: self._cache[k].prio)
            ent = self._cache.pop(victim)
            self._cache_used -= ent.nbytes
            self._clock = ent.prio
            self.stats["cache_evicted"] += 1

    def _hook_table(self, table: Table) -> None:
        tid = id(table)
        if tid not in self._hooked:
            table.on_mutation(self._evict)
            self._hooked[tid] = weakref.ref(table)
            self._finalizers[tid] = weakref.finalize(
                table, AnalyticsServer._table_died, weakref.ref(self), tid)

    @staticmethod
    def _table_died(server_ref, tid: int) -> None:
        """Finalizer for a hooked table: purge every server entry keyed
        by its (about to be recycled) id.  Static + weak so the
        finalizer pins neither the table nor the server."""
        srv = server_ref()
        if srv is None:
            return
        with srv._lock:
            srv._hooked.pop(tid, None)
            srv._finalizers.pop(tid, None)
            srv._drop_table_entries(tid)
            win = srv._windows.get(tid)
            if win is not None and not win.items \
                    and not win.drain_lock.locked():
                del srv._windows[tid]

    def _drop_table_entries(self, tid: int) -> None:
        """Drop cache entries and view registrations for a table id.
        Caller holds the admission lock."""
        for k in [k for k in self._cache if k[0] == tid]:
            self._cache_used -= self._cache.pop(k).nbytes
        for vk in [vk for vk in self._views if vk[0] == tid]:
            del self._views[vk]

    def _evict(self, table: Table) -> None:
        """Mutation hook: drop every cache entry for the mutated table.
        (All of them are dead — the version just bumped, so no remaining
        key can match a future probe.)"""
        with self._lock:
            tid = id(table)
            dead = [k for k in self._cache if k[0] == tid]
            for k in dead:
                self._cache_used -= self._cache.pop(k).nbytes
            self.stats["evicted"] += len(dead)

    def register_view(self, handle) -> None:
        """Register a :class:`~repro.core.materialize.MaterializedHandle`
        as a cache filler: statements whose semantic fingerprint matches
        one of the view's retained statements are answered from its fold
        state (delta-refreshed across appends) instead of scanning.
        ``Session.materialize`` on a server-attached session registers
        automatically."""
        with self._lock:
            self._hook_table(handle.table)
            for i, node in enumerate(handle.nodes):
                fp = semantic_fingerprint(node)
                if fp is not None:
                    self._views[(id(handle.table), fp)] = (handle, i)

    def clear_cache(self) -> None:
        """Drop every cached result (registered views stay)."""
        with self._lock:
            self._cache.clear()
            self._cache_used = 0

    # -- introspection & lifecycle -----------------------------------------
    def explain(self) -> str:
        """Render what draining the current windows WOULD do — cache
        answers, dedup, and the cross-session physical plan — without
        executing (the serving analogue of ``Session.explain``).  All
        per-table windows render as one combined batch; cross-table
        statements never fuse, so the passes shown are exactly the
        per-window drains' union."""
        with self._lock:
            pending = [p for w in self._windows.values() for p in w.items]
            if not pending:
                return "(empty batch)"
            hits = deduped = 0
            seen: set = set()
            uniq = []
            for p in pending:
                if p.fp is not None and p.table is not None:
                    tid = id(p.table)
                    if ((tid, p.table.version, p.fp) in self._cache
                            or (tid, p.fp) in self._views):
                        hits += 1
                        continue
                    dkey = (tid, p.fp)
                    if dkey in seen:
                        deduped += 1
                        continue
                    seen.add(dkey)
                uniq.append(p.node)
            head = (f"admission window: {len(pending)} submitted, "
                    f"{hits} cache-answerable, {deduped} deduped -> "
                    f"{len(uniq)} planned")
            if not uniq:
                return head
            return head + "\n" + plan(uniq).explain()

    def close(self) -> None:
        """Stop the background drainer (if any), drain every window,
        deregister every table eviction hook and drop the cache/view
        registries.  The server object stays usable for demand-mode
        drains afterwards (tables re-hook on the next submit), but the
        background drainer does NOT restart — ``close()`` is the polite
        end of a serving run."""
        self._closing = True
        self._wake.set()
        if self._drainer is not None:
            self._drainer.join(timeout=10.0)
        with self._lock:
            workers = list(self._workers)
        for w in workers:
            w.join(timeout=10.0)
        self.flush()
        with self._lock:
            for tid, ref in list(self._hooked.items()):
                t = ref()
                if t is not None:
                    t.remove_mutation_hook(self._evict)
                fin = self._finalizers.pop(tid, None)
                if fin is not None:
                    fin.detach()
            self._hooked.clear()
            self._cache.clear()
            self._cache_used = 0
            self._views.clear()

    def __enter__(self) -> "AnalyticsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
