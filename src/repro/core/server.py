"""Analytics server — cross-session scan sharing behind an admission window.

The PR-5 planner proves statement fusion works inside ONE analyst's
batch; production is thousands of concurrent analysts hitting the same
tables, where N users profiling a table should cost ONE fused scan.
MADlib's premise (§2, §3.2) — analytics run *inside* the engine so
concurrent submitters share the database's data movement — and Feng et
al. / sql4ml's declarative argument make that sharing legal: logical
statements can be re-grouped, fused, deduplicated and cached across
submitters without changing their semantics.  This module points the
existing planner at a statement *queue* instead of a batch:

* :class:`AnalyticsServer` is the long-lived serving front-end.  Many
  :class:`~repro.core.session.Session`\\ s (constructed with
  ``Session(server=...)``) submit logical plan nodes; each submit
  returns an async-style :class:`ServerHandle` immediately.
* Submitted statements sit in a short **admission window** (flushed when
  the pending count reaches ``window_size``, when ``window_timeout``
  seconds have passed since the window opened, on an explicit
  :meth:`flush`, or on demand when any handle's ``result()`` is read).
  The drain plans *across* sessions with :func:`repro.core.plan.plan`
  unchanged: compatible ``ScanAgg``\\ s over one (table, mask,
  block size) fuse into ONE ``run_many`` pass and compatible grouped
  statements into ONE ``run_grouped`` pass, regardless of which session
  submitted them.  Results route back per-handle via each statement's
  projection isolation, exactly as in a single-session batch.
* Statements whose :func:`~repro.core.plan.semantic_fingerprint` match
  within one window are **deduplicated**: the fold runs once and every
  submitter's handle receives the same result — N identical profile
  statements cost one member in one fused pass, not N.
* In front of planning sits a **version-keyed result cache**:
  ``(table id, table version, semantic fingerprint) -> finalized raw
  result``.  A repeated statement against an unchanged table is answered
  with ZERO scans, bit-identical for exact-state aggregates by the same
  argument as delta folds (it IS the previously computed state).  The
  cache is probed at window-drain time — never at admission — so a table
  mutated between admission and execution can never satisfy a stale
  entry: ``Table.append`` / ``invalidate`` bump the version (missing
  every old key) AND fire the table's mutation hooks, which evict the
  dead entries eagerly.
* Materialized living views (:func:`repro.core.materialize.materialize`)
  **register as cache fillers** (:meth:`register_view`): a statement
  matching a registered view's fingerprint is answered from the view's
  retained fold state — refreshed by a delta fold when the table has
  only appended, still zero scans — and the finalized result is pushed
  into the cache at the current version.

Observability: every drain records a ``kind="admission"`` trace event
(window size, statements planned after dedup/cache, physical passes,
``scans_saved``) and every cache answer a ``kind="cache_hit"`` event, so
tests and benches assert sharing instead of timing it
(:meth:`repro.core.trace.Trace.summary`).

Thread safety: submits, flushes and reads may come from any thread (the
bench drives 8 submitter threads); one re-entrant lock serializes window
state and execution.  Mutating a table concurrently with a flush that
scans it is the caller's race, exactly as with direct engine calls — the
server only guarantees it will never *cache* across such a mutation (the
fill re-checks the version after execution).
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable

from .plan import GroupedScanAgg, ScanAgg, plan, semantic_fingerprint
from .table import GroupedView, Table
from .trace import record as _record

__all__ = ["AnalyticsServer", "ServerHandle"]

_UNSET = object()
_MISS = object()


class ServerHandle:
    """Async-style result of one submitted statement.

    Returned immediately by :meth:`AnalyticsServer.submit`;
    :meth:`result` drains the admission window on demand if the
    statement is still pending, then blocks (``timeout`` seconds at
    most) until the value is routed back.  Handles are resolved exactly
    once; repeated reads return the same value.
    """

    def __init__(self, label: str, server: "AnalyticsServer"):
        self.label = label
        self._server = server
        self._event = threading.Event()
        self._value: Any = _UNSET
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def _resolve(self, value: Any) -> None:
        self._value = value
        self._event.set()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._event.set()

    def result(self, timeout: float | None = None) -> Any:
        if not self._event.is_set():
            # Demand execution: drain the window holding this statement.
            # If another thread is mid-flush, flush() blocks on the
            # server lock until it finishes, then drains any remainder —
            # either way the event is set when our window has executed.
            self._server.flush()
            if not self._event.wait(timeout):
                raise TimeoutError(
                    f"statement {self.label!r} still pending after "
                    f"{timeout}s")
        if self._error is not None:
            raise RuntimeError(
                f"statement {self.label!r} failed in its admission "
                f"window") from self._error
        return self._value


@dataclass
class _Pending:
    """One admitted statement awaiting its window drain."""

    node: Any                       # ScanAgg | GroupedScanAgg | fit | stream
    post: Callable | None
    handle: ServerHandle
    fp: tuple | None                # semantic fingerprint (None = opaque)
    table: Table | None             # base table (None for streams)


def _node_table(node) -> Table | None:
    t = getattr(node, "table", None)
    if isinstance(t, GroupedView):
        return t.table
    return t if isinstance(t, Table) else None


class AnalyticsServer:
    """Long-lived cross-session statement service (see module docstring).

    ``window_size`` — pending-statement count that auto-drains the
    window; ``window_timeout`` — seconds after which the open window
    drains at the next submit or :meth:`poll` (``None`` = count/demand
    only); ``cache_entries`` — LRU bound on the result cache.

    ``stats`` tallies lifetime counters (submitted / windows / planned /
    deduped / cache_hits / view_hits / scans_saved / evicted) for
    serving dashboards; per-execution assertions should use the trace
    events instead.
    """

    def __init__(self, *, window_size: int = 32,
                 window_timeout: float | None = None,
                 cache_entries: int = 1024):
        if window_size < 1:
            raise ValueError("window_size must be >= 1")
        self.window_size = int(window_size)
        self.window_timeout = window_timeout
        self.cache_entries = int(cache_entries)
        self._lock = threading.RLock()
        self._pending: list[_Pending] = []
        self._window_opened: float | None = None
        self._seq = 0
        # (table id, table version, fingerprint) -> finalized raw result
        self._cache: OrderedDict[tuple, Any] = OrderedDict()
        # (table id, fingerprint) -> (MaterializedHandle, statement index)
        self._views: dict[tuple, tuple] = {}
        # strong refs to hooked tables: keeps id()s stable for cache keys
        # and lets close() deregister the eviction hooks
        self._hooked: dict[int, Table] = {}
        self.stats = {"submitted": 0, "windows": 0, "planned": 0,
                      "deduped": 0, "cache_hits": 0, "view_hits": 0,
                      "scans_saved": 0, "evicted": 0}

    # -- admission ---------------------------------------------------------
    def submit(self, node, *, post: Callable | None = None,
               label: str | None = None) -> ServerHandle:
        """Admit one logical plan node; returns its handle immediately.
        The statement executes when its window drains (count threshold,
        timeout, explicit :meth:`flush`, or a demanded ``result()``)."""
        with self._lock:
            name = label or getattr(node, "label", None) or f"q{self._seq}"
            self._seq += 1
            handle = ServerHandle(name, self)
            table = _node_table(node)
            fp = semantic_fingerprint(node)
            if fp is not None and table is not None:
                self._hook_table(table)
            now = time.monotonic()
            if not self._pending:
                self._window_opened = now
            self._pending.append(_Pending(node, post, handle, fp, table))
            self.stats["submitted"] += 1
            if (len(self._pending) >= self.window_size
                    or (self.window_timeout is not None
                        and now - self._window_opened
                        >= self.window_timeout)):
                self.flush()
        return handle

    def poll(self) -> int:
        """Drain the window iff its timeout has expired (serving loops
        call this between accepts); returns statements drained."""
        with self._lock:
            if (self._pending and self.window_timeout is not None
                    and time.monotonic() - self._window_opened
                    >= self.window_timeout):
                return self.flush()
        return 0

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._pending)

    # -- the drain ---------------------------------------------------------
    def flush(self) -> int:
        """Drain the admission window: answer what the cache (or a
        registered view) can, dedup same-fingerprint statements, plan
        the remainder as ONE cross-session batch, execute, route results
        to their handles, and fill the cache.  Returns the number of
        statements drained."""
        with self._lock:
            batch, self._pending = self._pending, []
            self._window_opened = None
            if not batch:
                return 0
            self.stats["windows"] += 1

            to_plan: list[_Pending] = []
            rep_of: dict[tuple, int] = {}    # dedup key -> to_plan index
            routes: list[tuple[_Pending, int]] = []
            hits = deduped = 0
            for p in batch:
                if p.fp is not None and p.table is not None:
                    tid = id(p.table)
                    # version re-check happens HERE, at execute time: the
                    # key carries the table's *current* version, so an
                    # entry probed against a table mutated mid-window can
                    # only miss — the statement replans below.
                    val = self._answer(tid, p.table, p.fp)
                    if val is not _MISS:
                        hits += 1
                        self._resolve(p, val)
                        continue
                    dkey = (tid, p.fp)
                    if dkey in rep_of:
                        deduped += 1
                        self.stats["deduped"] += 1
                        routes.append((p, rep_of[dkey]))
                        continue
                    rep_of[dkey] = len(to_plan)
                routes.append((p, len(to_plan)))
                to_plan.append(p)

            # versions at plan time, for the post-execution cache fill
            fill = [(j, p, id(p.table), p.table.version)
                    for j, p in enumerate(to_plan)
                    if p.fp is not None and p.table is not None]
            n_scan_stmts = sum(
                isinstance(p.node, (ScanAgg, GroupedScanAgg))
                for p in batch)
            try:
                pl = plan([p.node for p in to_plan])
                scan_passes = sum(1 for ps in pl.passes
                                  if ps.kind in ("scan", "grouped"))
                scans_saved = max(0, n_scan_stmts - scan_passes)
                _record("admission", None, window=len(batch),
                        planned=len(to_plan), deduped=deduped,
                        cache_hits=hits, passes=len(pl.passes),
                        scans_saved=scans_saved)
                self.stats["planned"] += len(to_plan)
                self.stats["scans_saved"] += scans_saved
                results = pl.execute()
            except BaseException as e:
                for p, _ in routes:
                    p.handle._fail(e)
                raise
            for j, p, tid, version in fill:
                # fill only if the table did not move during execution —
                # a mid-flight mutation makes the scanned rows ambiguous
                if p.table.version == version:
                    self._cache_put((tid, version, p.fp), results[j])
            first_err = None
            for p, j in routes:
                err = self._resolve(p, results[j])
                if first_err is None:
                    first_err = err
            if first_err is not None:
                raise first_err
            return len(batch)

    def _resolve(self, p: _Pending, raw: Any) -> BaseException | None:
        """Apply the submitter's post and settle the handle.  A failing
        post fails ONLY its own handle (returned, not raised, so the
        rest of the window still resolves)."""
        try:
            value = p.post(raw) if p.post is not None else raw
        except BaseException as e:
            p.handle._fail(e)
            return e
        p.handle._resolve(value)
        return None

    # -- the result cache --------------------------------------------------
    def _answer(self, tid: int, table: Table, fp: tuple):
        """Cache-or-view answer for (table @ current version, fp), or
        ``_MISS``.  Records the ``cache_hit`` trace event on a hit."""
        key = (tid, table.version, fp)
        val = self._cache.get(key, _MISS)
        source = "cache"
        if val is _MISS:
            view = self._views.get((tid, fp))
            if view is None:
                return _MISS
            handle, idx = view
            # refresh + finalize: appends delta-fold (kind="delta" in the
            # trace — still zero scans), anything else rescans inside the
            # handle; either way the answer is current and gets cached at
            # the version the handle now pins.
            vals = handle.result()
            vals = vals if isinstance(vals, list) else [vals]
            val = vals[idx]
            self._cache_put((tid, table.version, fp), val)
            source = "view"
            self.stats["view_hits"] += 1
        else:
            self._cache.move_to_end(key)
        self.stats["cache_hits"] += 1
        _record("cache_hit", None, source=source,
                table_version=table.version)
        return val

    def _cache_put(self, key: tuple, value: Any) -> None:
        self._cache[key] = value
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_entries:
            self._cache.popitem(last=False)

    def _hook_table(self, table: Table) -> None:
        tid = id(table)
        if tid not in self._hooked:
            table.on_mutation(self._evict)
            self._hooked[tid] = table

    def _evict(self, table: Table) -> None:
        """Mutation hook: drop every cache entry for the mutated table.
        (All of them are dead — the version just bumped, so no remaining
        key can match a future probe.)"""
        with self._lock:
            tid = id(table)
            dead = [k for k in self._cache if k[0] == tid]
            for k in dead:
                del self._cache[k]
            self.stats["evicted"] += len(dead)

    def register_view(self, handle) -> None:
        """Register a :class:`~repro.core.materialize.MaterializedHandle`
        as a cache filler: statements whose semantic fingerprint matches
        one of the view's retained statements are answered from its fold
        state (delta-refreshed across appends) instead of scanning.
        ``Session.materialize`` on a server-attached session registers
        automatically."""
        with self._lock:
            self._hook_table(handle.table)
            for i, node in enumerate(handle.nodes):
                fp = semantic_fingerprint(node)
                if fp is not None:
                    self._views[(id(handle.table), fp)] = (handle, i)

    def clear_cache(self) -> None:
        """Drop every cached result (registered views stay)."""
        with self._lock:
            self._cache.clear()

    # -- introspection & lifecycle -----------------------------------------
    def explain(self) -> str:
        """Render what draining the current window WOULD do — cache
        answers, dedup, and the cross-session physical plan — without
        executing (the serving analogue of ``Session.explain``)."""
        with self._lock:
            if not self._pending:
                return "(empty batch)"
            hits = deduped = 0
            seen: set = set()
            uniq = []
            for p in self._pending:
                if p.fp is not None and p.table is not None:
                    tid = id(p.table)
                    if ((tid, p.table.version, p.fp) in self._cache
                            or (tid, p.fp) in self._views):
                        hits += 1
                        continue
                    dkey = (tid, p.fp)
                    if dkey in seen:
                        deduped += 1
                        continue
                    seen.add(dkey)
                uniq.append(p.node)
            head = (f"admission window: {len(self._pending)} submitted, "
                    f"{hits} cache-answerable, {deduped} deduped -> "
                    f"{len(uniq)} planned")
            if not uniq:
                return head
            return head + "\n" + plan(uniq).explain()

    def close(self) -> None:
        """Drain the window, deregister every table eviction hook and
        drop the cache/view registries.  The server object stays usable
        (tables re-hook on the next submit), but ``close()`` is the
        polite end of a serving run."""
        with self._lock:
            self.flush()
            for t in self._hooked.values():
                t.remove_mutation_hook(self._evict)
            self._hooked.clear()
            self._cache.clear()
            self._views.clear()

    def __enter__(self) -> "AnalyticsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
