"""Sharded Table abstraction — MADlib's distributed-by-hash table in JAX.

A :class:`Table` is the macro-programming unit of MADJAX: a pytree of
equal-length *columns* (arrays whose leading axis is the row axis), plus the
sharding metadata that says how rows are distributed across the mesh.  It is
the analogue of a Greenplum table ``DISTRIBUTED BY``: rows are partitioned
over the batch-like mesh axes ("segments"), and every aggregate/driver in
:mod:`repro.core` consumes tables.

Unlike an RDBMS table, columns may be multi-dimensional (a ``DOUBLE
PRECISION[]`` column is simply a ``(n_rows, d)`` array — the paper stores
feature vectors exactly this way in §4.1).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Iterator, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

Columns = Mapping[str, jax.Array]


def _n_rows(columns: Columns) -> int:
    sizes = {k: v.shape[0] for k, v in columns.items()}
    if len(set(sizes.values())) != 1:
        raise ValueError(f"ragged table: column row counts differ: {sizes}")
    return next(iter(sizes.values()))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Table:
    """A pytree of named columns sharing a leading row axis.

    ``columns`` maps column name -> array of shape ``(n_rows, ...)``.
    ``mesh`` / ``row_axes`` record how rows are distributed (may be None for
    a host-local table).
    """

    columns: dict[str, jax.Array]
    mesh: Mesh | None = None
    row_axes: tuple[str, ...] = ()
    # group_by memo: (key_col, num_groups) -> (version, GroupedView).
    # Host-side state private to this instance — never flattened into the
    # pytree, compared or hashed; derived tables (select/with_column/...)
    # start empty.  Entries are stamped with the table version they were
    # built at, so every lookup observes staleness (see group_by).
    _gb_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    # sort memo: key_col -> (version, (sorted_keys, perm)).  One level
    # below the group_by memo: the raw stable argsort of a column, shared
    # by GROUP BY partitioning AND sort-merge join key resolution
    # (core/join.py) — one argsort per (table, key), whoever asks first.
    # Same host-side / version-stamp discipline as _gb_cache.
    _sort_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    # Versioning (the IVM contract): ``_version`` bumps on EVERY mutation
    # (append or invalidate); ``_epoch`` bumps only on non-append
    # mutations (invalidate).  A retained fold state pinned at
    # (version v, epoch e, n_rows r) may be brought current by folding
    # ONLY rows [r:] iff the table's epoch is still e — the row prefix is
    # then guaranteed unchanged.  Host-side, never part of the pytree.
    _version: int = dataclasses.field(default=0, repr=False, compare=False)
    _epoch: int = dataclasses.field(default=0, repr=False, compare=False)
    # Mutation hooks (the eviction contract's push side): callables
    # ``hook(table)`` invoked host-side after every version bump, so
    # external caches keyed on this table (the analytics server's result
    # cache) evict eagerly instead of waiting to observe a version
    # mismatch.  Host-side state, never part of the pytree; derived
    # tables start with no hooks.
    _mutation_hooks: list = dataclasses.field(
        default_factory=list, repr=False, compare=False)

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return tuple(self.columns[n] for n in names), (names, self.mesh, self.row_axes)

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, mesh, row_axes = aux
        return cls(dict(zip(names, children)), mesh, row_axes)

    # -- construction ------------------------------------------------------
    @classmethod
    def from_columns(cls, columns: Columns) -> "Table":
        cols = {k: jnp.asarray(v) for k, v in columns.items()}
        _n_rows(cols)
        return cls(cols)

    def distribute(self, mesh: Mesh, row_axes: Sequence[str] = ("data",)) -> "Table":
        """Shard rows over ``row_axes`` of ``mesh`` (Greenplum DISTRIBUTED BY).

        Rows must divide the product of the named axis sizes; callers pad via
        :meth:`pad_to` first when needed.
        """
        from ..distributed.sharding import distribute_rows
        row_axes = tuple(row_axes)
        segs = int(np.prod([mesh.shape[a] for a in row_axes]))
        n = self.n_rows
        if n % segs:
            raise ValueError(f"n_rows={n} not divisible by {segs} segments; pad first")
        return Table(distribute_rows(mesh, row_axes, dict(self.columns)),
                     mesh, row_axes)

    # -- basic relational ops ----------------------------------------------
    @property
    def n_rows(self) -> int:
        return _n_rows(self.columns)

    @property
    def column_names(self) -> tuple[str, ...]:
        return tuple(sorted(self.columns))

    def __getitem__(self, name: str) -> jax.Array:
        return self.columns[name]

    def select(self, *names: str) -> "Table":
        return Table({n: self.columns[n] for n in names}, self.mesh, self.row_axes)

    def _place_rows(self, columns: dict) -> dict:
        """Re-place columns to this table's row sharding (no-op host-local).

        Every method that returns a Table carrying this table's
        ``mesh`` / ``row_axes`` MUST route fresh columns through here —
        otherwise the result lies about its layout to the sharded
        engines (new arrays would stay ``SingleDeviceSharding``).
        """
        if self.mesh is None:
            return columns
        from ..distributed.sharding import distribute_rows
        segs = int(np.prod([self.mesh.shape[a] for a in self.row_axes]))
        n = _n_rows(columns)
        if n % segs:
            raise ValueError(
                f"n_rows={n} not divisible by {segs} segments of the "
                f"table's mesh; pad before distributing")
        return distribute_rows(self.mesh, self.row_axes, columns)

    def with_column(self, name: str, values: jax.Array) -> "Table":
        cols = dict(self.columns)
        cols[name] = jnp.asarray(values)
        _n_rows(cols)
        if self.mesh is not None:
            from ..distributed.sharding import row_sharding
            cols[name] = jax.device_put(
                cols[name],
                row_sharding(self.mesh, self.row_axes, cols[name].ndim))
        return Table(cols, self.mesh, self.row_axes)

    def map_rows(self, fn: Callable[[Columns], Columns]) -> "Table":
        """Row-wise projection (a SELECT of expressions); traced & fused by XLA."""
        return Table(self._place_rows(dict(fn(self.columns))),
                     self.mesh, self.row_axes)

    def pad_to(self, n: int, fill: float = 0.0) -> tuple["Table", jax.Array]:
        """Pad to ``n`` rows; returns (padded table with a __valid__ mask column)."""
        cur = self.n_rows
        if n < cur:
            raise ValueError(f"pad_to({n}) smaller than n_rows={cur}")
        cols = {}
        for k, v in self.columns.items():
            pad = [(0, n - cur)] + [(0, 0)] * (v.ndim - 1)
            cols[k] = jnp.pad(v, pad, constant_values=fill)
        mask = jnp.arange(n) < cur
        if self.mesh is not None:
            from ..distributed.sharding import row_sharding
            cols = self._place_rows(cols)
            mask = jax.device_put(
                mask, row_sharding(self.mesh, self.row_axes, mask.ndim))
        return Table(cols, self.mesh, self.row_axes), mask

    def blocks(self, block_size: int) -> Iterator["Table"]:
        """Host-side iterator of row blocks (the out-of-core / streaming path)."""
        n = self.n_rows
        for start in range(0, n, block_size):
            stop = min(start + block_size, n)
            yield Table(
                {k: v[start:stop] for k, v in self.columns.items()},
                self.mesh,
                self.row_axes,
            )

    def row_spec(self) -> "Table":
        """ShapeDtypeStruct skeleton of this table (for lowering without data)."""
        cols = {
            k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in self.columns.items()
        }
        return Table(cols, self.mesh, self.row_axes)

    def group_by(self, key_col: str, num_groups: int | None = None
                 ) -> "GroupedView":
        """Partition rows by an integer group-id column (sort once, scan many).

        Returns a :class:`GroupedView`: the data columns permuted so each
        group's rows form one contiguous segment, plus the segment
        boundaries.  This is Greenplum's "redistribute by grouping key"
        materialized once up front — every grouped engine
        (``run_grouped`` / ``fit_grouped``) then folds the partitioned
        layout in O(n) instead of re-masking the full table per group.

        The view is **memoized** per ``(key_col, num_groups)`` on this
        Table instance, so every grouped statement and every
        ``fit_grouped`` over the same key shares ONE partitioning sort —
        the plan layer's sort dedup rests on this cache.  A ``None``
        group count also caches under its resolved value.  Entries are
        stamped with the table :attr:`version`, so :meth:`append` and
        :meth:`invalidate` retire them automatically — a hit is served
        only when the stamp matches the current version.  Derived
        tables (``select`` / ``with_column`` / ...) are new instances
        with empty caches; mutating ``columns`` in place requires an
        explicit :meth:`invalidate`.

        Out-of-range ids (``< 0`` or ``>= num_groups``) keep their rows in
        the permuted table but outside every segment; grouped engines
        ignore them, matching the masked semantics of ``gid == g``.
        """
        view = self.cached_group_by(key_col, num_groups)
        if view is not None:
            return view
        view = self._group_by_uncached(key_col, num_groups)
        self._gb_cache[(key_col, num_groups)] = (self._version, view)
        self._gb_cache[(key_col, view.num_groups)] = (self._version, view)
        return view

    def cached_group_by(self, key_col: str, num_groups: int | None = None
                        ) -> "GroupedView | None":
        """Version-checked :meth:`group_by` memo lookup: the memoized view
        for ``(key_col, num_groups)`` if one exists AND was built at the
        table's current :attr:`version`, else ``None``.  Never sorts.

        This is the ONLY sanctioned way for code outside this class (the
        plan layer's cost model, method wrappers) to peek at the memo —
        a direct ``_gb_cache`` read would resurrect views that an
        :meth:`append` or :meth:`invalidate` has already outdated.
        """
        hit = self._gb_cache.get((key_col, num_groups))
        if hit is None or hit[0] != self._version:
            return None
        return hit[1]

    @property
    def version(self) -> int:
        """Monotonic mutation counter.  Bumped by :meth:`append` and
        :meth:`invalidate`; anything caching state derived from this
        table's rows (group_by views, retained fold states, prepared
        programs keyed on table identity) must stamp the version it read
        and treat a mismatch as stale."""
        return self._version

    @property
    def epoch(self) -> int:
        """Append-survivor counter.  Bumped only by :meth:`invalidate`
        (arbitrary mutation); NOT by :meth:`append`.  While the epoch is
        unchanged, the row prefix ``[0:r]`` observed at any earlier
        version is guaranteed intact, so retained fold states may be
        brought current by folding only the appended suffix (the
        incremental-view-maintenance contract)."""
        return self._epoch

    def append(self, columns: Columns) -> "Table":
        """Append rows in place (the append-only ingest path) and bump
        :attr:`version`.

        ``columns`` must carry exactly this table's columns with matching
        dtypes and trailing shapes.  Existing rows are untouched —
        :attr:`epoch` does NOT bump — so retained statements
        (:func:`repro.core.materialize`) refresh by delta-folding only
        the new rows and merging with the aggregates' own combinators.
        Memoized :meth:`group_by` views are invalidated automatically via
        the version stamp (a later ``group_by`` re-sorts).

        On a distributed table the concatenated columns are re-placed
        over the mesh; the new row count must still divide the segment
        count.  Returns ``self`` for chaining.
        """
        new = {k: jnp.asarray(v) for k, v in columns.items()}
        if set(new) != set(self.columns):
            raise ValueError(
                f"append columns {sorted(new)} != table columns "
                f"{sorted(self.columns)}")
        _n_rows(new)
        cols = {}
        for k, old in self.columns.items():
            v = new[k]
            if v.dtype != old.dtype:
                raise ValueError(
                    f"append column {k!r}: dtype {v.dtype} != {old.dtype}")
            if v.shape[1:] != old.shape[1:]:
                raise ValueError(
                    f"append column {k!r}: trailing shape {v.shape[1:]} "
                    f"!= {old.shape[1:]}")
            cols[k] = jnp.concatenate([old, v], axis=0)
        cols = self._place_rows(cols)
        self.columns.clear()
        self.columns.update(cols)
        self._version += 1
        self._notify_mutation()
        return self

    def invalidate(self) -> None:
        """Declare arbitrary in-place mutation: drops every memoized
        :meth:`group_by` view and bumps BOTH :attr:`version` and
        :attr:`epoch`, so every downstream cache — gb memo, retained
        materialized states, plan-time cost lookups — observes staleness
        instead of relying on caller discipline.  Functional derivations
        (``select`` / ``with_column`` / ...) never need this; they return
        fresh instances.  Use :meth:`append` for append-only growth — it
        keeps the epoch so incremental refresh stays possible."""
        self._gb_cache.clear()
        self._sort_cache.clear()
        self._version += 1
        self._epoch += 1
        self._notify_mutation()

    def on_mutation(self, hook: Callable[["Table"], None]) -> None:
        """Register ``hook(table)`` to run after every mutation that bumps
        :attr:`version` (:meth:`append` and :meth:`invalidate`) — the
        push-side of the staleness contract.  External version-keyed
        caches (the analytics server's result cache) use this to evict
        entries for this table the moment it moves, rather than holding
        dead state until a probe notices the version mismatch.  Hooks run
        host-side, synchronously, in registration order; deregister with
        :meth:`remove_mutation_hook`."""
        self._mutation_hooks.append(hook)

    def remove_mutation_hook(self, hook: Callable[["Table"], None]) -> None:
        """Deregister a :meth:`on_mutation` hook (no-op if absent)."""
        try:
            self._mutation_hooks.remove(hook)
        except ValueError:
            pass

    def _notify_mutation(self) -> None:
        for hook in list(self._mutation_hooks):
            hook(self)

    def sort_permutation(self, key_col: str
                         ) -> tuple[jax.Array, jax.Array]:
        """Memoized stable argsort of one column: ``(sorted_keys, perm)``
        with ``sorted_keys == self[key_col][perm]``.

        This is THE partitioning sort of the engine — hoisted out of
        :meth:`group_by` so GROUP BY partitioning and sort-merge join key
        resolution (:mod:`repro.core.join`) share one argsort per
        ``(table, key)``: a dimension table grouped by its key and joined
        on the same key pays the sort once, whichever path asks first.
        Memoized per ``key_col`` with the same version-stamp staleness
        contract as the :meth:`group_by` memo; a miss records ONE
        ``kind="sort"`` trace event tagged ``table=id(self)`` (the
        per-table rollup in :meth:`Trace.summary` counts these), a hit
        records nothing.
        """
        hit = self._sort_cache.get(key_col)
        if hit is not None and hit[0] == self._version:
            return hit[1]
        from .trace import record
        record("sort", key_col=key_col, n_rows=self.n_rows,
               table=id(self))
        keys = self.columns[key_col]
        perm = jnp.argsort(keys, stable=True)
        out = (keys[perm], perm)
        self._sort_cache[key_col] = (self._version, out)
        return out

    def _group_by_uncached(self, key_col: str, num_groups: int | None
                           ) -> "GroupedView":
        sorted_keys, perm = self.sort_permutation(key_col)
        sorted_gids = sorted_keys.astype(jnp.int32)
        if num_groups is None:
            num_groups = int(jax.device_get(jnp.max(sorted_gids))) + 1
        offsets = jnp.searchsorted(
            sorted_gids, jnp.arange(num_groups + 1, dtype=jnp.int32)
        ).astype(jnp.int32)
        data = {k: v[perm] for k, v in self.columns.items() if k != key_col}
        return GroupedView(
            Table(data, self.mesh, self.row_axes), sorted_gids, perm,
            num_groups, jnp.diff(offsets), offsets,
        )


@dataclasses.dataclass
class GroupedView:
    """Partitioned ``GROUP BY`` layout of a :class:`Table`.

    ``table`` holds the data columns (group-id column stripped) with rows
    permuted so group ``g`` occupies the contiguous segment
    ``offsets[g]:offsets[g + 1]``; ``gids`` is the sorted id column,
    ``perm`` maps partitioned position -> original row, and ``counts``
    is rows per group.  Built by :meth:`Table.group_by`; the sort is paid
    once and shared by every subsequent grouped scan.
    """

    table: Table
    gids: jax.Array            # (n,) int32, sorted ascending
    perm: jax.Array            # (n,) int32, partitioned position -> orig row
    num_groups: int
    counts: jax.Array          # (G,) rows per group
    offsets: jax.Array         # (G + 1,) segment boundaries

    @property
    def n_rows(self) -> int:
        return self.table.n_rows

    def select(self, *names: str) -> "GroupedView":
        """Subset of data columns sharing this view's partitioning (the
        sort is NOT re-paid)."""
        return GroupedView(self.table.select(*names), self.gids, self.perm,
                           self.num_groups, self.counts, self.offsets)

    def permute(self, rows: jax.Array) -> jax.Array:
        """Bring a row-aligned array (e.g. a base mask) into the
        partitioned row order."""
        return jnp.asarray(rows)[self.perm]

    def aligned_blocks(self, block_size: int,
                       base_mask: jax.Array | None = None, *,
                       pad_blocks_to: int | None = None):
        """Group-aligned blocked layout: every group's segment zero-padded
        to a whole number of ``block_size`` row blocks, so each block holds
        rows of exactly ONE group.

        Returns ``(columns, valid, block_gids)``: columns with leading axis
        ``n_blocks * block_size``, a validity mask over real (and
        base-mask-passing) rows, and the single group id of each block.
        Empty groups get no blocks; out-of-range ids fall outside every
        segment and are dropped.  ``base_mask`` must already be in
        partitioned order (see :meth:`permute`).  Padding overhead is
        bounded by ``num_groups * block_size`` rows, so callers pick
        ``block_size`` near the typical segment size.

        ``pad_blocks_to`` rounds the block count up to a multiple (the
        sharded engine needs blocks to divide evenly across segments);
        padding blocks carry the sentinel group id ``num_groups`` (out of
        range: scatters drop them, active-group compaction never selects
        them) with every row masked invalid.
        """
        bs = int(block_size)
        counts = np.asarray(jax.device_get(self.counts))
        starts = np.asarray(jax.device_get(self.offsets))[:-1]
        bpg = -(-counts // bs)  # blocks per group (0 for empty groups)
        bg_np = np.repeat(np.arange(self.num_groups), bpg).astype(np.int32)
        ppg = bpg * bs          # padded rows per group
        n2 = int(ppg.sum())
        if n2 == 0:
            # No real blocks (all groups empty / every id out of range).
            # Still honour pad_blocks_to: emit that many sentinel blocks
            # so sharded layouts keep their every-segment-owns-whole-
            # blocks contract even for an empty view.  Sentinel columns
            # are constructed, not gathered — the table may have 0 rows.
            pad = int(pad_blocks_to) if pad_blocks_to else 0
            cols = {
                k: jnp.zeros((pad * bs,) + v.shape[1:], v.dtype)
                for k, v in self.table.columns.items()
            }
            return (cols, jnp.zeros((pad * bs,), jnp.bool_),
                    jnp.full((pad,), self.num_groups, jnp.int32))
        grp = np.repeat(np.arange(self.num_groups), ppg)
        out_start = np.concatenate([[0], np.cumsum(ppg)])[:-1]
        local = np.arange(n2) - out_start[grp]
        valid_np = local < counts[grp]
        src_np = np.where(valid_np, starts[grp] + local, 0).astype(np.int32)
        if pad_blocks_to:
            extra = -len(bg_np) % int(pad_blocks_to)
            if extra:
                bg_np = np.concatenate(
                    [bg_np,
                     np.full(extra, self.num_groups, np.int32)])
                src_np = np.concatenate(
                    [src_np, np.zeros(extra * bs, np.int32)])
                valid_np = np.concatenate(
                    [valid_np, np.zeros(extra * bs, bool)])
        src = jnp.asarray(src_np)
        cols = {k: v[src] for k, v in self.table.columns.items()}
        valid = jnp.asarray(valid_np)
        if base_mask is not None:
            valid = valid & jnp.asarray(base_mask)[src]
        return cols, valid, jnp.asarray(bg_np)

    def sharded_blocks(self, mesh: Mesh, row_axes=("data",),
                       block_size: int = 4096,
                       base_mask: jax.Array | None = None):
        """:meth:`aligned_blocks` distributed across the mesh's row axes.

        The block count is padded to a multiple of the segment count and
        the rows / validity mask / block-gid vector are placed with
        contiguous whole-block chunks per device, so each segment owns an
        integral run of group-aligned blocks — the MADlib two-phase
        layout: every segment folds its local blocks, per-group partial
        states merge across segments with the aggregate's combinators.
        """
        from ..distributed.sharding import distribute_rows, row_sharding
        row_axes = tuple(row_axes)
        segs = int(np.prod([mesh.shape[a] for a in row_axes]))
        cols, valid, bgids = self.aligned_blocks(
            block_size, base_mask, pad_blocks_to=segs)
        cols = distribute_rows(mesh, row_axes, dict(cols))
        valid = jax.device_put(valid, row_sharding(mesh, row_axes))
        bgids = jax.device_put(bgids, row_sharding(mesh, row_axes))
        return cols, valid, bgids


def synthetic_regression_table(
    key: jax.Array, n_rows: int, n_vars: int, noise: float = 0.1,
    dtype: Any = jnp.float32,
) -> tuple[Table, jax.Array]:
    """The paper's linregr benchmark data: y = <b, x> + eps (§4.4)."""
    kx, kb, ke = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n_rows, n_vars), dtype)
    b = jax.random.normal(kb, (n_vars,), dtype)
    y = x @ b + noise * jax.random.normal(ke, (n_rows,), dtype)
    return Table.from_columns({"x": x, "y": y}), b


def synthetic_classification_table(
    key: jax.Array, n_rows: int, n_vars: int, dtype: Any = jnp.float32
) -> tuple[Table, jax.Array]:
    """Logistic data: Pr[y=1|x] = sigmoid(<b, x>) (§4.2)."""
    kx, kb, ku = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n_rows, n_vars), dtype)
    b = jax.random.normal(kb, (n_vars,), dtype)
    p = jax.nn.sigmoid(x @ b)
    y = (jax.random.uniform(ku, (n_rows,)) < p).astype(dtype)
    return Table.from_columns({"x": x, "y": y}), b
