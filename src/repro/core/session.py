"""Session — the declarative front-end over the logical-plan layer.

The MADlib user experience is an analyst issuing statements and the
database sharing work across them (§3.2).  A :class:`Session` batches
statements as logical plan nodes, and :meth:`Session.run` plans and
executes them together: independent one-pass statistics over the same
table fold into ONE data pass, grouped statements share ONE partitioning
sort, engines are picked cost-based, and :meth:`Session.explain` shows
the physical plan before (or without) running it::

    sess = Session()
    stats = sess.profile(tbl)
    ols   = sess.linregr(tbl)
    freq  = sess.countmin_sketch(tbl, item_col="item")
    print(sess.explain())         # one shared-scan pass, three statements
    sess.run()
    ols.result().coef

Each statement returns a :class:`Handle`; ``handle.result()`` is
available after ``run()``.  ``run()`` consumes the batch — subsequent
statements start a new one.  Statements with *data dependencies* (e.g.
quantiles' range pass feeding its histogram pass) cannot share a batch;
issue them across two ``run()`` rounds or use the eager method wrappers,
which plan each statement individually.

**Server mode.**  ``Session(server=an_analytics_server)`` swaps the
private batch for the server's *cross-session* admission window
(:mod:`repro.core.server`): every statement submits immediately and
returns an async-style :class:`~repro.core.server.ServerHandle`; the
server fuses/dedups/caches across ALL attached sessions, and
``run()``/``handle.result()`` drain the shared window on demand.
Statements partition into per-table admission windows server-side, and
a server built with ``drain="thread"`` resolves handles in the
background — ``handle.wait()`` then observes results without this
session ever draining anything.  The statement-issuing API is identical
in both modes.
"""

from __future__ import annotations

from typing import Any, Callable

from .plan import (
    GroupedScanAgg, IterativeFit, JoinedGroupedScanAgg, ScanAgg,
    StreamAgg, plan,
)
from .table import Table

_UNSET = object()


class Handle:
    """Deferred result of one session statement."""

    def __init__(self, label: str):
        self.label = label
        self._value: Any = _UNSET
        self._failed = False

    def done(self) -> bool:
        return self._value is not _UNSET

    def result(self) -> Any:
        if self._value is _UNSET:
            if self._failed:
                raise RuntimeError(
                    f"statement {self.label!r} was in a batch whose "
                    "Session.run() raised — the batch was discarded; "
                    "re-issue the statement")
            raise RuntimeError(
                f"statement {self.label!r} has not executed yet — call "
                "Session.run() first")
        return self._value


class _DerivedHandle:
    """Lazy combination of several server handles (server-mode analogue
    of the eagerly-resolved derived Handle): ``result()`` gathers every
    part — draining the shared admission window on demand — and combines
    once."""

    def __init__(self, label: str, parts: list, combine: Callable):
        self.label = label
        self._parts = parts
        self._combine = combine
        self._value: Any = _UNSET

    def done(self) -> bool:
        return (self._value is not _UNSET
                or all(p.done() for p in self._parts))

    def result(self, timeout: float | None = None) -> Any:
        """Gather + combine the parts; ``timeout`` bounds the WHOLE
        gather (one shared deadline across parts, like
        :meth:`ServerHandle.result`)."""
        if self._value is _UNSET:
            if timeout is None:
                vals = [p.result() for p in self._parts]
            else:
                import time as _time
                deadline = _time.monotonic() + timeout
                vals = [p.result(timeout=max(
                    0.0, deadline - _time.monotonic()))
                    for p in self._parts]
            self._value = self._combine(vals)
        return self._value


class Session:
    """Batches logical statements and runs them through the planner —
    or, with ``server=``, submits them to a shared
    :class:`~repro.core.server.AnalyticsServer` admission window."""

    def __init__(self, server=None):
        self.server = server
        self._nodes: list = []
        self._posts: list = []
        self._handles: list = []
        self._derived: list = []
        self._materialized: list = []
        self.last_plan = None

    # -- generic statements ----------------------------------------------
    def statement(self, node, *, post: Callable | None = None) -> Handle:
        """Enqueue a prebuilt logical plan node; ``post`` (optional)
        shapes the raw engine result into the handle's value.  In server
        mode the node is submitted immediately and the returned handle
        resolves when the server's window drains."""
        if node.label is None:
            node.label = f"s{len(self._handles)}"
        if self.server is not None:
            h = self.server.submit(node, post=post, label=node.label)
            self._handles.append(h)
            return h
        h = Handle(node.label)
        self._nodes.append(node)
        self._posts.append(post)
        self._handles.append(h)
        return h

    def scan(self, agg, table: Table, *, columns=None, mask=None,
             block_size=None, engine: str = "auto", jit: bool = True,
             label: str | None = None, post=None) -> Handle:
        return self.statement(
            ScanAgg(agg, table, columns=columns, mask=mask,
                    block_size=block_size, engine=engine, jit=jit,
                    label=label), post=post)

    def grouped_scan(self, agg, table, group_col=None, num_groups=None, *,
                     columns=None, mask=None, block_size=None,
                     method: str = "auto", mesh=None, row_axes=None,
                     jit: bool = True, label=None, post=None) -> Handle:
        return self.statement(
            GroupedScanAgg(agg, table, group_col, num_groups,
                           columns=columns, mask=mask,
                           block_size=block_size, method=method, mesh=mesh,
                           row_axes=row_axes, jit=jit, label=label),
            post=post)

    def joined_grouped_scan(self, agg, join, num_groups=None, *,
                            columns=None, mask=None, block_size=None,
                            method: str = "auto", mesh=None, row_axes=None,
                            jit: bool = True, label=None, post=None
                            ) -> Handle:
        """``SELECT dim.attr, agg(...) FROM fact JOIN dim GROUP BY
        dim.attr`` as one statement; ``join`` is a
        :class:`~repro.core.join.Join`.  Statements over the same star
        triple fuse into ONE pass sharing the sort-merge resolution."""
        return self.statement(
            JoinedGroupedScanAgg(agg, join, num_groups, columns=columns,
                                 mask=mask, block_size=block_size,
                                 method=method, mesh=mesh,
                                 row_axes=row_axes, jit=jit, label=label),
            post=post)

    def fit(self, task, table=None, *, label=None, post=None,
            **kwargs) -> Handle:
        return self.statement(IterativeFit(task, table, label=label,
                                           **kwargs), post=post)

    def stream_scan(self, agg, blocks, *, columns=None, label=None,
                    post=None) -> Handle:
        return self.statement(StreamAgg(agg, blocks, columns=columns,
                                        label=label), post=post)

    # -- living views -------------------------------------------------------
    def materialize(self, *nodes):
        """Retain statement(s) as a living view: the initial fold runs
        NOW (not batched with :meth:`run`), and the returned
        :class:`~repro.core.materialize.MaterializedHandle` delta-folds
        appended rows on every later read — the always-fresh-dashboard
        pattern.  Several compatible statements share one retained scan.
        """
        from .materialize import materialize as _materialize
        h = _materialize(nodes[0] if len(nodes) == 1 else list(nodes))
        self._materialized.append(h)
        if self.server is not None:
            # living views double as cache fillers: matching statements
            # from ANY session are answered from the view's fold state
            self.server.register_view(h)
        return h

    def refresh(self) -> list:
        """Bring every living view issued through :meth:`materialize`
        current with its table and return their results, in issue
        order."""
        return [h.result() for h in self._materialized]

    def _derive(self, parts: list, combine: Callable):
        if self.server is not None:
            h = _DerivedHandle(f"d{len(self._derived)}", parts, combine)
            self._derived.append(h)
            return h
        h = Handle(f"d{len(self._derived)}")
        self._derived.append((h, parts, combine))
        return h

    # -- method sugar (lazy imports: methods build on core) ----------------
    def profile(self, table: Table, *, distinct_counts: bool = False,
                block_size=None, jit: bool = True) -> Handle:
        """All of ``profile``'s statistics as individual statements —
        their fusion into one scan falls out of the optimizer.  The
        eager ``methods.profile.profile`` is a thin wrapper over this."""
        from ..methods.profile import _shape_results, profile_aggregates
        aggs = profile_aggregates(table, distinct_counts=distinct_counts)
        parts = [self.scan(agg, table, block_size=block_size, jit=jit,
                           label=f"profile:{name.strip('_')}")
                 for name, agg in aggs.items()]
        names = list(aggs)
        return self._derive(
            parts, lambda vals: _shape_results(dict(zip(names, vals))))

    def linregr(self, table: Table, *, x_col: str = "x", y_col: str = "y",
                block_size=None, use_kernel: bool | str = False) -> Handle:
        from ..methods.linregr import LinregrAggregate
        return self.scan(LinregrAggregate(use_kernel), table,
                         columns={"x": x_col, "y": y_col},
                         block_size=block_size, label="linregr")

    def naive_bayes(self, table: Table, num_classes: int, *,
                    x_col: str = "x", y_col: str = "y",
                    block_size=None) -> Handle:
        from ..methods.naive_bayes import NaiveBayesAggregate
        return self.scan(NaiveBayesAggregate(num_classes), table,
                         columns={"x": x_col, "y": y_col},
                         block_size=block_size, label="naive_bayes")

    def countmin_sketch(self, table: Table, *, depth: int = 4,
                        width: int = 1024, item_col: str = "item",
                        block_size=None) -> Handle:
        from ..methods.sketches import CountMinAggregate
        return self.scan(
            CountMinAggregate(depth, width, item_col=item_col), table,
            columns=(item_col,), block_size=block_size, label="countmin")

    def fm_distinct_count(self, table: Table, *, num_hashes: int = 8,
                          bits: int = 32, item_col: str = "item",
                          block_size=None) -> Handle:
        from ..methods.sketches import FMAggregate
        return self.scan(FMAggregate(num_hashes, bits, item_col=item_col),
                         table, columns=(item_col,), block_size=block_size,
                         label="fm_distinct")

    def logregr(self, table: Table, *, x_col: str = "x", y_col: str = "y",
                max_iters: int = 30, tol: float = 1e-6, block_size=None
                ) -> Handle:
        from ..methods.logregr import IRLSTask, _result
        t = Table({"x": table[x_col], "y": table[y_col]}, table.mesh,
                  table.row_axes)
        return self.fit(IRLSTask(), t, max_iters=max_iters, tol=tol,
                        block_size=block_size, label="logregr",
                        post=_result)

    # -- planning & execution ----------------------------------------------
    def explain(self) -> str:
        """Render the physical plan for the pending batch (no execution).
        In server mode this renders the server's whole admission window —
        the batch shared across every attached session."""
        if self.server is not None:
            return self.server.explain()
        if not self._nodes:
            return "(empty batch)"
        return plan(self._nodes).explain()

    def run(self) -> list:
        """Plan and execute the pending batch; resolves every handle and
        returns the per-statement results in statement order.  The batch
        is consumed whether or not execution succeeds — a failed batch is
        discarded (its handles stay unresolved), it is never silently
        re-planned alongside the next one.  An empty batch returns
        ``[]``.  In server mode this drains the shared admission window
        and gathers this session's handles."""
        if self.server is not None:
            handles, self._handles = self._handles, []
            derived, self._derived = self._derived, []
            if not handles:
                return []
            self.server.flush()
            out = [h.result() for h in handles]
            for d in derived:
                d.result()
            return out
        if not self._nodes:
            self._derived = []
            return []
        try:
            pl = plan(self._nodes)
            self.last_plan = pl
            results = pl.execute()
            for h, post, res in zip(self._handles, self._posts, results):
                h._value = post(res) if post is not None else res
            for h, parts, combine in self._derived:
                h._value = combine([p.result() for p in parts])
            return [h.result() for h in self._handles]
        finally:
            for h in self._handles + [d for d, _, _ in self._derived]:
                if not h.done():
                    h._failed = True
            self._nodes, self._posts, self._handles = [], [], []
            self._derived = []
