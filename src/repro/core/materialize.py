"""Incremental view maintenance — retained statements as living views.

The paper's parallelization contract (§4.1: every aggregate ships a
merge combinator so partial states from disjoint row sets compose
exactly) is 90% of a materialized view: if a statement's fold state over
rows ``[0:r]`` is retained, bringing it current after an append needs
only the fold over rows ``[r:n]`` and ONE merge — never a rescan.  This
module is that last 10%:

* :class:`MaterializedHandle` pins (table **version**, plan
  **fingerprint**, retained **fold state**) for one or several fused
  scan statements;
* :meth:`MaterializedHandle.refresh` consults :attr:`Table.version` /
  :attr:`Table.epoch`: unchanged version -> no work; append-only growth
  (same epoch) -> **delta fold** of the new rows merged in with the
  members' own combinators (recorded as ``kind="delta"`` in the trace);
  anything else (``invalidate``) -> full rescan;
* exactness: for aggregates whose state arithmetic is exact (integer
  sketches, histogram counts, dyadic-f32 sums) the delta-merged state is
  **bit-identical** to a full rescan — the same associativity argument
  that makes :func:`run_sharded` exact across segments.

Grouped statements maintain stacked ``(G, ...)`` states and merge
group-wise.  A delta whose keys stay inside the pinned group count folds
incrementally; a delta introducing a NEW group id under
``num_groups=None`` semantics falls back to a rescan (the full run would
have grown ``G``).

Statements with a base ``mask`` are rejected loudly: a row filter is
row-aligned with one table version and cannot describe rows that did not
exist when it was built — filter into a derived table instead.

Living views also serve as **cache fillers** for the analytics server
(:meth:`repro.core.server.AnalyticsServer.register_view`, automatic via
``Session.materialize`` on a server-attached session): a submitted
statement whose semantic fingerprint matches a registered view is
answered from the view's retained fold state — delta-refreshed across
appends, still zero scans — instead of re-executing.
"""

from __future__ import annotations

import threading
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from .aggregates import (
    _fused_for, probe_segment_ops, run_grouped, run_local, run_many,
)
from .plan import GroupedScanAgg, ScanAgg, _member_agg, statement_fingerprint
from .table import GroupedView, Table

__all__ = ["MaterializedHandle", "materialize"]


class MaterializedHandle:
    """A living view over one or more fused scan statements.

    Built by :func:`materialize`; the constructor runs the initial full
    fold.  :meth:`result` returns the finalized result(s), refreshing
    first so reads are always current with the pinned table;
    :meth:`refresh` brings the retained state current without
    finalizing and reports HOW (``"noop"`` / ``"delta"`` /
    ``"rescan"``); :meth:`stale` says whether the table moved since the
    last refresh.  Results come back as a single value when built from
    one statement, else a list in statement order.

    Handles are thread-safe: an internal lock serializes
    refresh/result/state reads, so two concurrent server drains (or a
    drain racing a direct dashboard read) cannot interleave a delta
    fold with a rescan or double-fold one append.  The retained state
    transitions atomically from one pinned version to the next.
    """

    def __init__(self, nodes: Sequence, *, single: bool):
        self.nodes = list(nodes)
        self._single = single
        base = self.nodes[0]
        self.kind = "grouped" if isinstance(base, GroupedScanAgg) else "scan"
        self._validate(base)
        self.table: Table = base.table
        self.block_size = base.block_size
        self.jit = base.jit
        self.fingerprint = tuple(statement_fingerprint(n)
                                 for n in self.nodes)
        self.members = [_member_agg(n) for n in self.nodes]
        self.fused = _fused_for(self.members)
        if self.kind == "scan":
            self.engine = base.engine
        else:
            self.group_col = base.group_col
            self.mesh = base.mesh
            self.row_axes = base.row_axes
            self._groups_fixed = base.num_groups is not None
            self._groups_spec = base.num_groups
            self._method = self._resolve_method(base.method)
        # jitted merge/final programs, built lazily and retained with the
        # handle (its prepared statements)
        self._merge_fn = None
        self._final_fn = None
        self._result_cache: Any = None
        # reentrant: result() refreshes under the same lock
        self._state_lock = threading.RLock()
        with self._state_lock:
            self._full_build()

    # -- validation --------------------------------------------------------
    def _validate(self, base) -> None:
        for n in self.nodes:
            if type(n) is not type(base):
                raise TypeError(
                    "materialize: cannot mix scan and grouped statements "
                    "in one handle")
            if not isinstance(n, (ScanAgg, GroupedScanAgg)):
                raise TypeError(
                    f"materialize: not a retainable scan statement: {n!r} "
                    "(fit and stream statements hold no mergeable state)")
            if n.mask is not None:
                raise ValueError(
                    "materialize: masked statements are not supported — a "
                    "base row filter is row-aligned with ONE table version "
                    "and says nothing about appended rows; filter into a "
                    "derived table and materialize that")
            if isinstance(n.table, GroupedView):
                raise TypeError(
                    "materialize: grouped statements must reference the "
                    "Table itself, not a prebuilt GroupedView — a view is "
                    "a snapshot and carries no version to track")
            if n.table is not base.table:
                raise ValueError(
                    "materialize: statements retain state over different "
                    "tables; build one handle per table")
            if (n.block_size, n.jit) != (base.block_size, base.jit):
                raise ValueError("materialize: members disagree on "
                                 "block_size/jit")
        if self.kind == "grouped":
            key = (base.group_col, base.num_groups, base.method,
                   id(base.mesh), base.row_axes)
            for n in self.nodes:
                if (n.group_col, n.num_groups, n.method, id(n.mesh),
                        n.row_axes) != key:
                    raise ValueError(
                        "materialize: grouped members disagree on "
                        "group_col/num_groups/method/mesh/row_axes")
        else:
            if len({n.engine for n in self.nodes}) > 1:
                raise ValueError("materialize: members disagree on engine")

    def _resolve_method(self, method: str) -> str:
        """Pin segment vs masked once — build, rescans and delta folds
        must all take the same path (same state partitioning story)."""
        if method != "auto":
            return method
        data = {k: v for k, v in self.table.columns.items()
                if k != self.group_col}
        for m in self.members:
            try:
                ok = probe_segment_ops(m, data) is not None
            except Exception:
                ok = False
            if not ok:
                return "masked"
        return "segment"

    # -- state building ----------------------------------------------------
    def _pin(self, state, n_rows: int, version: int, epoch: int) -> None:
        # pin the version OBSERVED WHEN THE FOLD WAS DECIDED, never the
        # table's current one: a mutation landing mid-fold must leave the
        # handle stale (the next refresh catches up), not silently pinned
        # at a version whose rows the state never saw
        self._state = state
        self._version = version
        self._epoch = epoch
        self._n_rows = n_rows
        self._result_cache = None

    def _full_build(self) -> None:
        t = self.table
        version, epoch = t.version, t.epoch
        if self.kind == "scan":
            state = run_many(self.members, t, block_size=self.block_size,
                             jit=self.jit, engine=self.engine,
                             finalize=False)
        else:
            G = self._groups_spec
            if G is None:
                gids = t[self.group_col].astype(jnp.int32)
                G = int(jax.device_get(jnp.max(gids))) + 1
            self._G = G
            state = run_grouped(self.fused, t, self.group_col,
                                num_groups=G, block_size=self.block_size,
                                method=self._method, mesh=self.mesh,
                                row_axes=self.row_axes, jit=self.jit,
                                finalize=False)
        self._pin(state, t.n_rows, version, epoch)

    def _delta_fold(self, version: int, epoch: int, n_rows: int) -> bool:
        """Fold ONLY rows ``[pinned:n_rows]`` and merge into the
        retained state; returns False when delta semantics cannot match
        a full rescan (a new group id under open group-count
        semantics).  ``version``/``epoch``/``n_rows`` are the table
        coordinates the caller observed when it decided to delta —
        what the merged state gets pinned at."""
        t = self.table
        delta_cols = {k: v[self._n_rows:n_rows] for k, v in t.columns.items()}
        delta = Table(delta_cols)
        if self.kind == "scan":
            new = run_local(self.fused, delta, block_size=self.block_size,
                            jit=self.jit, finalize=False,
                            trace_kind="delta")
        else:
            G = self._G
            if not self._groups_fixed:
                mx = int(jax.device_get(jnp.max(
                    delta_cols[self.group_col].astype(jnp.int32))))
                if mx >= G:
                    return False  # full run would have grown num_groups
            # The aligned layout pads every group segment to whole blocks,
            # so a small delta folded at the build block size would pay
            # G * block_size padded rows.  Shrink the delta block toward
            # ~1 block per group: exact-state merges are partition-
            # independent, so the merged state stays bit-identical.
            per_g = -(-delta.n_rows // max(G, 1))
            bs = max(64, min(self.block_size or 4096,
                             1 << max(per_g - 1, 0).bit_length()))
            new = run_grouped(self.fused, delta, self.group_col,
                              num_groups=G, block_size=bs,
                              method=self._method, mesh=None, jit=self.jit,
                              finalize=False, trace_kind="delta")
        if self._merge_fn is None:
            fn = self.fused.merge if self.kind == "scan" \
                else jax.vmap(self.fused.merge)
            self._merge_fn = jax.jit(fn) if self.jit else fn
        self._pin(self._merge_fn(self._state, new), n_rows, version, epoch)
        return True

    # -- the living-view API -----------------------------------------------
    @property
    def version(self) -> int:
        """The table version the retained state is pinned at."""
        with self._state_lock:
            return self._version

    def stale(self) -> bool:
        """Has the table mutated since the retained state was pinned?"""
        with self._state_lock:
            return self.table.version != self._version

    def refresh(self) -> str:
        """Bring the retained state current and say how: ``"noop"``
        (already at the pinned version, or an empty append), ``"delta"``
        (pure append — fold ONLY the new rows and merge, zero rescans),
        or ``"rescan"`` (the table was invalidated, or delta semantics
        could not match a full run — the data was re-read in full).
        Callers accounting for scans saved must treat ``"rescan"``
        honestly: the read happened, it just happened in here."""
        with self._state_lock:
            t = self.table
            # one consistent observation of the table's coordinates: the
            # fold decided from it pins exactly these, so a mutation
            # racing the fold leaves the handle honestly stale
            version, epoch, n_rows = t.version, t.epoch, t.n_rows
            if version == self._version:
                return "noop"
            if epoch == self._epoch and n_rows >= self._n_rows:
                if n_rows == self._n_rows:  # empty append
                    self._version = version
                    return "noop"
                if self._delta_fold(version, epoch, n_rows):
                    return "delta"
            self._full_build()
            return "rescan"

    def result(self, *, refresh: bool = True) -> Any:
        """Finalized result(s) at the current table version (refreshing
        first unless ``refresh=False``), cached per pinned state."""
        with self._state_lock:
            if refresh:
                self.refresh()
            if self._result_cache is None:
                if self._final_fn is None:
                    fn = self.fused.final if self.kind == "scan" \
                        else jax.vmap(self.fused.final)
                    self._final_fn = jax.jit(fn) if self.jit else fn
                self._result_cache = self._final_fn(self._state)
            outs = self._result_cache
        return outs[0] if self._single else list(outs)


def materialize(statements) -> MaterializedHandle:
    """Retain one statement (or a compatible batch sharing one scan) as
    a :class:`MaterializedHandle` — the initial fold runs immediately::

        h = materialize(ScanAgg(agg, tbl))
        tbl.append(new_rows)
        h.result()      # delta fold + merge, NOT a rescan

    ``statements`` is a single :class:`~repro.core.plan.ScanAgg` /
    :class:`~repro.core.plan.GroupedScanAgg` or a sequence of them (all
    over the same table; results then come back as a list).
    """
    if isinstance(statements, (ScanAgg, GroupedScanAgg)):
        return MaterializedHandle([statements], single=True)
    nodes = list(statements)
    if not nodes:
        raise ValueError("materialize: empty statement batch")
    return MaterializedHandle(nodes, single=False)
