"""Measured cost calibration — the planner's statistics catalog.

PR 5's cost model ranks engines by a hand-waved rows-moved heuristic;
this module replaces the guesses with MEASUREMENT.  The calibration
harness (``benchmarks/calibrate.py``) micro-benches every
(engine x aggregate class x shape bucket) cell on the current backend,
replays compiled-HLO cost analysis for context, and persists one JSON
file per backend.  When a calibration is ACTIVE, the planner's engine
selection (:mod:`repro.core.plan`) ranks candidates by interpolated
measured seconds instead of heuristic row counts, ``explain()`` renders
``measured <backend>@<timestamp>``, grouped block sizing
(:func:`repro.core.aggregates.segment_block_size`) takes the measured
best block, and kernel ``supports`` rankers read tuned tile parameters
through :func:`kernel_param`.

Activation is NEVER implicit — a calibration file lying on disk changes
nothing.  ``current()`` returns a calibration only when one was
activated programmatically (:func:`use` / :func:`activate`) or named by
the ``MADJAX_CALIBRATION`` environment variable; with none, every
consumer falls back to the PR-5 heuristics unchanged (regression-tested
in ``tests/test_plan.py``).

Lookup model: measurements are bucketed by shape (``rows``, optionally
``groups``).  A query picks the nearest bucket in log2 space and scales
its seconds linearly in rows — a first-order model that preserves the
*ranking* the measurements established, which is all engine selection
consumes.  Aggregate classes fall back to ``"generic"`` when the
specific class (``"xtx"``, ``"sketch"``) was not measured.

This module is deliberately stdlib-only (no jax): it imports into the
bottom of the core layer without cycles.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import os
from typing import Any, Iterator

__all__ = [
    "Calibration", "activate", "current", "deactivate", "kernel_param",
    "load", "save", "use",
]


def _bucket_distance(entry: dict, rows: int, groups: int | None) -> float:
    d = abs(math.log2(max(rows, 1))
            - math.log2(max(int(entry.get("rows", 1)), 1)))
    if groups is not None and entry.get("groups"):
        d += abs(math.log2(max(groups, 1))
                 - math.log2(max(int(entry["groups"]), 1)))
    return d


@dataclasses.dataclass(frozen=True)
class Calibration:
    """One backend's measured cost tables.

    ``engines``: engine key -> aggregate class -> list of bucket entries
    ``{"rows": int, "groups": int?, "seconds": float, ...}`` (extra keys,
    e.g. replayed HLO statistics, are carried but not consumed).
    ``kernels``: kernel name -> tuned parameter dict (tile/block sizes).
    ``grouped_block``: bucket entries ``{"rows", "groups", "block"}`` —
    the measured-best segment block size per shape bucket.
    """

    backend: str
    timestamp: str
    engines: dict[str, dict[str, list]]
    kernels: dict[str, dict[str, Any]]
    grouped_block: list
    source: str | None = None

    @staticmethod
    def from_dict(d: dict, source: str | None = None) -> "Calibration":
        return Calibration(
            backend=str(d.get("backend", "unknown")),
            timestamp=str(d.get("timestamp", "unknown")),
            engines=dict(d.get("engines", {})),
            kernels=dict(d.get("kernels", {})),
            grouped_block=list(d.get("grouped_block", [])),
            source=source,
        )

    def to_dict(self) -> dict:
        return {"backend": self.backend, "timestamp": self.timestamp,
                "engines": self.engines, "kernels": self.kernels,
                "grouped_block": self.grouped_block}

    def engine_seconds(self, engine: str, agg_class: str, rows: int,
                       groups: int | None = None) -> float | None:
        """Interpolated measured seconds for one candidate, or None when
        this calibration has no bucket for it (the caller must then fall
        back to heuristics for ALL candidates — never mix units)."""
        table = self.engines.get(engine)
        if not table:
            return None
        entries = table.get(agg_class) or table.get("generic")
        if not entries:
            return None
        best = min(entries, key=lambda e: _bucket_distance(e, rows, groups))
        base_rows = max(int(best.get("rows", 1)), 1)
        return float(best["seconds"]) * (max(rows, 1) / base_rows)

    def kernel_param(self, kernel: str, param: str):
        return (self.kernels.get(kernel) or {}).get(param)

    def grouped_block_size(self, rows: int, groups: int) -> int | None:
        """Measured-best segment block size for the nearest shape bucket."""
        if not self.grouped_block:
            return None
        best = min(self.grouped_block,
                   key=lambda e: _bucket_distance(e, rows, groups))
        b = best.get("block")
        return None if b is None else int(b)


# ---------------------------------------------------------------------------
# Activation — explicit, stack-scoped, or by environment variable.
# ---------------------------------------------------------------------------

_ACTIVE: list[Calibration] = []
_ENV_CACHE: dict[str, Calibration] = {}


def load(path: str) -> Calibration:
    with open(path) as f:
        return Calibration.from_dict(json.load(f), source=str(path))


def save(cal: Calibration, path: str) -> str:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(cal.to_dict(), f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def activate(cal: Calibration) -> Calibration:
    _ACTIVE.append(cal)
    return cal


def deactivate(cal: Calibration) -> None:
    _ACTIVE.remove(cal)


@contextlib.contextmanager
def use(cal: "Calibration | str") -> Iterator[Calibration]:
    """Scope a calibration (object or JSON path) over a block::

        with calibration.use("benchmarks/calibration/cpu.json"):
            print(explain(statements))   # costs render as measured
    """
    c = load(cal) if isinstance(cal, str) else cal
    activate(c)
    try:
        yield c
    finally:
        deactivate(c)


def current() -> Calibration | None:
    """The active calibration: the innermost :func:`use`/:func:`activate`
    scope, else the ``MADJAX_CALIBRATION`` env file (cached per path),
    else None — heuristics everywhere."""
    if _ACTIVE:
        return _ACTIVE[-1]
    path = os.environ.get("MADJAX_CALIBRATION")
    if not path:
        return None
    hit = _ENV_CACHE.get(path)
    if hit is None:
        hit = load(path)  # loud on a missing/garbled file: explicit opt-in
        _ENV_CACHE[path] = hit
    return hit


def kernel_param(kernel: str, param: str, default=None):
    """Tuned kernel parameter from the active calibration (None/default
    when no calibration is active or the kernel was not tuned) — the
    registry's ``supports`` rankers read tile sizes through this."""
    cal = current()
    if cal is None:
        return default
    v = cal.kernel_param(kernel, param)
    return default if v is None else v
