"""Host-side execution tracing — the plan layer's observability hooks.

The scan-sharing optimizer's whole claim is "N statements, ONE data
pass"; this module is how that claim is *checked* instead of asserted.
Every execution engine records one event per physical data pass
(``kind="scan"``), :meth:`Table.group_by` records one event per
partitioning sort actually performed (``kind="sort"`` — cache hits are
silent), the iterative engines record one event per fit
(``kind="fit"``), and a materialized-handle refresh that folds only
appended rows records its pass as ``kind="delta"`` instead of a scan —
so tests can assert "this refresh did NOT rescan the table".
``tests/test_plan.py`` and ``benchmarks/bench_plan.py`` wrap executions
in :func:`trace_execution` and count.

Events are recorded host-side at engine entry (never inside a traced
function), so the counters see physical engine executions: a fused
``run_many`` pass is ONE scan event regardless of how many member
aggregates it folds, and a masked grouped pass is one event even though
its cost is O(G·n) — the cost difference lives in ``explain()``, the
event count in the trace.

The analytics server (:mod:`repro.core.server`) adds two serving-side
kinds so cross-session sharing is *asserted*, not timed:
``kind="admission"`` — one event per drained admission window, tagged
with its base table (``detail["table"]``), the window size, statements
actually planned (after result-cache hits and same-fingerprint dedup),
physical passes, ``scans_saved`` (scan statements submitted minus scan
passes executed minus any view answers that had to rescan), and the
window's ``opened_at`` / ``drained_at`` monotonic timestamps +
``latency`` — per-table isolation ("a slow drain on table A did not
delay table B") is asserted from these timestamps, never from
wall-clock heuristics; and ``kind="cache_hit"`` — one event per
statement answered from the version-keyed result cache or a registered
materialized view, carrying ``detail["refresh"]`` with the honest
refresh kind (``"none"``/``"noop"``/``"delta"`` cost zero scans;
``"rescan"`` means the view re-read the table inside the hit path).
:meth:`Trace.summary` rolls every kind up into counts, plus a
per-table breakdown of the serving events under ``"by_table"``.

The join layer adds two more kinds.  ``kind="join"`` — one event per
sort-merge key resolution actually performed (:meth:`repro.core.join
.Join.resolve`; memo hits are silent, like ``group_by``), so "N joined
statements shared one resolution" is a trace count.  Every ``sort``
event carries ``detail["table"]`` (the sorting table's id) and
:meth:`Trace.summary` rolls sorts up per table under
``"sorts_by_table"`` — the assertion surface for sort dedup across a
star schema ("the dim key sort and the fact partition sort happened
once EACH"), counted, never timed.  ``kind="cache_reject"`` — one
event per statement the server-side result cache refused to fingerprint
because it reads MORE THAN ONE table (a join): the cache keys on a
single table's version, so caching a join result could serve stale
state after only the dimension mutated; the loud event makes the
refusal observable (see :func:`repro.core.plan.semantic_fingerprint`).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Iterator


@dataclasses.dataclass
class Event:
    kind: str               # "scan" | "sort" | "fit" | "delta" | "kernel"
    #                       | "admission" | "cache_hit" | "join"
    #                       | "cache_reject"
    engine: str | None      # "local" / "sharded" / "grouped-segment" / ...;
    # for kind="kernel" this is the RESOLVED implementation ("ref" /
    # "pallas"), with detail carrying the kernel name and requested impl
    detail: dict[str, Any]


class Trace:
    """An ordered list of engine events, with kind-filtered views."""

    def __init__(self):
        self.events: list[Event] = []

    def _kind(self, kind: str) -> list[Event]:
        return [e for e in self.events if e.kind == kind]

    @property
    def scans(self) -> list[Event]:
        return self._kind("scan")

    @property
    def sorts(self) -> list[Event]:
        return self._kind("sort")

    @property
    def fits(self) -> list[Event]:
        return self._kind("fit")

    @property
    def deltas(self) -> list[Event]:
        return self._kind("delta")

    @property
    def kernels(self) -> list[Event]:
        """Kernel dispatch resolutions — one per physical execution that
        consulted the registry; ``engine`` is the resolved impl."""
        return self._kind("kernel")

    @property
    def admissions(self) -> list[Event]:
        """Admission-window drains — one per drained per-table window
        (however triggered: count threshold, timeout, flush, demand, or
        the background drainer); ``detail`` carries the base table id,
        window size, planned/deduped/cache-hit statement counts,
        ``scans_saved``, and the ``opened_at``/``drained_at``/``latency``
        timestamps isolation assertions are built from."""
        return self._kind("admission")

    @property
    def joins(self) -> list[Event]:
        """Sort-merge join key resolutions actually performed
        (``Join.resolve`` memo misses; hits are silent) — N joined
        statements over one (fact, dim, key) triple record ONE."""
        return self._kind("join")

    @property
    def cache_rejects(self) -> list[Event]:
        """Statements the semantic fingerprint refused to identify for
        the result cache because they read more than one table;
        ``detail["tables"]`` lists the table ids involved."""
        return self._kind("cache_reject")

    @property
    def cache_hits(self) -> list[Event]:
        """Statements answered from the server's version-keyed result
        cache (``detail["source"] == "cache"``) or a registered
        materialized view (``"view"``).  ``detail["refresh"]`` says what
        the answer really cost: ``"none"``/``"noop"``/``"delta"`` cost
        zero physical scans, ``"rescan"`` re-read the table inside the
        hit path."""
        return self._kind("cache_hit")

    def summary(self) -> dict:
        """Counts per event kind, plus the admission windows' aggregate
        sharing tallies (``scans_saved`` / ``deduped`` summed across
        windows) — what benches and serving logs print.  When admission
        events are present, ``out["by_table"]`` breaks the serving
        tallies down per base table (keyed by the admission events'
        ``detail["table"]`` id): windows drained, statements admitted,
        scans saved, dedups and cache hits — the cross-table rollup for
        per-table admission windows."""
        out: dict[str, Any] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        sorts = self._kind("sort")
        if sorts:
            # per-table sort rollup: sort dedup across a star schema
            # ("one argsort per (table, key)") is asserted from these
            # counts, never from timing
            by_sorts: dict[Any, int] = {}
            for e in sorts:
                t = e.detail.get("table")
                by_sorts[t] = by_sorts.get(t, 0) + 1
            out["sorts_by_table"] = by_sorts
        admissions = self._kind("admission")
        for field in ("scans_saved", "deduped"):
            total = sum(e.detail.get(field, 0) for e in admissions)
            if total:
                out[field] = total
        if admissions:
            by: dict[Any, dict[str, int]] = {}
            for e in admissions:
                row = by.setdefault(e.detail.get("table"), {
                    "windows": 0, "statements": 0, "scans_saved": 0,
                    "deduped": 0, "cache_hits": 0})
                row["windows"] += 1
                row["statements"] += e.detail.get("window", 0)
                row["scans_saved"] += e.detail.get("scans_saved", 0)
                row["deduped"] += e.detail.get("deduped", 0)
                row["cache_hits"] += e.detail.get("cache_hits", 0)
            out["by_table"] = by
        return out


_ACTIVE: list[Trace] = []


def record(kind: str, engine: str | None = None, **detail: Any) -> None:
    """Record one event on every active trace (no-op when none are)."""
    for t in _ACTIVE:
        t.events.append(Event(kind, engine, detail))


@contextlib.contextmanager
def trace_execution() -> Iterator[Trace]:
    """Collect engine events for the dynamic extent of the block::

        with trace_execution() as t:
            session.run()
        assert len(t.scans) == 1

    Nestable; every active trace sees every event.
    """
    t = Trace()
    _ACTIVE.append(t)
    try:
        yield t
    finally:
        _ACTIVE.remove(t)
