"""The convex-optimization abstraction — MADlib §5.1 (Wisconsin layer).

Decouples the *model* from the *solver*: a model is a sum-decomposable
objective ``f(w) = Σ_i f_i(w)`` where each table row encodes one ``f_i``;
solvers only see ``loss(params, block, mask)``.  Every Table-2 model
(least squares, lasso, logistic regression, SVM, low-rank recommendation,
CRF labeling) and — per DESIGN.md §3 — the LM train step plug into this
one abstraction.

Solvers provided:

* :func:`gradient_descent` — full-batch GD; the gradient is computed as a
  **user-defined aggregate** (transition = block gradient, merge = sum),
  i.e. the same engine whose speedup the paper measures.
* :func:`sgd` — stochastic gradient descent with Robbins-Monro stepsizes
  (Eq. 1 of the paper), single-shard pass.
* :func:`parallel_sgd` — Zinkevich-style parallelized SGD [47]: each
  segment runs a local SGD pass over its rows, models are averaged with a
  ``pmean`` (a one-round UDA merge).
* :func:`newton` — Newton / IRLS steps with the Hessian accumulated by the
  same UDA pattern (logistic regression §4.2 uses this).
* :func:`conjugate_gradient` — MADlib's CG support module (Table 1), a
  ``lax.while_loop`` over matvecs.

Every solver's convergence loop routes through the unified iterative
executor (:mod:`repro.core.iterative`): GD and Newton are single-pass
tasks (:class:`GradientDescentTask` / :class:`NewtonTask`), SGD epochs
are counted iterations of :class:`SGDEpochTask` — so all of them inherit
the compiled ``lax.while_loop``/``scan`` fast path, sharded execution
(the whole fit inside one ``shard_map`` program) and warm starts, and
``svm`` / ``lasso`` / ``sgd_models`` inherit the executor through
:class:`ConvexProgram` without further changes.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from .aggregates import Aggregate, MERGE_SUM
from .iterative import IterativeTask, fit
from .table import Table, Columns


LossFn = Callable[[Any, Columns, jax.Array], jax.Array]
# loss(params, block, mask) -> scalar SUM of f_i over unmasked rows.


@dataclasses.dataclass
class ConvexProgram:
    """A sum-decomposable objective. ``loss`` must return the *sum* (not
    mean) of per-row losses over the unmasked rows, so that gradients are
    additive across blocks/segments (the UDA merge contract)."""

    loss: LossFn
    regularizer: Callable[[Any], jax.Array] | None = None  # added once, not per row

    def total_loss(self, params, block, mask):
        l = self.loss(params, block, mask)
        if self.regularizer is not None:
            l = l + self.regularizer(params)
        return l


# ---------------------------------------------------------------------------
# Gradient / Hessian accumulation as UDAs.
# ---------------------------------------------------------------------------

class GradientAggregate(Aggregate):
    """transition = add block gradient; merge = sum; final = (grad, loss, n)."""

    merge_ops = MERGE_SUM

    def __init__(self, program: ConvexProgram, params):
        self.program = program
        self.params = params

    def init(self, block):
        zg = jax.tree.map(jnp.zeros_like, self.params)
        return {"grad": zg, "loss": jnp.zeros(()), "n": jnp.zeros((), jnp.int32)}

    def transition(self, state, block, mask):
        loss, grad = jax.value_and_grad(self.program.loss)(self.params, block, mask)
        return {
            "grad": jax.tree.map(jnp.add, state["grad"], grad),
            "loss": state["loss"] + loss,
            "n": state["n"] + jnp.sum(mask.astype(jnp.int32)),
        }


class HessianAggregate(Aggregate):
    """Accumulates gradient and dense Hessian — valid for small parameter
    dimension (the paper's regression setting, where k ≤ a few hundred)."""

    merge_ops = MERGE_SUM

    def __init__(self, program: ConvexProgram, params: jax.Array):
        if jnp.ndim(params) != 1:
            raise ValueError("HessianAggregate expects a flat parameter vector")
        self.program = program
        self.params = params

    def init(self, block):
        d = self.params.shape[0]
        return {
            "grad": jnp.zeros((d,)),
            "hess": jnp.zeros((d, d)),
            "loss": jnp.zeros(()),
            "n": jnp.zeros((), jnp.int32),
        }

    def transition(self, state, block, mask):
        loss, grad = jax.value_and_grad(self.program.loss)(self.params, block, mask)
        hess = jax.hessian(self.program.loss)(self.params, block, mask)
        return {
            "grad": state["grad"] + grad,
            "hess": state["hess"] + hess,
            "loss": state["loss"] + loss,
            "n": state["n"] + jnp.sum(mask.astype(jnp.int32)),
        }


# ---------------------------------------------------------------------------
# Solvers — every convergence loop below routes through the unified
# iterative executor (repro.core.iterative); no solver owns a loop.
# ---------------------------------------------------------------------------

class GradientDescentTask(IterativeTask):
    """Full-batch GD: the per-iteration pass is one GradientAggregate
    execution; the driver step is ``w ← w − α·∇f``."""

    def __init__(self, program: ConvexProgram, params0, stepsize: float,
                 tol: float):
        self.program = program
        self.params0 = params0
        self.stepsize = stepsize
        self.tol = tol

    def init_state(self, columns):
        return {"params": self.params0, "gnorm": jnp.float32(jnp.inf)}

    def make_aggregate(self, state):
        return GradientAggregate(self.program, state["params"])

    def update(self, state, out):
        params = state["params"]
        g = out["grad"]
        if self.program.regularizer is not None:
            g = jax.tree.map(
                jnp.add, g, jax.grad(self.program.regularizer)(params))
        gnorm = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(g)))
        # on convergence the pre-step params are the answer
        stepped = jax.tree.map(
            lambda p, gg: jnp.where(gnorm < self.tol, p,
                                    p - self.stepsize * gg), params, g)
        return {"params": stepped, "gnorm": gnorm}

    def metric(self, prev, new, out):
        return new["gnorm"]

    def trace_record(self, state, out, m):
        return (out["loss"], m)


def gradient_descent(program: ConvexProgram, table: Table, params0,
                     *, stepsize: float = 1e-3, max_iters: int = 100,
                     tol: float = 1e-6, block_size: int | None = None,
                     mode: str = "compiled"):
    """Full-batch GD; each round's gradient is one UDA execution."""
    res = fit(GradientDescentTask(program, params0, stepsize, tol), table,
              max_iters=max_iters, tol=tol, block_size=block_size, mode=mode)
    losses, gnorms = res.trace
    trace = list(zip(np.asarray(losses).tolist(),
                     np.asarray(gnorms).tolist()))
    return res.state["params"], trace, res.converged


class NewtonTask(IterativeTask):
    """Newton / IRLS: Hessian + gradient accumulated by one UDA pass,
    driver step solves ``H δ = g``."""

    def __init__(self, program: ConvexProgram, params0: jax.Array,
                 ridge: float):
        self.program = program
        self.params0 = params0
        self.ridge = ridge

    def init_state(self, columns):
        return {"params": self.params0, "delta": jnp.float32(jnp.inf)}

    def make_aggregate(self, state):
        return HessianAggregate(self.program, state["params"])

    def update(self, state, out):
        params = state["params"]
        g, h = out["grad"], out["hess"]
        if self.program.regularizer is not None:
            g = g + jax.grad(self.program.regularizer)(params)
            h = h + jax.hessian(self.program.regularizer)(params)
        h = h + self.ridge * jnp.eye(h.shape[0])
        step = jnp.linalg.solve(h, g)
        new = params - step
        delta = jnp.linalg.norm(step) / (jnp.linalg.norm(new) + 1e-12)
        return {"params": new, "delta": delta}

    def metric(self, prev, new, out):
        return new["delta"]

    def trace_record(self, state, out, m):
        return (out["loss"], m)


def newton(program: ConvexProgram, table: Table, params0: jax.Array, *,
           max_iters: int = 20, tol: float = 1e-8, ridge: float = 1e-6,
           block_size: int | None = None, mode: str = "compiled"):
    """Newton's method with UDA-accumulated gradient/Hessian (IRLS engine)."""
    res = fit(NewtonTask(program, params0, ridge), table,
              max_iters=max_iters, tol=tol, block_size=block_size, mode=mode)
    losses, deltas = res.trace
    trace = list(zip(np.asarray(losses).tolist(),
                     np.asarray(deltas).tolist()))
    return res.state["params"], trace, res.converged


class SGDEpochTask(IterativeTask):
    """One executor iteration = one SGD epoch (Bismarck's IGD): a shuffled
    pass over the engine-local rows, optionally with Robbins-Monro
    stepsizes (paper Eq. 1, ``anneal=True``).

    SGD is not a pure fold, so this task overrides :meth:`iteration` and
    reads rows through ``run_pass.columns`` (shard-local inside the
    sharded engine).  Zinkevich model averaging [47] happens ONCE after
    all epochs via :meth:`mesh_epilogue` — the one-round mean-merge UDA
    of the paper's §5.1, matching the pre-refactor ``parallel_sgd``."""

    def __init__(self, program: ConvexProgram, params0, stepsize: float,
                 batch: int, key: jax.Array, anneal: bool = True):
        self.program = program
        self.params0 = params0
        self.stepsize = stepsize
        self.batch = batch
        self.key = key
        self.anneal = anneal

    def init_state(self, columns):
        return {"params": self.params0, "epoch": jnp.int32(0),
                "key": self.key}

    def iteration(self, state, run_pass):
        columns = run_pass.columns
        if columns is None:
            raise ValueError("SGDEpochTask needs row access; the stream "
                             "engine cannot shuffle minibatches")
        n = next(iter(columns.values())).shape[0]
        nb = n // self.batch
        key, sub = jax.random.split(state["key"])
        if run_pass.row_axes:
            # decorrelate shards: fold the segment index into the key
            sub = jax.random.fold_in(
                sub, jax.lax.axis_index(run_pass.row_axes))
        alpha = self.stepsize / (1.0 + state["epoch"].astype(jnp.float32)) \
            if self.anneal else jnp.float32(self.stepsize)
        perm = jax.random.permutation(sub, n)[: nb * self.batch] \
            .reshape(nb, self.batch)
        gmask = run_pass.mask

        def body(params, idx):
            block = {k: v[idx] for k, v in columns.items()}
            mask = jnp.ones((self.batch,), jnp.bool_) if gmask is None \
                else gmask[idx]
            g = jax.grad(self.program.total_loss)(params, block, mask)
            return jax.tree.map(
                lambda p, gg: p - alpha * gg / self.batch, params, g), None

        params, _ = jax.lax.scan(body, state["params"], perm)
        new = {"params": params, "epoch": state["epoch"] + 1, "key": key}
        return new, jnp.zeros(()), jnp.float32(jnp.inf)

    def mesh_epilogue(self, state, row_axes):
        # model averaging = one-round mean-merge UDA, after all epochs
        return {**state, "params": jax.tree.map(
            lambda p: jax.lax.pmean(p, row_axes), state["params"])}


def sgd(program: ConvexProgram, table: Table, params0, *, stepsize: float = 1e-2,
        epochs: int = 1, batch: int = 64, key: jax.Array | None = None,
        anneal: bool = True):
    """Single-shard SGD with Robbins-Monro annealing (paper Eq. 1).

    Epochs run as counted executor iterations — the whole fit is one
    compiled ``lax.scan`` over epochs of (shuffle, gather, grad, update)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    task = SGDEpochTask(program, params0, stepsize, batch, key, anneal)
    res = fit(task, table, max_iters=epochs, tol=None, engine="local")
    return res.state["params"]


def parallel_sgd(program: ConvexProgram, table: Table, params0, *,
                 stepsize: float = 1e-2, epochs: int = 1, batch: int = 64,
                 mesh: Mesh | None = None, row_axes=("data",),
                 key: jax.Array | None = None):
    """Zinkevich model-averaging SGD [47]: each segment runs its local
    epochs (constant stepsize, as pre-refactor), then models are averaged
    ONCE with a pmean — the whole fit compiled inside ONE shard_map
    program via the executor's counted mode + mesh epilogue."""
    mesh = mesh or table.mesh
    if mesh is None:
        return sgd(program, table, params0, stepsize=stepsize, epochs=epochs,
                   batch=batch, key=key)
    key = key if key is not None else jax.random.PRNGKey(0)
    task = SGDEpochTask(program, params0, stepsize, batch, key, anneal=False)
    res = fit(task, table, max_iters=epochs, tol=None, engine="sharded",
              mesh=mesh, row_axes=tuple(row_axes or table.row_axes))
    return res.state["params"]


def conjugate_gradient(matvec: Callable[[jax.Array], jax.Array], b: jax.Array,
                       x0: jax.Array | None = None, *, tol: float = 1e-8,
                       max_iters: int | None = None):
    """MADlib's conjugate-gradient support module: solve A x = b for SPD A
    given only ``matvec`` — fully on-device ``lax.while_loop``."""
    n = b.shape[0]
    max_iters = max_iters or 2 * n
    x0 = jnp.zeros_like(b) if x0 is None else x0

    def cond(c):
        _, r, _, rs, i = c
        return jnp.logical_and(i < max_iters, rs > tol * tol)

    def body(c):
        x, r, p, rs, i = c
        ap = matvec(p)
        alpha = rs / (jnp.vdot(p, ap) + 1e-30)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.vdot(r, r).real
        p = r + (rs_new / (rs + 1e-30)) * p
        return x, r, p, rs_new, i + 1

    r0 = b - matvec(x0)
    rs0 = jnp.vdot(r0, r0).real
    x, r, p, rs, i = jax.lax.while_loop(cond, body, (x0, r0, r0, rs0, jnp.int32(0)))
    return x, jnp.sqrt(rs), i
