"""The convex-optimization abstraction — MADlib §5.1 (Wisconsin layer).

Decouples the *model* from the *solver*: a model is a sum-decomposable
objective ``f(w) = Σ_i f_i(w)`` where each table row encodes one ``f_i``;
solvers only see ``loss(params, block, mask)``.  Every Table-2 model
(least squares, lasso, logistic regression, SVM, low-rank recommendation,
CRF labeling) and — per DESIGN.md §3 — the LM train step plug into this
one abstraction.

Solvers provided:

* :func:`gradient_descent` — full-batch GD; the gradient is computed as a
  **user-defined aggregate** (transition = block gradient, merge = sum),
  i.e. the same engine whose speedup the paper measures.
* :func:`sgd` — stochastic gradient descent with Robbins-Monro stepsizes
  (Eq. 1 of the paper), single-shard pass.
* :func:`parallel_sgd` — Zinkevich-style parallelized SGD [47]: each
  segment runs a local SGD pass over its rows, models are averaged with a
  ``pmean`` (a one-round UDA merge).
* :func:`newton` — Newton / IRLS steps with the Hessian accumulated by the
  same UDA pattern (logistic regression §4.2 uses this).
* :func:`conjugate_gradient` — MADlib's CG support module (Table 1), a
  ``lax.while_loop`` over matvecs.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .aggregates import Aggregate, MERGE_SUM, run_sharded, run_local
from .compat import shard_map as _compat_shard_map
from .table import Table, Columns


LossFn = Callable[[Any, Columns, jax.Array], jax.Array]
# loss(params, block, mask) -> scalar SUM of f_i over unmasked rows.


@dataclasses.dataclass
class ConvexProgram:
    """A sum-decomposable objective. ``loss`` must return the *sum* (not
    mean) of per-row losses over the unmasked rows, so that gradients are
    additive across blocks/segments (the UDA merge contract)."""

    loss: LossFn
    regularizer: Callable[[Any], jax.Array] | None = None  # added once, not per row

    def total_loss(self, params, block, mask):
        l = self.loss(params, block, mask)
        if self.regularizer is not None:
            l = l + self.regularizer(params)
        return l


# ---------------------------------------------------------------------------
# Gradient / Hessian accumulation as UDAs.
# ---------------------------------------------------------------------------

class GradientAggregate(Aggregate):
    """transition = add block gradient; merge = sum; final = (grad, loss, n)."""

    merge_ops = MERGE_SUM

    def __init__(self, program: ConvexProgram, params):
        self.program = program
        self.params = params

    def init(self, block):
        zg = jax.tree.map(jnp.zeros_like, self.params)
        return {"grad": zg, "loss": jnp.zeros(()), "n": jnp.zeros((), jnp.int32)}

    def transition(self, state, block, mask):
        loss, grad = jax.value_and_grad(self.program.loss)(self.params, block, mask)
        return {
            "grad": jax.tree.map(jnp.add, state["grad"], grad),
            "loss": state["loss"] + loss,
            "n": state["n"] + jnp.sum(mask.astype(jnp.int32)),
        }


class HessianAggregate(Aggregate):
    """Accumulates gradient and dense Hessian — valid for small parameter
    dimension (the paper's regression setting, where k ≤ a few hundred)."""

    merge_ops = MERGE_SUM

    def __init__(self, program: ConvexProgram, params: jax.Array):
        if jnp.ndim(params) != 1:
            raise ValueError("HessianAggregate expects a flat parameter vector")
        self.program = program
        self.params = params

    def init(self, block):
        d = self.params.shape[0]
        return {
            "grad": jnp.zeros((d,)),
            "hess": jnp.zeros((d, d)),
            "loss": jnp.zeros(()),
            "n": jnp.zeros((), jnp.int32),
        }

    def transition(self, state, block, mask):
        loss, grad = jax.value_and_grad(self.program.loss)(self.params, block, mask)
        hess = jax.hessian(self.program.loss)(self.params, block, mask)
        return {
            "grad": state["grad"] + grad,
            "hess": state["hess"] + hess,
            "loss": state["loss"] + loss,
            "n": state["n"] + jnp.sum(mask.astype(jnp.int32)),
        }


def _run(agg, table, block_size):
    if table.mesh is not None:
        return run_sharded(agg, table, block_size=block_size)
    return run_local(agg, table, block_size=block_size)


# ---------------------------------------------------------------------------
# Solvers.
# ---------------------------------------------------------------------------

def gradient_descent(program: ConvexProgram, table: Table, params0,
                     *, stepsize: float = 1e-3, max_iters: int = 100,
                     tol: float = 1e-6, block_size: int | None = None):
    """Full-batch GD; each round's gradient is one UDA execution."""
    params = params0
    trace = []
    for it in range(1, max_iters + 1):
        out = _run(GradientAggregate(program, params), table, block_size)
        g = out["grad"]
        if program.regularizer is not None:
            g = jax.tree.map(
                jnp.add, g, jax.grad(program.regularizer)(params)
            )
        gnorm = float(
            jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(g)))
        )
        trace.append((float(out["loss"]), gnorm))
        if gnorm < tol:
            return params, trace, True
        params = jax.tree.map(lambda p, gg: p - stepsize * gg, params, g)
    return params, trace, False


def sgd(program: ConvexProgram, table: Table, params0, *, stepsize: float = 1e-2,
        epochs: int = 1, batch: int = 64, key: jax.Array | None = None,
        anneal: bool = True):
    """Single-shard SGD with Robbins-Monro annealing (paper Eq. 1).

    The per-step update runs as one fused jit (shuffle indices on host,
    gather + grad + update on device)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    n = table.n_rows
    nb = n // batch

    @jax.jit
    def epoch_fn(params, perm, alpha):
        def body(carry, idx):
            params = carry
            block = {k: v[idx] for k, v in table.columns.items()}
            mask = jnp.ones((batch,), jnp.bool_)
            g = jax.grad(program.total_loss)(params, block, mask)
            params = jax.tree.map(lambda p, gg: p - alpha * gg / batch, params, g)
            return params, None

        idxs = perm[: nb * batch].reshape(nb, batch)
        params, _ = jax.lax.scan(body, params, idxs)
        return params

    params = params0
    for e in range(epochs):
        key, sub = jax.random.split(key)
        perm = jax.random.permutation(sub, n)
        alpha = stepsize / (1.0 + e) if anneal else stepsize
        params = epoch_fn(params, perm, alpha)
    return params


def parallel_sgd(program: ConvexProgram, table: Table, params0, *,
                 stepsize: float = 1e-2, epochs: int = 1, batch: int = 64,
                 mesh: Mesh | None = None, row_axes=("data",),
                 key: jax.Array | None = None):
    """Zinkevich model-averaging SGD [47]: local passes + pmean merge."""
    mesh = mesh or table.mesh
    if mesh is None:
        return sgd(program, table, params0, stepsize=stepsize, epochs=epochs,
                   batch=batch, key=key)
    row_axes = tuple(row_axes or table.row_axes)
    in_spec = jax.tree.map(
        lambda v: P(row_axes, *([None] * (v.ndim - 1))), dict(table.columns)
    )

    def shard_fn(columns, params, key):
        n = next(iter(columns.values())).shape[0]
        # decorrelate shards: fold the shard index into the key
        idx = jax.lax.axis_index(row_axes)
        key = jax.random.fold_in(key, idx)
        nb = n // batch

        def epoch(params, ekey):
            perm = jax.random.permutation(ekey, n)[: nb * batch].reshape(nb, batch)

            def body(params, idx):
                block = {k: v[idx] for k, v in columns.items()}
                mask = jnp.ones((batch,), jnp.bool_)
                g = jax.grad(program.total_loss)(params, block, mask)
                return jax.tree.map(lambda p, gg: p - stepsize * gg / batch,
                                    params, g), None

            params, _ = jax.lax.scan(body, params, perm)
            return params, None

        params, _ = jax.lax.scan(epoch, params, jax.random.split(key, epochs))
        # model averaging = one-round mean-merge UDA
        return jax.tree.map(lambda p: jax.lax.pmean(p, row_axes), params)

    fn = jax.jit(_compat_shard_map(
        shard_fn, mesh=mesh,
        in_specs=(in_spec, P(), P()),
        out_specs=P(), check_vma=False,
    ))
    key = key if key is not None else jax.random.PRNGKey(0)
    return fn(dict(table.columns), params0, key)


def newton(program: ConvexProgram, table: Table, params0: jax.Array, *,
           max_iters: int = 20, tol: float = 1e-8, ridge: float = 1e-6,
           block_size: int | None = None):
    """Newton's method with UDA-accumulated gradient/Hessian (IRLS engine)."""
    params = params0
    trace = []
    for it in range(1, max_iters + 1):
        out = _run(HessianAggregate(program, params), table, block_size)
        g, h = out["grad"], out["hess"]
        if program.regularizer is not None:
            g = g + jax.grad(program.regularizer)(params)
            h = h + jax.hessian(program.regularizer)(params)
        h = h + ridge * jnp.eye(h.shape[0])
        step = jnp.linalg.solve(h, g)
        params = params - step
        delta = float(jnp.linalg.norm(step) / (jnp.linalg.norm(params) + 1e-12))
        trace.append((float(out["loss"]), delta))
        if delta < tol:
            return params, trace, True
    return params, trace, False


def conjugate_gradient(matvec: Callable[[jax.Array], jax.Array], b: jax.Array,
                       x0: jax.Array | None = None, *, tol: float = 1e-8,
                       max_iters: int | None = None):
    """MADlib's conjugate-gradient support module: solve A x = b for SPD A
    given only ``matvec`` — fully on-device ``lax.while_loop``."""
    n = b.shape[0]
    max_iters = max_iters or 2 * n
    x0 = jnp.zeros_like(b) if x0 is None else x0

    def cond(c):
        _, r, _, rs, i = c
        return jnp.logical_and(i < max_iters, rs > tol * tol)

    def body(c):
        x, r, p, rs, i = c
        ap = matvec(p)
        alpha = rs / (jnp.vdot(p, ap) + 1e-30)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.vdot(r, r).real
        p = r + (rs_new / (rs + 1e-30)) * p
        return x, r, p, rs_new, i + 1

    r0 = b - matvec(x0)
    rs0 = jnp.vdot(r0, r0).real
    x, r, p, rs, i = jax.lax.while_loop(cond, body, (x0, r0, r0, rs0, jnp.int32(0)))
    return x, jnp.sqrt(rs), i
