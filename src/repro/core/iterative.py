"""Unified iterative executor — ONE driver loop for every multipass method.

MADlib's §3.1.2 driver pattern (a state-resident outer loop around a bulk
UDA inner pass) used to be reimplemented per method: ``logregr`` IRLS,
``kmeans`` Lloyd, ``lda`` EM and the ``convex`` solvers each hand-rolled
their own convergence loop.  Following Feng et al.'s *Towards a Unified
Architecture for in-RDBMS Analytics* (Bismarck), they all fit one harness:

    state_0 = init ;  repeat:  agg_out = ONE shared scan (a UDA pass)
                               state   = update(state, agg_out)   # driver
                               m       = metric(...)              # scalar
              until m < tol or max_iters

The **task contract** is :class:`IterativeTask`:

* ``init_state(columns)``   — driver-side model state (small, device-resident)
* ``make_aggregate(state)`` — the per-iteration UDA pass, any
  :class:`~repro.core.aggregates.Aggregate` (use ``FusedAggregate`` to fold
  several statistics in the same scan)
* ``update(state, agg_out)``— the driver-side step (solve, renormalize, …)
* ``metric(prev, new, agg_out)`` — scalar convergence criterion (< tol stops)
* ``finalize(state, agg_out)``   — shape the last state/pass into the result
* ``trace_record(state, agg_out, m)`` — small per-iteration record (traced)

Tasks whose iteration is not a single pure scan (two-pass k-means, SGD
epochs) override :meth:`IterativeTask.iteration` instead and call the
supplied ``run_pass`` runner as many times as their dataflow needs — the
controller still owns the loop, the engines and convergence.

**One controller, four engines.**  :func:`fit` executes any task

* locally (single shard, blocked ``lax.scan`` fold),
* sharded (the whole loop lives inside ONE ``shard_map`` program: local
  fold → ``psum``-family merge → replicated update, per iteration — zero
  host round-trips across the entire fit),
* streaming (:func:`fit_stream`: each iteration re-folds a host-side
  block stream with donated device state — the out-of-core path), and
* grouped (:func:`fit_grouped`: ``GROUP BY`` model fitting — one model
  per group, every iteration a shared scan over the whole table with
  per-group masks, converged groups frozen).

``mode="compiled"`` (default) turns the loop into a single
``lax.while_loop`` (or ``lax.scan`` when ``tol=None`` — fixed-count
iteration); ``mode="host"`` keeps a Python loop that pulls one scalar per
round (the paper-faithful driver, useful for debugging and for streams).
New methods should register a task here instead of writing loops:
``grep "for it in range" src/repro/methods`` is expected to stay empty.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..distributed.sharding import row_pspec
from .aggregates import (
    Aggregate, _blocked_fold, _collective_leaf, probe_segment_ops,
    run_local, run_sharded, run_stream, segment_block_size,
    segment_block_update,
)
from .compat import shard_map as _compat_shard_map
from .table import Table, Columns
from .trace import record as _record


def relative_change(prev, new) -> jax.Array:
    """Default convergence metric: ||new - prev|| / (||prev|| + eps)."""
    dn = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda p, n: jnp.sum((n - p) ** 2), prev, new),
    )
    pn = jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda p: jnp.sum(p ** 2), prev)
    )
    return jnp.sqrt(dn) / (jnp.sqrt(pn) + 1e-12)


# ---------------------------------------------------------------------------
# Pass runners — how one UDA pass executes under each engine.
# ---------------------------------------------------------------------------

class PassRunner:
    """Executes ONE shared scan inside a compiled engine.

    ``columns``/``mask`` expose the engine-local rows to tasks that are not
    pure folds (e.g. SGD epochs, which gather shuffled minibatches);
    ``row_axes`` is non-empty exactly when running inside ``shard_map`` —
    such tasks must merge their own state across segments (``pmean``/...).
    """

    def __init__(self, columns: Columns, mask=None,
                 block_size: int | None = None,
                 row_axes: tuple[str, ...] = ()):
        self.columns = columns
        self.mask = mask
        self.block_size = block_size
        self.row_axes = tuple(row_axes)

    def __call__(self, agg: Aggregate):
        local = _blocked_fold(agg, self.columns, self.mask, self.block_size)
        if self.row_axes:
            local = agg.mesh_merge(local, self.row_axes)
        return agg.final(local)


class _EagerRunner:
    """Host-mode runner: one jitted engine call per pass (run_local /
    run_sharded pick the engine from the table's distribution)."""

    row_axes: tuple[str, ...] = ()

    def __init__(self, table: Table, mask=None, block_size: int | None = None):
        self.table = table
        self.columns = dict(table.columns)
        self.mask = mask
        self.block_size = block_size

    def __call__(self, agg: Aggregate):
        if self.table.mesh is not None:
            return run_sharded(agg, self.table, block_size=self.block_size,
                               mask=self.mask)
        return run_local(agg, self.table, block_size=self.block_size,
                         mask=self.mask)


class _StreamRunner:
    """Each pass re-folds a fresh block stream; state stays on device."""

    row_axes: tuple[str, ...] = ()
    columns = None
    mask = None

    def __init__(self, blocks_factory: Callable[[], Iterable[Columns]]):
        self.blocks_factory = blocks_factory

    def __call__(self, agg: Aggregate):
        return run_stream(agg, self.blocks_factory())


# ---------------------------------------------------------------------------
# The task protocol.
# ---------------------------------------------------------------------------

class IterativeTask:
    """Base class for iterative fits (see module docstring for the contract).

    Subclasses implement ``init_state`` / ``make_aggregate`` / ``update``
    (and usually ``metric`` / ``finalize``); tasks whose iteration is not a
    single scan override :meth:`iteration`.
    """

    def init_state(self, columns: Columns) -> Any:
        raise NotImplementedError

    def make_aggregate(self, state) -> Aggregate:
        raise NotImplementedError

    def update(self, state, agg_out) -> Any:
        raise NotImplementedError

    def metric(self, prev_state, new_state, agg_out) -> jax.Array:
        return relative_change(prev_state, new_state)

    def finalize(self, state, agg_out) -> Any:
        return state

    def trace_record(self, state, agg_out, metric) -> Any:
        return metric

    def mesh_epilogue(self, state, row_axes: tuple[str, ...]) -> Any:
        """Sharded-engine hook, applied once after the loop (still inside
        ``shard_map``): bring a per-segment final state to a replicated
        one.  Identity for tasks whose carry is already replicated (every
        pure-UDA task); tasks that defer their cross-segment merge (e.g.
        one-shot model averaging) override this."""
        return state

    def iteration(self, state, run_pass) -> tuple[Any, Any, jax.Array]:
        """One driver round: (new_state, agg_out, metric).  Override for
        multi-statement iterations; call ``run_pass(aggregate)`` once per
        data pass your dataflow needs."""
        out = run_pass(self.make_aggregate(state))
        new = self.update(state, out)
        return new, out, self.metric(state, new, out)


@dataclasses.dataclass
class FitResult:
    """Outcome of an iterative fit.

    ``state`` is the final driver state, ``result`` is
    ``task.finalize(state, last agg_out)``.  ``trace`` is the pytree of
    stacked per-iteration :meth:`IterativeTask.trace_record` values (leading
    axis = iterations actually run; for grouped fits the group axis leads).
    ``n_iters``/``converged`` are scalars — per-group vectors for
    :func:`fit_grouped`.  ``stats`` carries engine diagnostics (grouped
    fits record the layout, per-round active-row counts and total row
    blocks scanned); None for engines that report nothing.
    """

    state: Any
    result: Any
    n_iters: Any
    converged: Any
    trace: Any
    stats: Any = None


# ---------------------------------------------------------------------------
# Compiled loop bodies (absorbing core/driver.py's engines).
# ---------------------------------------------------------------------------

def _zeros_of(struct):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), struct)


def _cast_like(tree, struct):
    return jax.tree.map(lambda x, s: jnp.asarray(x, s.dtype), tree, struct)


def _make_iter_fn(task: IterativeTask, runner):
    def iter_fn(state):
        new, aux, m = task.iteration(state, runner)
        rec = task.trace_record(new, aux, m)
        return new, aux, jnp.asarray(m, jnp.float32), rec
    return iter_fn


def _while_fit(iter_fn, state0, max_iters: int, tol: float):
    """``lax.while_loop`` fast path: the convergence test is part of the
    compiled program (data-dependent stopping, zero host round-trips)."""
    state0 = jax.tree.map(jnp.asarray, state0)
    state_s = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state0)
    _, aux_s, _, rec_s = jax.eval_shape(iter_fn, state0)
    trace0 = jax.tree.map(
        lambda s: jnp.zeros((max_iters,) + s.shape, s.dtype), rec_s)

    def cond(c):
        _, _, i, m, _ = c
        return jnp.logical_and(i < max_iters, m >= tol)

    def body(c):
        state, _, i, _, trace = c
        new, aux, m, rec = iter_fn(state)
        trace = jax.tree.map(lambda t, r: t.at[i].set(r), trace,
                             _cast_like(rec, rec_s))
        return (_cast_like(new, state_s), _cast_like(aux, aux_s), i + 1, m,
                trace)

    init = (state0, _zeros_of(aux_s), jnp.int32(0), jnp.float32(jnp.inf),
            trace0)
    return jax.lax.while_loop(cond, body, init)


def _scan_fit(iter_fn, state0, n_iters: int):
    """``lax.scan`` fast path for fixed-count iteration (``tol=None``)."""
    state0 = jax.tree.map(jnp.asarray, state0)
    state_s = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state0)
    _, aux_s, _, rec_s = jax.eval_shape(iter_fn, state0)

    def step(carry, _):
        state, _ = carry
        new, aux, m, rec = iter_fn(state)
        return (_cast_like(new, state_s), _cast_like(aux, aux_s)), \
            _cast_like(rec, rec_s)

    (state, aux), trace = jax.lax.scan(
        step, (state0, _zeros_of(aux_s)), None, length=n_iters)
    return state, aux, jnp.int32(n_iters), jnp.float32(jnp.inf), trace


# ---------------------------------------------------------------------------
# The controller.
# ---------------------------------------------------------------------------

def fit(task: IterativeTask, table: Table, *, max_iters: int = 100,
        tol: float | None = 1e-6, engine: str = "auto",
        mode: str = "compiled", block_size: int | None = None,
        mask: jax.Array | None = None, warm_start: Any = None,
        mesh=None, row_axes=None, jit: bool = True) -> FitResult:
    """Execute an :class:`IterativeTask` to convergence on one engine.

    ``engine``: "auto" (sharded iff the table is distributed), "local", or
    "sharded".  ``tol=None`` runs exactly ``max_iters`` rounds (``lax.scan``).
    ``warm_start`` seeds the driver state (skips ``task.init_state``).
    """
    if engine not in ("auto", "local", "sharded"):
        raise ValueError(f"unknown engine {engine!r} (use 'auto', 'local' "
                         "or 'sharded'; streaming goes through fit_stream)")
    columns = dict(table.columns)
    mesh = mesh if mesh is not None else table.mesh
    row_axes = tuple(row_axes or table.row_axes or ("data",))
    if engine == "auto":
        engine = "sharded" if mesh is not None else "local"
    if engine == "sharded" and mesh is None:
        engine = "local"

    state0 = warm_start if warm_start is not None else task.init_state(columns)
    state0 = jax.tree.map(jnp.asarray, state0)
    _record("fit", engine=engine, mode=mode)

    if mode == "host":
        return _fit_host(task, table, mask, state0, block_size, max_iters,
                         tol)
    if mode != "compiled":
        raise ValueError(f"unknown mode {mode!r}")

    if engine == "local":
        def go(columns, mask, state0):
            runner = PassRunner(columns, mask, block_size)
            iter_fn = _make_iter_fn(task, runner)
            if tol is None:
                return _scan_fit(iter_fn, state0, max_iters)
            return _while_fit(iter_fn, state0, max_iters, tol)

        fn = jax.jit(go) if jit else go
        state, aux, n, m, trace = fn(columns, mask, state0)
    else:
        in_spec = jax.tree.map(
            lambda v: row_pspec(row_axes, v.ndim), columns)
        mask_arr = jnp.ones((table.n_rows,), jnp.bool_) if mask is None \
            else jnp.asarray(mask)

        def shard_fn(columns, mask, state0):
            runner = PassRunner(columns, mask, block_size, row_axes)
            iter_fn = _make_iter_fn(task, runner)
            if tol is None:
                out = _scan_fit(iter_fn, state0, max_iters)
            else:
                out = _while_fit(iter_fn, state0, max_iters, tol)
            state, aux, n, m, trace = out
            return task.mesh_epilogue(state, row_axes), aux, n, m, trace

        mapped = _compat_shard_map(
            shard_fn, mesh=mesh,
            in_specs=(in_spec, row_pspec(row_axes), P()),
            out_specs=P(), check_vma=False)
        fn = jax.jit(mapped) if jit else mapped
        state, aux, n, m, trace = fn(columns, mask_arr, state0)

    result = task.finalize(state, aux)
    n = int(n)
    converged = False if tol is None else bool(m < tol)
    trace = jax.tree.map(lambda t: np.asarray(t[:n]), trace)
    return FitResult(state, result, n, converged, trace)


def _host_loop(task, runner, state0, max_iters, tol) -> FitResult:
    """Paper-faithful host driver: one engine call per pass, one scalar
    (the metric) pulled to the host per round."""
    state = state0
    aux = None
    recs = []
    converged = False
    n = 0
    for n in range(1, max_iters + 1):
        state, aux, m = task.iteration(state, runner)
        recs.append(task.trace_record(state, aux, m))
        if tol is not None and float(m) < tol:
            converged = True
            break
    trace = jax.tree.map(lambda *xs: np.asarray(jnp.stack(xs)), *recs)
    return FitResult(state, task.finalize(state, aux), n, converged, trace)


def _fit_host(task, table, mask, state0, block_size, max_iters, tol):
    return _host_loop(task, _EagerRunner(table, mask, block_size), state0,
                      max_iters, tol)


def fit_stream(task: IterativeTask,
               blocks_factory: Callable[[], Iterable[Columns]], *,
               max_iters: int = 100, tol: float | None = 1e-6,
               warm_start: Any = None) -> FitResult:
    """Out-of-core iteration: every round streams the blocks produced by a
    fresh ``blocks_factory()`` through :func:`run_stream` (device-resident
    fold state), so only one block is ever materialized on device."""
    if warm_start is not None:
        state0 = jax.tree.map(jnp.asarray, warm_start)
    else:
        try:
            first = next(iter(blocks_factory()))
        except StopIteration:
            raise ValueError("fit_stream: blocks_factory() produced no "
                             "blocks — at least one block is required to "
                             "shape the driver state") from None
        state0 = jax.tree.map(
            jnp.asarray,
            task.init_state({k: jnp.asarray(v) for k, v in first.items()}))
    _record("fit", engine="stream")
    return _host_loop(task, _StreamRunner(blocks_factory), state0,
                      max_iters, tol)


# ---------------------------------------------------------------------------
# GROUP BY model fitting — one model per group, shared scans.
# ---------------------------------------------------------------------------

def fit_grouped(task: IterativeTask, table: Table, key_col: str,
                num_groups: int | None = None, *, max_iters: int = 100,
                tol: float | None = 1e-6, block_size: int | None = None,
                mask: jax.Array | None = None, warm_start: Any = None,
                layout: str = "auto", mesh=None, row_axes=None,
                jit: bool = True) -> FitResult:
    """Fit one model per group of ``key_col`` — MADlib's ``GROUP BY``
    model fitting (the paper's grouped linregr, §4.1) generalized to every
    registered task.

    Two layouts share the controller:

    * ``layout="segment"`` — the partitioned grouped-scan core: rows are
      permuted into group-aligned blocks once (:meth:`Table.group_by` +
      ``aligned_blocks``; each block holds rows of exactly one group);
      every round gather-compacts the blocks of still-ACTIVE groups and
      folds only those through the task's real block transition, segment-
      merging each block state into its group's accumulator.  Per-round
      cost is O(active rows), so the tail of a skewed-convergence fit
      tracks the groups still iterating instead of G full-table scans.
      Requires the task's default single-scan ``iteration`` and leaf-wise
      merge combinators.
    * ``layout="masked"`` — the fallback (multi-statement ``iteration``
      overrides, generic-merge aggregates): every round vmaps the task's
      pass over per-group validity masks against the full table (O(G·n)).

    ``layout="auto"`` picks segment whenever the task supports it.
    Converged groups are frozen under both layouts.  Returns a
    :class:`FitResult` whose ``state``/``result``/``trace`` carry a
    leading group axis, whose ``n_iters``/``converged`` are per-group
    vectors, and whose ``stats`` records the layout plus (segment) the
    per-round active-row counts and total blocks scanned.  ``warm_start``,
    when given, must already be stacked per group.

    ``mesh`` (defaulting to the table's) runs the WHOLE frozen-group
    driver loop inside one ``shard_map`` program on the segment layout:
    the group-aligned blocks are chunked across the mesh's row axes, each
    round every segment gather-compacts and folds its LOCAL still-active
    blocks, per-group partial states merge with the aggregate's leaf
    combinator collectives, and the replicated driver update / freezing /
    active-row trace proceed exactly as locally — zero host round-trips
    across the fit.  The masked layout ignores ``mesh`` and executes as
    one jit program over the (possibly distributed) rows.
    """
    cols = dict(table.columns)
    gids = cols.pop(key_col).astype(jnp.int32)
    if num_groups is None:
        num_groups = int(jax.device_get(jnp.max(gids))) + 1
    G = num_groups
    if mesh is None:
        mesh = table.mesh
    row_axes = tuple(row_axes or table.row_axes or ("data",))

    if warm_start is not None:
        states0 = jax.tree.map(jnp.asarray, warm_start)
    else:
        s0 = jax.tree.map(jnp.asarray, task.init_state(cols))
        states0 = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (G,) + x.shape), s0)

    if layout == "auto":
        layout = "segment" if _segment_task_ok(task, states0, cols) \
            else "masked"
    _record("fit", engine=f"grouped-{layout}", sharded=mesh is not None,
            groups=G)
    if layout == "segment":
        return _fit_grouped_segment(task, table, key_col, G, states0,
                                    max_iters, tol, block_size, mask, jit,
                                    mesh=mesh, row_axes=row_axes)
    if layout != "masked":
        raise ValueError(f"unknown layout {layout!r} "
                         "(use 'auto', 'segment' or 'masked')")
    return _fit_grouped_masked(task, cols, gids, G, states0, max_iters,
                               tol, block_size, mask, jit)


def _segment_task_ok(task: IterativeTask, states0, cols) -> bool:
    """Segment layout needs the default single-scan iteration (multi-
    statement rounds drive the pass runner themselves) and an aggregate
    with leaf-wise merge combinators."""
    if type(task).iteration is not IterativeTask.iteration:
        return False
    try:
        agg = task.make_aggregate(jax.tree.map(lambda x: x[0], states0))
        return probe_segment_ops(agg, cols) is not None
    except Exception:
        return False


def _fit_grouped_masked(task, cols, gids, G, states0, max_iters, tol,
                        block_size, mask, jit_):
    """Masked-vmap fallback: every group folds the full table per round."""
    base_mask = mask if mask is not None \
        else jnp.ones((next(iter(cols.values())).shape[0],), jnp.bool_)
    eff_tol = jnp.float32(jnp.inf if tol is None else tol)

    def go(cols, gids, base_mask, states0):
        groups = jnp.arange(G)

        def per_group(g, s):
            runner = PassRunner(cols, (gids == g) & base_mask, block_size)
            new, aux, m = task.iteration(s, runner)
            rec = task.trace_record(new, aux, m)
            return new, aux, jnp.asarray(m, jnp.float32), rec

        vfn = jax.vmap(per_group, in_axes=(0, 0))
        state_s = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), states0)
        _, aux_s, _, rec_s = jax.eval_shape(vfn, groups, states0)
        trace0 = jax.tree.map(
            lambda s: jnp.zeros((s.shape[0], max_iters) + s.shape[1:],
                                s.dtype), rec_s)

        def cond(c):
            _, _, i, m_vec, _, _ = c
            return jnp.logical_and(i < max_iters, jnp.any(m_vec >= eff_tol))

        def body(c):
            states, aux, i, m_vec, it_vec, trace = c
            active = m_vec >= eff_tol

            def sel(n_, o_):
                act = active.reshape((G,) + (1,) * (n_.ndim - 1))
                return jnp.where(act, n_, o_)

            new, aux_new, m_new, rec = vfn(groups, states)
            states = jax.tree.map(sel, _cast_like(new, state_s), states)
            aux = jax.tree.map(sel, _cast_like(aux_new, aux_s), aux)
            trace = jax.tree.map(
                lambda t, r: t.at[:, i].set(
                    jnp.where(active.reshape((G,) + (1,) * (r.ndim - 1)),
                              r, t[:, i])),
                trace, _cast_like(rec, rec_s))
            if tol is not None:  # counted mode keeps every group active
                m_vec = jnp.where(active, m_new, m_vec)
            it_vec = it_vec + active.astype(jnp.int32)
            return states, aux, i + 1, m_vec, it_vec, trace

        init = (states0, _zeros_of(aux_s), jnp.int32(0),
                jnp.full((G,), jnp.inf, jnp.float32),
                jnp.zeros((G,), jnp.int32), trace0)
        states, aux, _, m_vec, it_vec, trace = jax.lax.while_loop(
            cond, body, init)
        results = jax.vmap(task.finalize)(states, aux)
        return states, results, m_vec, it_vec, trace

    fn = jax.jit(go) if jit_ else go
    states, results, m_vec, it_vec, trace = fn(cols, gids, base_mask, states0)
    n_iters = np.asarray(it_vec)
    converged = np.zeros((G,), bool) if tol is None \
        else np.asarray(m_vec) < tol
    # per-group traces, truncated to the longest-running group
    n_max = int(n_iters.max()) if G else 0
    trace = jax.tree.map(lambda t: np.asarray(t[:, :n_max]), trace)
    return FitResult(states, results, n_iters, converged, trace,
                     {"layout": "masked"})


def _fit_grouped_segment(task, table, key_col, G, states0, max_iters, tol,
                         block_size, mask, jit_, mesh=None, row_axes=()):
    """Partitioned layout: one segment scan over the gather-compacted
    blocks of still-active groups per round.  With ``mesh`` the same loop
    runs inside ONE ``shard_map`` program: every segment owns a chunk of
    whole blocks, compacts/folds its local active ones, and the per-group
    partials merge with the leaf combinator collectives before the
    (replicated) driver update."""
    if type(task).iteration is not IterativeTask.iteration:
        raise ValueError("fit_grouped: layout='segment' requires the "
                         "default single-scan iteration(); multi-statement "
                         "tasks need layout='masked'")
    view = table.group_by(key_col, G)
    n = view.n_rows

    agg0 = task.make_aggregate(jax.tree.map(lambda x: x[0], states0))
    ops = probe_segment_ops(agg0, dict(view.table.columns))
    if ops is None:
        raise ValueError("fit_grouped: layout='segment' needs leaf-wise "
                         "merge combinators; use layout='masked'")

    # Group-aligned blocked layout, built once: each block holds rows of
    # exactly one group, so a round gather-compacts whole blocks.
    pmask = None if mask is None else view.permute(mask)
    bs = segment_block_size(n, G, block_size)
    if mesh is not None:
        row_axes = tuple(row_axes)
        cols, valid, bgids = view.sharded_blocks(mesh, row_axes, bs, pmask)
    else:
        row_axes = ()
        cols, valid, bgids = view.aligned_blocks(bs, pmask)
    # real global block count for stats: sentinel padding blocks (gid G,
    # added only to divide the segment count) are not scannable work
    NB = int(jax.device_get(jnp.sum(bgids < G)))
    counts = view.counts
    eff_tol = jnp.float32(jnp.inf if tol is None else tol)

    def go(cols, valid, bgids, counts, states0):
        nbl = bgids.shape[0]  # engine-local block count (= NB locally)

        def round_core(states, active):
            """One driver round over the compacted local blocks of active
            groups."""
            # sentinel gid G marks sharding-padding blocks: the appended
            # False keeps them out of every round's compaction
            act_ext = jnp.concatenate(
                [active, jnp.zeros((1,), active.dtype)])
            act_blk = act_ext[bgids] if nbl else jnp.zeros((0,), jnp.bool_)
            nb = jnp.sum(act_blk.astype(jnp.int32))
            m_rows = jnp.sum(counts * active.astype(jnp.int32))
            # gather-compact: indices of active blocks, packed to the front
            pos = jnp.cumsum(act_blk.astype(jnp.int32)) - 1
            blk_idx = jnp.zeros((max(nbl, 1),), jnp.int32).at[
                jnp.where(act_blk, pos, nbl)
            ].set(jnp.arange(nbl, dtype=jnp.int32), mode="drop")

            inits = jax.vmap(
                lambda s: task.make_aggregate(s).init(cols))(states)

            def blk_body(carry):
                b, acc = carry
                j = blk_idx[b]
                blk = jax.tree.map(
                    lambda c: jax.lax.dynamic_slice_in_dim(c, j * bs, bs),
                    cols)
                bm = jax.lax.dynamic_slice_in_dim(valid, j * bs, bs)
                g = bgids[j]
                acc = segment_block_update(task.make_aggregate, states,
                                           ops, blk, bm, g, acc)
                return b + 1, acc

            _, merged = jax.lax.while_loop(
                lambda c: c[0] < nb, blk_body, (jnp.int32(0), inits))
            if row_axes:
                # second-phase aggregation: per-group partials -> global
                merged = jax.tree.map(
                    partial(_collective_leaf, axes=row_axes), ops, merged)

            def g_post(s, agg_state):
                a = task.make_aggregate(s)
                out = a.final(agg_state)
                new = task.update(s, out)
                mm = task.metric(s, new, out)
                return new, out, jnp.asarray(mm, jnp.float32), \
                    task.trace_record(new, out, mm)

            new, aux, m_new, rec = jax.vmap(g_post)(states, merged)
            return new, aux, m_new, rec, m_rows, nb

        state_s = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), states0)
        _, aux_s, _, rec_s, *_ = jax.eval_shape(
            round_core, states0, jnp.ones((G,), jnp.bool_))
        trace0 = jax.tree.map(
            lambda s: jnp.zeros((s.shape[0], max_iters) + s.shape[1:],
                                s.dtype), rec_s)

        def cond(c):
            i, m_vec = c[2], c[3]
            return jnp.logical_and(i < max_iters, jnp.any(m_vec >= eff_tol))

        def body(c):
            states, aux, i, m_vec, it_vec, trace, blk_tot, act_tr = c
            active = m_vec >= eff_tol
            new, aux_new, m_new, rec, m_rows, nb = round_core(states, active)

            def sel(n_, o_):
                act = active.reshape((G,) + (1,) * (n_.ndim - 1))
                return jnp.where(act, n_, o_)

            states = jax.tree.map(sel, _cast_like(new, state_s), states)
            aux = jax.tree.map(sel, _cast_like(aux_new, aux_s), aux)
            trace = jax.tree.map(
                lambda t, r: t.at[:, i].set(
                    jnp.where(active.reshape((G,) + (1,) * (r.ndim - 1)),
                              r, t[:, i])),
                trace, _cast_like(rec, rec_s))
            if tol is not None:  # counted mode keeps every group active
                m_vec = jnp.where(active, m_new, m_vec)
            it_vec = it_vec + active.astype(jnp.int32)
            return (states, aux, i + 1, m_vec, it_vec, trace,
                    blk_tot + nb, act_tr.at[i].set(m_rows))

        init = (states0, _zeros_of(aux_s), jnp.int32(0),
                jnp.full((G,), jnp.inf, jnp.float32),
                jnp.zeros((G,), jnp.int32), trace0, jnp.int32(0),
                jnp.zeros((max_iters,), jnp.int32))
        states, aux, n_rounds, m_vec, it_vec, trace, blk_tot, act_tr = \
            jax.lax.while_loop(cond, body, init)
        if row_axes:  # total blocks actually folded, across all segments
            blk_tot = jax.lax.psum(blk_tot, row_axes)
        results = jax.vmap(task.finalize)(states, aux)
        return (states, results, m_vec, it_vec, trace, n_rounds, blk_tot,
                act_tr)

    if mesh is not None:
        col_spec = jax.tree.map(
            lambda v: row_pspec(row_axes, v.ndim), cols)
        go = _compat_shard_map(
            go, mesh=mesh,
            in_specs=(col_spec, row_pspec(row_axes), row_pspec(row_axes),
                      P(), P()),
            out_specs=P(), check_vma=False)
    fn = jax.jit(go) if jit_ else go
    (states, results, m_vec, it_vec, trace, n_rounds, blk_tot, act_tr) = fn(
        cols, valid, bgids, counts, states0)
    n_iters = np.asarray(it_vec)
    converged = np.zeros((G,), bool) if tol is None \
        else np.asarray(m_vec) < tol
    # per-group traces, truncated to the longest-running group
    n_max = int(n_iters.max()) if G else 0
    trace = jax.tree.map(lambda t: np.asarray(t[:, :n_max]), trace)
    n_rounds = int(n_rounds)
    stats = {
        "layout": "segment",
        "sharded": mesh is not None,
        "block_size": bs,
        "rounds": n_rounds,
        "blocks": int(blk_tot),
        "blocks_full_scan": n_rounds * NB,
        "active_rows": np.asarray(act_tr)[:n_rounds],
    }
    return FitResult(states, results, n_iters, converged, trace, stats)
