"""Device-side sort-merge equi-join — star-schema GROUP BY without
materializing the joined table.

The canonical in-database analytics workload (MADlib's own motivating
setting) is a fact table joined to a small dimension and aggregated by a
dimension attribute::

    SELECT dim.attr, agg(fact.cols...)
    FROM fact JOIN dim ON fact.fk = dim.key
    GROUP BY dim.attr

Feng et al.'s unified-architecture bet applies here too: the join must
FEED the existing aggregate/segment machinery, not sidestep it with a
gathered copy of the dimension's columns on every fact row (which
doubles memory traffic and breaks scan fusion).  So a :class:`Join` is
resolved to exactly ONE new column — a fact-aligned ``int32`` group-id
vector — and everything downstream is the unchanged grouped core:

* the dimension side pays ONE memoized stable argsort of its key column
  (:meth:`Table.sort_permutation` — shared with any GROUP BY over the
  same key);
* fact foreign keys are ``searchsorted`` against the sorted dimension
  keys (device-side sort-merge key resolution); the matched row's
  ``attr`` value IS the group id, so duplicate attr values across
  dimension rows collapse into one group exactly like SQL's
  ``GROUP BY dim.attr``;
* dangling foreign keys follow the explicit ``on_missing=`` policy:
  ``"error"`` raises loudly with the dangling count, ``"drop"`` assigns
  the sentinel id ``-1`` — out of range for every segment by
  :meth:`Table.group_by`'s documented semantics, so dropped rows vanish
  from every group without a separate mask;
* duplicate dimension KEYS are always rejected loudly (an equi-join
  against a non-unique key is a fan-out, not a dimension lookup);
* the resolved ``fact + gid`` table routes straight into
  ``run_grouped`` / ``fit_grouped``, bit-identical to a
  materialize-then-aggregate oracle for exact-state aggregates (same
  gid sequence -> same stable partition permutation -> same blocked
  fold).

Resolution is memoized per ``(fact, dim, fact_key, dim_key, attr_col,
on_missing)`` and stamped with BOTH tables' versions, so every joined
statement over one star triple shares one resolution — and through the
shared joined table, one fact-side partitioning sort.  On a distributed
fact the dimension's sorted key/attr columns are replicated across the
mesh (:func:`repro.distributed.sharding.replicate`) while fact blocks
stay row-sharded, so the sharded grouped engine works unchanged.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .table import Table
from .trace import record

__all__ = ["Join", "JoinResolution", "JOIN_GID_COL"]

# The resolved group-id column spliced onto the fact table.  Internal to
# the join layer: methods never reference it (CI enforces this) — they
# hand a Join to the plan layer and the grouped core sees an ordinary
# integer group column.
JOIN_GID_COL = "__join_gid__"

_ON_MISSING = ("error", "drop")


@dataclasses.dataclass(frozen=True)
class JoinResolution:
    """Outcome of resolving a :class:`Join`: the fact table extended with
    the fact-aligned group-id column (``table[gid_col]``), ready for
    ``group_by(gid_col, num_groups)``.  ``dangling`` counts fact rows
    whose foreign key matched no dimension row (only ever non-zero under
    ``on_missing="drop"``)."""

    table: Table
    gid_col: str
    num_groups: int
    dangling: int


@dataclasses.dataclass(eq=False)
class Join:
    """Logical equi-join spec: ``fact JOIN dim ON fact[fact_key] ==
    dim[dim_key]``, grouping by the dimension attribute ``attr_col``
    (an integer column on ``dim``, the usual group-id contract).

    A Join is cheap to construct and carries no device state; the work
    happens in :meth:`resolve`, which is memoized across Join instances
    — two Joins over the same ``(fact, dim, keys, attr, on_missing)``
    share one resolution, which is what lets the planner fuse joined
    statements built independently by different sessions.
    """

    fact: Table
    dim: Table
    fact_key: str
    dim_key: str
    attr_col: str
    on_missing: str = "error"   # "error" | "drop"

    def __post_init__(self):
        if self.on_missing not in _ON_MISSING:
            raise ValueError(
                f"Join: on_missing={self.on_missing!r} — expected one of "
                f"{_ON_MISSING} (an implicit policy for dangling foreign "
                f"keys would silently change results)")
        for table, col, side in ((self.fact, self.fact_key, "fact"),
                                 (self.dim, self.dim_key, "dim"),
                                 (self.dim, self.attr_col, "dim")):
            if col not in table.columns:
                raise KeyError(
                    f"Join: column {col!r} not on the {side} table "
                    f"(has {sorted(table.columns)})")

    # -- identity ----------------------------------------------------------
    def spec_key(self) -> tuple:
        """Fusion/memo identity: two Joins with equal spec keys resolve
        to the same joined table (tables by object identity, like every
        plan-layer fusion key)."""
        return (id(self.fact), id(self.dim), self.fact_key, self.dim_key,
                self.attr_col, self.on_missing)

    def attr_groups(self) -> int:
        """Group count of the join's GROUP BY: ``max(dim.attr) + 1``
        (0 for an empty dimension).  Cheap — the dimension is small —
        and safe to call at plan/explain time without resolving."""
        if self.dim.n_rows == 0:
            return 0
        attr = self.dim[self.attr_col].astype(jnp.int32)
        return int(jax.device_get(jnp.max(attr))) + 1

    # -- resolution --------------------------------------------------------
    def resolve(self) -> JoinResolution:
        """Sort-merge key resolution, memoized on both tables' versions.

        Returns the fact table extended with ONE ``int32`` column
        (:data:`JOIN_GID_COL`): each fact row's matched dimension row's
        ``attr`` value, or ``-1`` for a dangling key under
        ``on_missing="drop"``.  The dimension's columns are never
        gathered onto fact rows.  A memo miss records one ``kind="join"``
        trace event; hits are silent — "the resolution is shared" is
        asserted from these counts, never from timing.
        """
        key = self.spec_key()
        hit = _RESOLUTIONS.get(key)
        if hit is not None and hit[0] is self.fact and hit[1] is self.dim \
                and hit[2] == self.fact.version \
                and hit[3] == self.dim.version:
            return hit[4]
        res = self._resolve_uncached()
        if len(_RESOLUTIONS) >= _RESOLUTIONS_MAX:
            _RESOLUTIONS.pop(next(iter(_RESOLUTIONS)))
        # pin both tables so their ids cannot be recycled into this key
        _RESOLUTIONS[key] = (self.fact, self.dim, self.fact.version,
                             self.dim.version, res)
        return res

    def _resolve_uncached(self) -> JoinResolution:
        n_fact, n_dim = self.fact.n_rows, self.dim.n_rows
        record("join", fact=id(self.fact), dim=id(self.dim),
               fact_rows=n_fact, dim_rows=n_dim,
               on=f"{self.fact_key}={self.dim_key}", attr=self.attr_col)
        fk = self.fact[self.fact_key]
        if n_dim == 0:
            if self.on_missing == "error":
                raise ValueError(
                    f"Join: empty dimension — every foreign key of "
                    f"{self.fact_key!r} is dangling ({n_fact} rows); "
                    "use on_missing='drop' to aggregate over no groups")
            gids = jnp.full((n_fact,), -1, jnp.int32)
            return self._finish(gids, num_groups=0, dangling=n_fact)

        # One shared argsort of the dimension key (the group_by memo's
        # sort, if anyone grouped the dimension by this key already).
        sorted_keys, perm = self.dim.sort_permutation(self.dim_key)
        if n_dim > 1 and bool(jax.device_get(
                jnp.any(sorted_keys[1:] == sorted_keys[:-1]))):
            raise ValueError(
                f"Join: duplicate keys in dim[{self.dim_key!r}] — an "
                "equi-join against a non-unique dimension key is a "
                "fan-out, not a dimension lookup; deduplicate the "
                "dimension first")
        sorted_attr = self.dim[self.attr_col][perm].astype(jnp.int32)
        num_groups = int(jax.device_get(sorted_attr.max())) + 1

        if self.fact.mesh is not None:
            # Broadcast side of the star: the small sorted key/attr
            # arrays replicate across the fact's mesh, fact foreign keys
            # stay row-sharded — the searchsorted/gather below then
            # needs no cross-device data movement for fact rows.
            from ..distributed.sharding import replicate
            sorted_keys = replicate(self.fact.mesh, sorted_keys)
            sorted_attr = replicate(self.fact.mesh, sorted_attr)

        pos = jnp.clip(jnp.searchsorted(sorted_keys, fk), 0, n_dim - 1)
        matched = sorted_keys[pos] == fk
        dangling = int(jax.device_get(jnp.sum(~matched)))
        if dangling and self.on_missing == "error":
            raise ValueError(
                f"Join: {dangling} of {n_fact} fact rows have foreign "
                f"keys ({self.fact_key!r}) matching no dim[{self.dim_key!r}] "
                "row; fix the data or pass on_missing='drop' to exclude "
                "them from every group")
        gids = jnp.where(matched, sorted_attr[pos], jnp.int32(-1))
        return self._finish(gids, num_groups=num_groups, dangling=dangling)

    def _finish(self, gids: jax.Array, *, num_groups: int, dangling: int
                ) -> JoinResolution:
        # with_column re-places the gid column with the fact's row
        # sharding and returns a FRESH table (empty memo caches), so the
        # joined table's own partitioning sort is shared by every
        # statement that reaches it through the resolution memo.
        joined = self.fact.with_column(JOIN_GID_COL, gids)
        return JoinResolution(joined, JOIN_GID_COL, num_groups, dangling)


# spec key -> (fact, dim, fact_version, dim_version, JoinResolution).
# Module-level (Joins are throwaway specs; the memo must outlive them),
# bounded FIFO, entries pin their tables exactly like plan._PROJECTED_CACHE
# pins its aggregates.
_RESOLUTIONS: dict[tuple, tuple] = {}
_RESOLUTIONS_MAX = 64
