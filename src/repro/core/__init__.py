"""MADJAX core: the paper's contribution as composable JAX modules.

The stack is declarative-over-unified (§3.2; Feng et al.): methods emit
**logical plan nodes** (:mod:`repro.core.plan` — ``ScanAgg``,
``GroupedScanAgg``, ``JoinedGroupedScanAgg``, ``IterativeFit``,
``StreamAgg``) and the planner fuses compatible statements into shared
scans, dedups partitioning sorts through the memoized
``Table.group_by`` (and, one level down, ``Table.sort_permutation`` —
the hoisted argsort that GROUP BY and the star-schema join layer
share), and picks engines cost-based from the capability matrix
(``ENGINE_CAPS``, below) — ``explain()`` renders the chosen physical
plan like ``EXPLAIN``.  Star-schema workloads go through
:class:`~repro.core.join.Join` (:mod:`repro.core.join`): a device-side
sort-merge equi-join resolves ``fact JOIN dim GROUP BY dim.attr`` to a
single fact-aligned group-id column feeding the unchanged grouped
core — the dimension is never materialized onto fact rows.  :class:`Session`
is the analyst front-end: batch statements, explain, run.  Retained
statements become *living views* (:func:`materialize` /
``Session.materialize``): a :class:`MaterializedHandle` pins the table
version and fold state, and appends (``Table.append``) refresh by
delta-folding only the new rows with the aggregates' own merge
combinators — bit-identical to a rescan for exact-state aggregates.
:class:`AnalyticsServer` (:mod:`repro.core.server`) lifts all of this
across *sessions*: many ``Session(server=...)`` submitters share one
admission window, compatible statements from different analysts fuse
into ONE physical pass, identical statements deduplicate, and a
version-keyed result cache answers repeats with zero scans.

- Table          — sharded pytree-of-columns (macro-programming substrate)
- Aggregate      — the (init, transition, merge, final) UDA pattern
- FusedAggregate / run_many — shared-scan execution: N heterogeneous
  aggregates (mixed merge combinators, including generic-merge) packed
  into one state pytree and folded in ONE data pass.  ``run_many`` picks
  the engine (local vs sharded) from the table's sharding; use it whenever
  several statistics are wanted from the same table — e.g. ``profile``
  computes every column's summary AND every FM distinct-count in a single
  scan.  Amortizing data movement across aggregates is the paper's §4.1
  two-phase speedup argument applied one level up.

The engine matrix — every workload is (execution engine) x (pass shape):

  ============  =========================  ===============================
  engine        one-pass (Aggregate)       iterative (IterativeTask)
  ============  =========================  ===============================
  local         run_local                  fit(engine="local")
  sharded       run_sharded                fit(engine="sharded")
  stream        run_stream                 fit_stream
  grouped       run_grouped                fit_grouped
  ============  =========================  ===============================

Engine capabilities — which cross-cutting features each engine honors
(``mask=`` is a base row filter applied at the fold level; ``group_by``
means stacked per-group output; ``fit`` is iterative driving; ``stream``
is out-of-core block iteration).  The same matrix is exported as data
(``ENGINE_CAPS``) and is what the planner filters candidate engines
through before costing them:

  ===============  =====  ========  ==================  ======
  engine           mask   group_by  fit                 stream
  ===============  =====  ========  ==================  ======
  local            yes    —         fit("local")        —
  sharded          yes    —         fit("sharded")      —
  stream           —      —         fit_stream          yes
  grouped-segment  yes    yes       fit_grouped         —
  grouped-masked   yes    yes       fit_grouped         —
  sharded-grouped  yes    yes       fit_grouped(mesh=)  —
  ===============  =====  ========  ==================  ======

  (``fit_grouped(mesh=)`` requires the segment layout; the masked layout
  ignores ``mesh`` and runs as one jit program.)

- local: single-shard blocked ``lax.scan`` fold (PostgreSQL mode).
- sharded: ``shard_map`` over the mesh's row axes — local fold, then the
  merge-combinator collective (Greenplum segments; for iterative fits the
  WHOLE loop lives inside one shard_map program).
- stream: host-side block iterator with donated device state (the
  out-of-core path); empty streams raise ValueError.
- grouped: the partitioned grouped-scan core.  ``Table.group_by`` sorts
  rows by group id ONCE into a ``GroupedView`` (contiguous segments +
  boundaries); ``aligned_blocks`` pads each segment to whole row blocks
  so each block holds exactly one group, and ``segment_fold`` folds ALL
  groups in a single O(n) blocked scan, segment-merging each block state
  into its group's accumulator with the aggregate's own merge combinators
  (``Aggregate.segment_ops``).  ``fit_grouped`` additionally
  gather-compacts the blocks of still-active groups every round, so
  skewed-convergence tails cost O(active rows) instead of G full scans.
  Generic-merge aggregates and multi-statement tasks fall back to the
  masked-vmap path (O(G·n), exact for any mask-honoring aggregate).
- sharded-grouped (``run_grouped(mesh=)`` / ``fit_grouped(mesh=)``,
  defaulting to the table's mesh): MADlib's two-phase GROUP BY across the
  mesh — the group-aligned blocks are chunked whole across the row axes
  (``GroupedView.sharded_blocks``), every segment runs the real block
  transition locally and the G per-segment partial states merge with each
  leaf's combinator collective: one data pass, G x num_segments partial
  states, bit-identical to the local segment fold for exact-state
  aggregates.  Generic-merge aggregates take a sharded masked path (local
  masked folds + all-gather generic merge).  ``fit_grouped(mesh=)`` runs
  the whole frozen-group driver loop inside ONE shard_map program with
  the active-row trace preserved in ``FitResult.stats``.

- IterativeTask + fit / fit_grouped / fit_stream — the unified iterative
  executor (§3.1.2 driver pattern, Bismarck-style): ONE controller loop
  runs any registered task on all four engines, with a compiled
  ``lax.while_loop``/``scan`` fast path, warm starts, and per-group
  (GROUP BY) model fitting.  logregr / linregr / kmeans / lda and the
  convex solvers are all tasks; new iterative methods must register a
  task instead of hand-rolling a convergence loop.
- host_driver / device_driver / counted_driver — step-function iteration
  (no table scan), delegating to the executor's loop engines
- ConvexProgram + solvers — the §5.1 model/solver decoupling

Kernel hot paths are resolved through :mod:`repro.kernels.registry`: each
kernel registers a (ref, pallas) implementation pair and call sites
dispatch by name with backend/shape-aware selection (compiled Pallas on
TPU, jnp reference elsewhere, interpret-mode Pallas on request).
"""

from .table import (
    GroupedView,
    Table,
    synthetic_classification_table,
    synthetic_regression_table,
)
from .aggregates import (
    Aggregate,
    FusedAggregate,
    MERGE_MAX,
    MERGE_MIN,
    MERGE_SUM,
    run_grouped,
    run_local,
    run_many,
    run_sharded,
    run_stream,
    segment_fold,
)
from .iterative import (
    FitResult,
    IterativeTask,
    fit,
    fit_grouped,
    fit_stream,
    relative_change,
)
from .driver import (
    IterationResult,
    counted_driver,
    device_driver,
    host_driver,
)
from .convex import (
    ConvexProgram,
    GradientAggregate,
    HessianAggregate,
    conjugate_gradient,
    gradient_descent,
    newton,
    parallel_sgd,
    sgd,
)
from .templates import ProfileAggregate, map_columns, one_hot_encode
from .join import Join, JoinResolution
from .plan import (
    ENGINE_CAPS,
    GroupedScanAgg,
    IterativeFit,
    JoinedGroupedScanAgg,
    PhysicalPlan,
    ScanAgg,
    StreamAgg,
    execute,
    explain,
    plan,
)
from .materialize import MaterializedHandle, materialize
from .server import AnalyticsServer, ServerHandle
from .session import Handle, Session
from .trace import Trace, trace_execution

__all__ = [
    "ENGINE_CAPS", "ScanAgg", "GroupedScanAgg", "JoinedGroupedScanAgg",
    "IterativeFit", "StreamAgg", "PhysicalPlan", "plan", "execute",
    "explain", "Join", "JoinResolution",
    "Session", "Handle", "Trace", "trace_execution",
    "MaterializedHandle", "materialize",
    "AnalyticsServer", "ServerHandle",
    "Table", "GroupedView", "Aggregate", "FusedAggregate", "MERGE_SUM",
    "MERGE_MAX", "MERGE_MIN",
    "run_local", "run_sharded", "run_stream", "run_grouped", "run_many",
    "segment_fold",
    "IterativeTask", "FitResult", "fit", "fit_grouped", "fit_stream",
    "IterationResult", "host_driver", "device_driver", "counted_driver",
    "relative_change", "ConvexProgram", "GradientAggregate",
    "HessianAggregate", "gradient_descent", "sgd", "parallel_sgd", "newton",
    "conjugate_gradient", "ProfileAggregate", "map_columns", "one_hot_encode",
    "synthetic_regression_table", "synthetic_classification_table",
]
