"""MADJAX core: the paper's contribution as composable JAX modules.

- Table          — sharded pytree-of-columns (macro-programming substrate)
- Aggregate      — the (init, transition, merge, final) UDA pattern
- run_local / run_sharded / run_stream / run_grouped — execution engines
- host_driver / device_driver / counted_driver — multipass iteration
- ConvexProgram + solvers — the §5.1 model/solver decoupling
"""

from .table import (
    Table,
    synthetic_classification_table,
    synthetic_regression_table,
)
from .aggregates import (
    Aggregate,
    MERGE_MAX,
    MERGE_MIN,
    MERGE_SUM,
    run_grouped,
    run_local,
    run_sharded,
    run_stream,
)
from .driver import (
    IterationResult,
    counted_driver,
    device_driver,
    host_driver,
    relative_change,
)
from .convex import (
    ConvexProgram,
    GradientAggregate,
    HessianAggregate,
    conjugate_gradient,
    gradient_descent,
    newton,
    parallel_sgd,
    sgd,
)
from .templates import ProfileAggregate, map_columns, one_hot_encode

__all__ = [
    "Table", "Aggregate", "MERGE_SUM", "MERGE_MAX", "MERGE_MIN",
    "run_local", "run_sharded", "run_stream", "run_grouped",
    "IterationResult", "host_driver", "device_driver", "counted_driver",
    "relative_change", "ConvexProgram", "GradientAggregate",
    "HessianAggregate", "gradient_descent", "sgd", "parallel_sgd", "newton",
    "conjugate_gradient", "ProfileAggregate", "map_columns", "one_hot_encode",
    "synthetic_regression_table", "synthetic_classification_table",
]
