"""Pallas TPU kernel: blocked accumulation of X^T X and X^T y.

The paper's own perf story (§4.4) is three generations of exactly this
loop: v0.1 nested-loop outer products, v0.2 untuned BLAS doing the wrong
rank-1 form (y^T y 3-4x slower than x x^T), v0.3 Eigen rank-1 symmetric
updates.  On a TPU the correct form is the **rank-TILE update**: stream
row tiles of X through VMEM and issue (K, TILE_N) @ (TILE_N, K) MXU
contractions into a persistent (K, K) VMEM accumulator.

Grid: 1-D over row tiles.  Both outputs map every grid step to the same
(0, 0) block, so they live in VMEM across the whole grid (sequential TPU
grid semantics) — initialized at step 0, accumulated thereafter.

VMEM budget per step: TILE_N*K (x tile) + K*K (accumulator) + TILE_N
(y tile) + K (xty) floats.  For K ≤ 512, TILE_N = 1024: 4*(512k + 256k)
≈ 3 MB — comfortably inside the ~16 MB/core budget, leaving room for
double buffering of the streamed tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xtx_kernel(x_ref, y_ref, xtx_ref, xty_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        xtx_ref[...] = jnp.zeros_like(xtx_ref)
        xty_ref[...] = jnp.zeros_like(xty_ref)

    x = x_ref[...]                      # (TILE_N, K)
    y = y_ref[...]                      # (TILE_N, 1)
    # rank-TILE symmetric update on the MXU; accumulate in f32
    xtx_ref[...] += jax.lax.dot_general(
        x, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    xty_ref[...] += jax.lax.dot_general(
        x, y, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def xtx_xty_padded(x: jax.Array, y: jax.Array, *, tile_n: int = 1024,
                   interpret: bool = True):
    """x: (N, K) with N % tile_n == 0, K % 128 == 0 (pre-padded by ops.py).

    Returns (xtx (K, K) f32, xty (K, 1) f32).
    """
    n, k = x.shape
    assert n % tile_n == 0, (n, tile_n)
    grid = (n // tile_n,)
    return pl.pallas_call(
        _xtx_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, k), lambda i: (0, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, k), jnp.float32),
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, y.reshape(n, 1))
