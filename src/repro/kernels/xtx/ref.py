"""Pure-jnp oracle for the xtx kernel."""

import jax.numpy as jnp


def xtx_xty_ref(x, y):
    """(N,K),(N,) -> (K,K) f32, (K,) f32."""
    x32 = x.astype(jnp.float32)
    y32 = y.astype(jnp.float32)
    return x32.T @ x32, x32.T @ y32
