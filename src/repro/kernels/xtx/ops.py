"""Public wrapper for the xtx kernel: padding + dispatch policy.

K is padded to the 128-lane MXU boundary and N to the tile size with zero
rows (zeros contribute nothing to either accumulation — the same masking
trick the UDA transition uses).  On non-TPU backends the kernel runs in
interpret mode (correctness path); TPU gets the compiled kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import xtx_xty_padded


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


@functools.partial(jax.jit, static_argnames=("tile_n",))
def xtx_xty(x: jax.Array, y: jax.Array, *, tile_n: int = 1024):
    """(N, K), (N,) -> (X^T X (K,K) f32, X^T y (K,) f32) for any N, K."""
    n, k = x.shape
    kp = max(_round_up(k, 128), 128)
    tile = min(tile_n, max(_round_up(n, 8), 8))
    np_ = _round_up(n, tile)
    x = jnp.pad(x, ((0, np_ - n), (0, kp - k)))
    y = jnp.pad(y, (0, np_ - n))
    interpret = jax.default_backend() != "tpu"
    xtx, xty = xtx_xty_padded(x, y, tile_n=tile, interpret=interpret)
    return xtx[:k, :k], xty[:k, 0]
