"""Public wrapper: pad rows to the tile, centroids/features to lane
boundaries, dispatch compiled-vs-interpret, unpad."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import assign_reduce_padded


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


@functools.partial(jax.jit, static_argnames=("tile_n",))
def assign_and_reduce(x: jax.Array, c: jax.Array, m: jax.Array, *,
                      tile_n: int = 512):
    """x (N,D), centroids (K,D), mask (N,) -> (assign, mind, sums, counts).

    Padded rows get mask 0 (contribute nothing); padded centroid slots get
    +inf-ish distance via large coordinates so argmin never picks them.
    """
    n, d = x.shape
    k = c.shape[0]
    dp = max(_round_up(d, 128), 128)
    kp = max(_round_up(k, 8), 8)
    tile = min(tile_n, max(_round_up(n, 8), 8))
    np_ = _round_up(n, tile)
    xp = jnp.pad(x.astype(jnp.float32), ((0, np_ - n), (0, dp - d)))
    # pad centroids with a huge sentinel so padded slots never win argmin
    cp = jnp.pad(c.astype(jnp.float32), ((0, kp - k), (0, dp - d)),
                 constant_values=1e15)
    cp = cp.at[:k, d:].set(0.0)
    mp = jnp.pad(m.astype(jnp.float32), (0, np_ - n))[:, None]
    interpret = jax.default_backend() != "tpu"
    assign, mind, sums, counts = assign_reduce_padded(
        xp, cp, mp, tile_n=tile, interpret=interpret)
    return (assign[:n, 0], mind[:n, 0], sums[:k, :d], counts[:k, 0])
