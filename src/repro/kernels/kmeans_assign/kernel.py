"""Pallas TPU kernel: fused k-means assignment + partial reduction.

One streamed pass per Lloyd round (the pass standard SQL cannot express —
paper §4.3 fn.1): for each row tile in VMEM compute squared distances to
all centroids via the matmul identity (MXU), take the argmin (VPU), and
accumulate per-centroid coordinate sums + counts into persistent VMEM
accumulators via a one-hot matmul (MXU again).

Grid: 1-D over row tiles.  centroids (K, D) are re-used by every step
(constant index_map → stays resident in VMEM).  sums/counts map to block
(0, 0) every step → VMEM-persistent accumulators.

VMEM per step (f32): TILE_N*D (x) + K*D (centroids) + TILE_N*K (dists +
one-hot) + K*D (sums).  TILE_N=512, K≤1024, D≤256 → ≈ 3.5 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, m_ref, assign_ref, mind_ref, sums_ref, counts_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = x_ref[...]                                  # (T, D)
    c = c_ref[...]                                  # (K, D)
    m = m_ref[...]                                  # (T, 1)
    xx = jnp.sum(x * x, axis=-1, keepdims=True)     # (T, 1)
    cc = jnp.sum(c * c, axis=-1)                    # (K,)
    xc = jax.lax.dot_general(                       # (T, K) on the MXU
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    d2 = xx - 2.0 * xc + cc[None, :]
    assign = jnp.argmin(d2, axis=-1)                # (T,)
    mind = jnp.min(d2, axis=-1)
    k = c.shape[0]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
              == assign[:, None]).astype(jnp.float32) * m
    assign_ref[...] = assign[:, None].astype(jnp.int32)
    mind_ref[...] = jnp.maximum(mind, 0.0)[:, None] * m
    sums_ref[...] += jax.lax.dot_general(           # (K, D) one-hot matmul
        onehot, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    counts_ref[...] += jnp.sum(onehot, axis=0)[:, None]


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def assign_reduce_padded(x, c, m, *, tile_n: int = 512,
                         interpret: bool = True):
    """x (N, D), c (K, D), m (N, 1); N % tile_n == 0.

    Returns assign (N,1) i32, mind (N,1) f32, sums (K,D) f32, counts (K,1)
    f32."""
    n, d = x.shape
    k = c.shape[0]
    grid = (n // tile_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, c, m)
