"""Pure-jnp oracle for the kmeans_assign kernel."""

import jax
import jax.numpy as jnp


def assign_and_reduce_ref(x, c, m):
    """x (N,D), c (K,D), m (N,) -> (assign (N,), mind (N,), sums (K,D),
    counts (K,))."""
    x32 = x.astype(jnp.float32)
    c32 = c.astype(jnp.float32)
    d2 = (jnp.sum(x32 * x32, -1, keepdims=True) - 2.0 * x32 @ c32.T
          + jnp.sum(c32 * c32, -1)[None])
    assign = jnp.argmin(d2, -1)
    mind = jnp.maximum(jnp.min(d2, -1), 0.0) * m
    onehot = jax.nn.one_hot(assign, c.shape[0], dtype=jnp.float32) \
        * m[:, None]
    return assign, mind, onehot.T @ x32, jnp.sum(onehot, 0)
