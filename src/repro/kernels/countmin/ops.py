"""Public wrapper for the countmin kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import countmin_padded


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


@functools.partial(jax.jit, static_argnames=("depth", "width", "tile_n"))
def countmin_block(items: jax.Array, mask: jax.Array, depth: int, width: int,
                   *, tile_n: int = 2048) -> jax.Array:
    """(N,) items + (N,) mask -> (depth, width) int32 count increments."""
    n = items.shape[0]
    tile = min(tile_n, max(_round_up(n, 8), 8))
    np_ = _round_up(n, tile)
    ip = jnp.pad(items.astype(jnp.int32), (0, np_ - n))[:, None]
    mp = jnp.pad(mask.astype(jnp.int32), (0, np_ - n))[:, None]
    interpret = jax.default_backend() != "tpu"
    return countmin_padded(ip, mp, depth=depth, width=width, tile_n=tile,
                           interpret=interpret)
