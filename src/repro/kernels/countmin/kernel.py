"""Pallas TPU kernel: count-min sketch block update.

Per grid step: hash a VMEM-resident tile of items with ``depth``
multiply-shift/fmix32 functions, expand each hash row to a one-hot
(TILE, WIDTH) mask and reduce over the tile — a matmul-free VPU reduction
— accumulating into the persistent (DEPTH, WIDTH) sketch block.

The scatter-free formulation matters: TPUs have no efficient in-VMEM
scatter-add; the iota-compare + sum is the idiomatic replacement and
vectorizes across the 8×128 VPU lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_PRIMES = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F,
           0x165667B1, 0xD3A2646C, 0xFD7046C5, 0xB55A4F09)


def _fmix32(h):
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def _kernel(items_ref, mask_ref, sketch_ref, *, depth: int, width: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        sketch_ref[...] = jnp.zeros_like(sketch_ref)

    items = items_ref[...][:, 0].astype(jnp.uint32)       # (T,)
    mask = mask_ref[...][:, 0].astype(jnp.int32)          # (T,)
    t = items.shape[0]
    for d in range(depth):                                 # static unroll
        mult = jnp.uint32(_PRIMES[d])
        h = _fmix32(items * mult + mult)
        idx = (h % jnp.uint32(width)).astype(jnp.int32)    # (T,)
        onehot = (jax.lax.broadcasted_iota(jnp.int32, (t, width), 1)
                  == idx[:, None]).astype(jnp.int32) * mask[:, None]
        sketch_ref[d, :] += jnp.sum(onehot, axis=0)


@functools.partial(jax.jit,
                   static_argnames=("depth", "width", "tile_n", "interpret"))
def countmin_padded(items, mask, *, depth: int, width: int,
                    tile_n: int = 2048, interpret: bool = True):
    """items (N,1) i32, mask (N,1) i32, N % tile_n == 0 -> (depth,width)."""
    n = items.shape[0]
    grid = (n // tile_n,)
    return pl.pallas_call(
        functools.partial(_kernel, depth=depth, width=width),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((depth, width), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((depth, width), jnp.int32),
        interpret=interpret,
    )(items, mask)
