"""Pure-jnp oracle for the countmin kernel — reuses the method-layer hash
(the kernel must agree with what countmin_query reads)."""

import jax
import jax.numpy as jnp

from ...methods.sketches import _hash_rows


def countmin_block_ref(items, mask, depth, width):
    idx = _hash_rows(items.astype(jnp.int32), depth, width)  # (depth, n)
    upd = mask.astype(jnp.int32)

    def row(i):
        return jnp.zeros((width,), jnp.int32).at[i].add(upd)

    return jax.vmap(row)(idx)
