"""Kernel dispatch registry — one fast data path, many entry points.

Every kernel package ships two interchangeable implementations: ``ref``
(pure jnp, the test oracle, fast under plain XLA on any backend) and
``pallas`` (the hand-tiled TPU kernel; its public wrapper falls back to
interpret mode off-TPU, which validates the kernel body but is far too
slow for throughput).  Before this module, every method hand-rolled its
own inline import + backend test to choose between them; now call sites
say ``dispatch("xtx", x, y)`` and the policy lives in exactly one place.

Dispatch policy (``impl`` argument):

* ``"auto"``    — compiled Pallas on TPU when the entry's ``supports``
  hook accepts the call, jnp reference everywhere else.  This is what
  ``use_kernel=True`` in the method layer means.
* ``"ref"``     — force the jnp oracle.
* ``"pallas"``  — force the Pallas wrapper.  Off-TPU this warns ONCE per
  kernel (interpret mode: the correctness path kernel tests pin, far too
  slow for throughput); on TPU a call the ``supports`` hook rejects
  raises instead of silently degrading to ``ref``.

``supports`` is a *ranker*, not just a gate: it may return ``True``
(take the call), ``False`` (can't), or a non-empty dict of tuned keyword
arguments (take the call with these tile/block parameters — typically
read from the active measured calibration, see
:mod:`repro.core.calibration`).  Tuned kwargs only flow into the pallas
implementation; explicit caller kwargs always win.

Every dispatch records a ``kind="kernel"`` event on active traces
(:mod:`repro.core.trace`) carrying the RESOLVED implementation, so
benchmarks and tests can assert which kernel actually ran.

Built-in entries (registered lazily on first lookup, so importing this
module never drags in kernel bodies): ``xtx``, ``kmeans_assign``,
``countmin``, ``flash_attention``, and the whole-fold grouped kernels
``segment_linregr`` / ``segment_countmin`` / ``segment_fm``
(kernels/segment_fold).  New kernels call :func:`register`.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable

import jax

IMPLS = ("auto", "ref", "pallas")

# kernels already warned about forced-pallas interpret mode (once per
# kernel per process, so parity matrices don't drown the signal)
_WARNED_INTERPRET: set[str] = set()


def _trace_kernel(name: str, resolved: str, requested: str) -> None:
    # lazy: core.trace lives above an import cycle (core -> aggregates ->
    # this module); by dispatch time it is always importable
    from ..core.trace import record
    record("kernel", engine=resolved, name=name, requested=requested)


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """A named (ref, pallas) implementation pair.

    ``supports(*args, **kwargs) -> bool | dict`` gates shape/dtype
    combinations the compiled Pallas kernel cannot take — and, as a
    ranker, may return tuned kwargs for the ones it can.  When it
    rejects, auto-dispatch degrades to ``ref``; a forced ``"pallas"`` on
    TPU raises loudly instead.
    """

    name: str
    ref: Callable[..., Any]
    pallas: Callable[..., Any] | None = None
    supports: Callable[..., Any] | None = None

    def resolve(self, impl: str, *args, **kwargs) -> tuple[str, dict]:
        """Resolve ``impl`` for a concrete call: which implementation runs,
        and with which tuned kwargs.  Works on ShapeDtypeStruct args (the
        hooks use shapes/dtypes only), so callers can resolve host-side
        before tracing."""
        if impl == "ref":
            return "ref", {}
        if impl == "auto":
            if self.pallas is None or jax.default_backend() != "tpu":
                return "ref", {}
            ok = True if self.supports is None \
                else self.supports(*args, **kwargs)
            if not ok:
                return "ref", {}
            return "pallas", (ok if isinstance(ok, dict) else {})
        if impl == "pallas":
            if self.pallas is None:
                raise ValueError(
                    f"kernel {self.name!r} has no pallas implementation")
            if jax.default_backend() != "tpu":
                if self.name not in _WARNED_INTERPRET:
                    _WARNED_INTERPRET.add(self.name)
                    warnings.warn(
                        f"kernel {self.name!r}: impl='pallas' forced on "
                        f"backend {jax.default_backend()!r} — running the "
                        "kernel body in interpret mode (correctness path, "
                        "far too slow for throughput)", stacklevel=3)
                return "pallas", {}
            ok = True if self.supports is None \
                else self.supports(*args, **kwargs)
            if not ok:
                shapes = [getattr(a, "shape", a) for a in args]
                raise ValueError(
                    f"kernel {self.name!r}: impl='pallas' forced but the "
                    f"supports gate rejected the call (args shapes "
                    f"{shapes}, kwargs {kwargs}); use impl='auto' to "
                    "degrade to the jnp ref, or reshape to a supported "
                    "layout")
            return "pallas", (ok if isinstance(ok, dict) else {})
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")

    def pick(self, *args, **kwargs) -> str:
        """Resolve "auto" for a concrete call: which impl would run?"""
        return self.resolve("auto", *args, **kwargs)[0]


_REGISTRY: dict[str, KernelEntry] = {}
_BUILTINS_LOADED = False


def register(name: str, *, ref: Callable, pallas: Callable | None = None,
             supports: Callable | None = None,
             overwrite: bool = False) -> KernelEntry:
    if not overwrite and name in _REGISTRY:
        raise ValueError(f"kernel {name!r} already registered")
    entry = KernelEntry(name, ref, pallas, supports)
    _REGISTRY[name] = entry
    return entry


def get(name: str) -> KernelEntry:
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown kernel {name!r}; registered: {available()}") from None


def available() -> tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def dispatch(name: str, *args, impl: str = "auto", _record: bool = True,
             **kwargs):
    """Run kernel ``name`` on ``args`` under the dispatch policy above.

    ``_record=False`` suppresses the trace event — engine paths that
    resolve host-side (and record there, once per physical execution)
    pass it so the traced inner call doesn't double-count."""
    entry = get(name)
    resolved, tuned = entry.resolve(impl, *args, **kwargs)
    if _record:
        _trace_kernel(name, resolved, impl)
    if resolved == "ref":
        return entry.ref(*args, **kwargs)
    return entry.pallas(*args, **{**tuned, **kwargs})


def resolve_impl(use_kernel: bool | str) -> str | None:
    """Method-layer ``use_kernel`` flag -> dispatch impl (None = inline
    jnp transition, no registry call)."""
    if use_kernel is False:
        return None
    if use_kernel is True:
        return "auto"
    if use_kernel in IMPLS:
        return use_kernel
    raise ValueError(f"use_kernel must be bool or one of {IMPLS}, "
                     f"got {use_kernel!r}")


# ---------------------------------------------------------------------------
# Built-in kernels.  Registration is deferred to first lookup: the ref
# modules import the method layer (countmin's oracle shares the method
# hash) and the method layer imports this module, so import-time
# registration would cycle.
# ---------------------------------------------------------------------------

def _calibrated(kernel: str, param: str):
    """Measured tile/block parameter from the active calibration, or None.
    Lazy import: calibration sits in core, which imports this module."""
    from ..core.calibration import kernel_param
    return kernel_param(kernel, param)


def _ensure_builtins() -> None:
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return

    # overwrite=True + flag set at the END: if any import below raises,
    # the next lookup retries the whole registration instead of leaving a
    # permanently partial registry with misleading unknown-kernel errors.
    from .xtx import ops as xtx_ops, ref as xtx_ref

    def xtx_supports(x, y, *, tile_n=1024):
        # ranker: no shape constraints (ops.py pads), but a measured
        # calibration may pin a better row tile for this backend
        t = _calibrated("xtx", "tile_n")
        return {"tile_n": int(t)} if t else True

    register("xtx", ref=xtx_ref.xtx_xty_ref, pallas=xtx_ops.xtx_xty,
             supports=xtx_supports, overwrite=True)

    from .kmeans_assign import ops as ka_ops, ref as ka_ref
    register("kmeans_assign", ref=ka_ref.assign_and_reduce_ref,
             pallas=ka_ops.assign_and_reduce, overwrite=True)

    from .countmin import ops as cm_ops, ref as cm_ref

    def countmin_supports(items, mask, depth, width, *, tile_n=2048):
        t = _calibrated("countmin", "tile_n")
        return {"tile_n": int(t)} if t else True

    register("countmin", ref=cm_ref.countmin_block_ref,
             pallas=cm_ops.countmin_block, supports=countmin_supports,
             overwrite=True)

    from .segment_fold import ops as sf_ops, ref as sf_ref
    register("segment_linregr", ref=sf_ref.segment_linregr_ref,
             pallas=sf_ops.segment_linregr,
             supports=sf_ops.segment_linregr_supports, overwrite=True)
    register("segment_countmin", ref=sf_ref.segment_countmin_ref,
             pallas=sf_ops.segment_countmin,
             supports=sf_ops.segment_countmin_supports, overwrite=True)
    register("segment_fm", ref=sf_ref.segment_fm_ref,
             pallas=sf_ops.segment_fm,
             supports=sf_ops.segment_fm_supports, overwrite=True)

    from .flash_attention import ops as fa_ops, ref as fa_ref

    def flash_ref(q, k, v, *, causal=True, **_):
        return fa_ref.attention_ref(
            q, k, v, scale=1.0 / (q.shape[-1] ** 0.5), causal=causal)

    def flash_pallas(q, k, v, *, causal=True, tile_q=256, tile_k=256):
        # force=True so off-TPU requests genuinely run the Pallas body
        # (interpret mode) instead of the wrapper's own jnp fallback.
        s = q.shape[2]
        return fa_ops.flash_attention(
            q, k, v, causal=causal, tile_q=min(tile_q, s),
            tile_k=min(tile_k, s), force=True)

    def flash_supports(q, k, v, *, causal=True, tile_q=256, tile_k=256):
        s = q.shape[2]
        return s % min(tile_q, s) == 0 and s % min(tile_k, s) == 0

    register("flash_attention", ref=flash_ref, pallas=flash_pallas,
             supports=flash_supports, overwrite=True)
    _BUILTINS_LOADED = True
