"""Kernel dispatch registry — one fast data path, many entry points.

Every kernel package ships two interchangeable implementations: ``ref``
(pure jnp, the test oracle, fast under plain XLA on any backend) and
``pallas`` (the hand-tiled TPU kernel; its public wrapper falls back to
interpret mode off-TPU, which validates the kernel body but is far too
slow for throughput).  Before this module, every method hand-rolled its
own inline import + backend test to choose between them; now call sites
say ``dispatch("xtx", x, y)`` and the policy lives in exactly one place.

Dispatch policy (``impl`` argument):

* ``"auto"``    — compiled Pallas on TPU when the entry's ``supports``
  predicate accepts the call, jnp reference everywhere else.  This is
  what ``use_kernel=True`` in the method layer means.
* ``"ref"``     — force the jnp oracle.
* ``"pallas"``  — force the Pallas wrapper (interpret mode off-TPU; the
  correctness path kernel tests pin).

Built-in entries (registered lazily on first lookup, so importing this
module never drags in kernel bodies): ``xtx``, ``kmeans_assign``,
``countmin``, ``flash_attention``.  New kernels call :func:`register`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

IMPLS = ("auto", "ref", "pallas")


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """A named (ref, pallas) implementation pair.

    ``supports(*args, **kwargs) -> bool`` gates shape/dtype combinations
    the Pallas kernel cannot take; when it rejects, auto-dispatch degrades
    to ``ref`` instead of erroring.
    """

    name: str
    ref: Callable[..., Any]
    pallas: Callable[..., Any] | None = None
    supports: Callable[..., bool] | None = None

    def pick(self, *args, **kwargs) -> str:
        """Resolve "auto" for a concrete call: which impl would run?"""
        if self.pallas is None:
            return "ref"
        if jax.default_backend() != "tpu":
            return "ref"
        if self.supports is not None and not self.supports(*args, **kwargs):
            return "ref"
        return "pallas"


_REGISTRY: dict[str, KernelEntry] = {}
_BUILTINS_LOADED = False


def register(name: str, *, ref: Callable, pallas: Callable | None = None,
             supports: Callable | None = None,
             overwrite: bool = False) -> KernelEntry:
    if not overwrite and name in _REGISTRY:
        raise ValueError(f"kernel {name!r} already registered")
    entry = KernelEntry(name, ref, pallas, supports)
    _REGISTRY[name] = entry
    return entry


def get(name: str) -> KernelEntry:
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown kernel {name!r}; registered: {available()}") from None


def available() -> tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def dispatch(name: str, *args, impl: str = "auto", **kwargs):
    """Run kernel ``name`` on ``args`` under the dispatch policy above."""
    entry = get(name)
    if impl == "auto":
        impl = entry.pick(*args, **kwargs)
    if impl == "ref":
        return entry.ref(*args, **kwargs)
    if impl == "pallas":
        if entry.pallas is None:
            raise ValueError(f"kernel {name!r} has no pallas implementation")
        return entry.pallas(*args, **kwargs)
    raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")


def resolve_impl(use_kernel: bool | str) -> str | None:
    """Method-layer ``use_kernel`` flag -> dispatch impl (None = inline
    jnp transition, no registry call)."""
    if use_kernel is False:
        return None
    if use_kernel is True:
        return "auto"
    if use_kernel in IMPLS:
        return use_kernel
    raise ValueError(f"use_kernel must be bool or one of {IMPLS}, "
                     f"got {use_kernel!r}")


# ---------------------------------------------------------------------------
# Built-in kernels.  Registration is deferred to first lookup: the ref
# modules import the method layer (countmin's oracle shares the method
# hash) and the method layer imports this module, so import-time
# registration would cycle.
# ---------------------------------------------------------------------------

def _ensure_builtins() -> None:
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return

    # overwrite=True + flag set at the END: if any import below raises,
    # the next lookup retries the whole registration instead of leaving a
    # permanently partial registry with misleading unknown-kernel errors.
    from .xtx import ops as xtx_ops, ref as xtx_ref
    register("xtx", ref=xtx_ref.xtx_xty_ref, pallas=xtx_ops.xtx_xty,
             overwrite=True)

    from .kmeans_assign import ops as ka_ops, ref as ka_ref
    register("kmeans_assign", ref=ka_ref.assign_and_reduce_ref,
             pallas=ka_ops.assign_and_reduce, overwrite=True)

    from .countmin import ops as cm_ops, ref as cm_ref
    register("countmin", ref=cm_ref.countmin_block_ref,
             pallas=cm_ops.countmin_block, overwrite=True)

    from .flash_attention import ops as fa_ops, ref as fa_ref

    def flash_ref(q, k, v, *, causal=True, **_):
        return fa_ref.attention_ref(
            q, k, v, scale=1.0 / (q.shape[-1] ** 0.5), causal=causal)

    def flash_pallas(q, k, v, *, causal=True, tile_q=256, tile_k=256):
        # force=True so off-TPU requests genuinely run the Pallas body
        # (interpret mode) instead of the wrapper's own jnp fallback.
        s = q.shape[2]
        return fa_ops.flash_attention(
            q, k, v, causal=causal, tile_q=min(tile_q, s),
            tile_k=min(tile_k, s), force=True)

    def flash_supports(q, k, v, *, causal=True, tile_q=256, tile_k=256):
        s = q.shape[2]
        return s % min(tile_q, s) == 0 and s % min(tile_k, s) == 0

    register("flash_attention", ref=flash_ref, pallas=flash_pallas,
             supports=flash_supports, overwrite=True)
    _BUILTINS_LOADED = True
