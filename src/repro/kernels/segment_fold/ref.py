"""Pure-jnp oracles for the segment-fold kernels.

Each oracle replays the generic grouped path EXACTLY — the same blocked
``lax.scan``, the same per-block transition arithmetic as the aggregate's
``transition`` (including the mask-multiply forms), and the same
``.at[g].add/.max`` segment merge — so for exact-state aggregates
(integer sketches, dyadic linregr) the result is bit-identical to
:func:`repro.core.aggregates.segment_fold` run without a kernel.

All three consume the group-aligned layout of
:meth:`~repro.core.table.GroupedView.aligned_blocks`: ``n2`` permuted /
padded rows forming ``nb`` equal blocks, one group per block, with
sentinel pad blocks carrying ``gid == num_groups`` (dropped by the
out-of-range scatter, exactly as in the generic path).  They return the
fold-from-zero state stack; the caller merges it with the per-group init
states under the aggregate's own combinators.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...methods.sketches import _PRIMES, _fmix32, _hash_rows, _lowest_set_bit


def _blocked(arr, nb):
    n2 = arr.shape[0]
    if nb <= 0 or n2 % nb:
        raise ValueError(f"segment_fold ref: {n2} rows do not form {nb} "
                         "equal blocks")
    return arr.reshape((nb, n2 // nb) + arr.shape[1:])


def segment_linregr_ref(x, y, valid, bgids, *, num_groups: int):
    """Whole-fold OLS state stack: (N2,K) x / (N2,) y / (N2,) valid with
    ``nb`` group-aligned blocks -> the linregr state dict stacked (G,...)."""
    nb = bgids.shape[0]
    k = x.shape[1]
    f = x.dtype
    xb, yb, vb = _blocked(x, nb), _blocked(y, nb), _blocked(valid, nb)
    acc = {
        "xtx": jnp.zeros((num_groups, k, k), f),
        "xty": jnp.zeros((num_groups, k), f),
        "y_sum": jnp.zeros((num_groups,), f),
        "y_sq": jnp.zeros((num_groups,), f),
        "n": jnp.zeros((num_groups,), jnp.float32),
    }

    def step(acc, xs):
        xq, yq, m, g = xs
        # the aggregate's transition, verbatim (mask-multiply forms)
        xm = xq * m[:, None].astype(xq.dtype)
        ym = yq * m.astype(yq.dtype)
        bstate = {
            "xtx": xm.T @ xm,
            "xty": xm.T @ ym,
            "y_sum": jnp.sum(ym),
            "y_sq": jnp.sum(ym * ym),
            "n": jnp.sum(m.astype(jnp.float32)),
        }
        return jax.tree.map(lambda a, b: a.at[g[None]].add(b[None]),
                            acc, bstate), None

    acc, _ = jax.lax.scan(step, acc, (xb, yb, vb, bgids))
    return acc


def segment_countmin_ref(items, valid, bgids, *, depth: int, width: int,
                         num_groups: int):
    """Whole-fold Count-Min stack: (N2,) items -> (G, depth, width) i32."""
    nb = bgids.shape[0]
    ib = _blocked(items.astype(jnp.int32), nb)
    vb = _blocked(valid, nb)
    acc = jnp.zeros((num_groups, depth, width), jnp.int32)

    def step(acc, xs):
        it, m, g = xs
        idx = _hash_rows(it, depth, width)                   # (depth, bs)
        upd = m.astype(jnp.int32)
        bstate = jax.vmap(lambda s, i: s.at[i].add(upd))(
            jnp.zeros((depth, width), jnp.int32), idx)
        return acc.at[g[None]].add(bstate[None]), None

    acc, _ = jax.lax.scan(step, acc, (ib, vb, bgids))
    return acc


def segment_fm_ref(items, valid, bgids, *, num_hashes: int, bits: int,
                   num_groups: int):
    """Whole-fold Flajolet-Martin stack: (N2,) items -> (G, H, bits) i32
    {0,1} bitmaps, max-merged per block."""
    nb = bgids.shape[0]
    ib = _blocked(items.astype(jnp.uint32), nb)
    vb = _blocked(valid, nb)
    acc = jnp.zeros((num_groups, num_hashes, bits), jnp.int32)
    mults = _PRIMES[:num_hashes][:, None]

    def step(acc, xs):
        it, m, g = xs
        h = _fmix32(it[None, :] * mults + mults)             # (H, bs)
        r = _lowest_set_bit(h, bits)
        onehots = jax.nn.one_hot(r, bits, dtype=jnp.int32)
        onehots = onehots * m.astype(jnp.int32)[None, :, None]
        bstate = jnp.max(onehots, axis=1)                    # (H, bits)
        return acc.at[g[None]].max(bstate[None]), None

    acc, _ = jax.lax.scan(step, acc, (ib, vb, bgids))
    return acc
