"""Segment-fold kernels — the grouped hot path as ONE fused Pallas loop.

The partitioned grouped-scan core (:func:`repro.core.aggregates
.segment_fold`) folds group-aligned row blocks and scatter-merges each
block state into stacked ``(G, ...)`` per-group accumulators.  The
kernels in this package fuse that whole fold — block transition AND
segment-boundary merge — into a single Pallas grid loop: block gids ride
in SMEM (scalar prefetch), the per-group accumulators persist in VMEM
across the sequential TPU grid, and each step's MXU/VPU block update is
accumulated straight into its group's slot.

``ref.py`` holds the pure-jnp whole-fold oracles (bit-identical to the
generic scan + scatter path for exact-state aggregates), ``kernel.py``
the Pallas bodies, ``ops.py`` the padding/dispatch wrappers and the
``supports`` gates.  Dispatched by name through ``kernels/registry.py``
(``segment_linregr``, ``segment_countmin``, ``segment_fm``).
"""
