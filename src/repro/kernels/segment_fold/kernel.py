"""Pallas TPU kernels: the grouped segment fold as one fused grid loop.

Templated on the xtx/countmin kernels, extended with the segment-merge
contract of the partitioned grouped core:

* the block-gid vector rides in SMEM via scalar prefetch
  (``PrefetchScalarGridSpec``) — the grid step reads its group id before
  touching data;
* the stacked ``(G, ...)`` per-group accumulators map every grid step to
  the same block (constant index maps), so they persist in VMEM across
  the whole sequential grid — zero-initialized at step 0, accumulated
  dynamically at ``pl.ds(g, 1)`` thereafter.  The segment-boundary merge
  is thereby fused into the grid loop: no per-block states ever
  round-trip HBM;
* sentinel pad blocks (``gid == num_groups``, produced by
  ``sharded_blocks`` so every mesh segment gets whole blocks) are
  skipped by a ``pl.when`` guard — the VMEM analogue of the generic
  path's out-of-range scatter drop.

Per-block arithmetic mirrors each aggregate's ``transition`` exactly
(mask-multiply, MXU rank-BS updates in f32, iota-compare one-hot
reductions instead of scatters), so for exact-state aggregates the
result is bit-identical to the jnp segment fold.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_PRIMES = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F,
           0x165667B1, 0xD3A2646C, 0xFD7046C5, 0xB55A4F09)


def _fmix32(h):
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


# ---------------------------------------------------------------------------
# linregr / xtx-class: OLS sufficient statistics per group.
# ---------------------------------------------------------------------------

def _linregr_kernel(bgids_ref, x_ref, y_ref, m_ref,
                    xtx_ref, xty_ref, mom_ref, *, num_groups: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        xtx_ref[...] = jnp.zeros_like(xtx_ref)
        xty_ref[...] = jnp.zeros_like(xty_ref)
        mom_ref[...] = jnp.zeros_like(mom_ref)

    g = bgids_ref[i]

    @pl.when(g < num_groups)  # sentinel pad blocks carry gid == num_groups
    def _update():
        m = m_ref[...]                       # (BS, 1) f32 validity
        x = x_ref[...] * m                   # the transition's mask-multiply
        y = y_ref[...] * m
        # rank-BS symmetric update on the MXU, accumulated in f32
        xtx_blk = jax.lax.dot_general(
            x, x, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # (K, K)
        xty_blk = jnp.sum(x * y, axis=0, keepdims=True)      # (1, K)
        # scalar moments packed into one 128-lane row: y_sum | y_sq | n
        lane = jax.lax.broadcasted_iota(jnp.int32, (1, 128), 1)
        mom_blk = (jnp.where(lane == 0, jnp.sum(y), 0.0)
                   + jnp.where(lane == 1, jnp.sum(y * y), 0.0)
                   + jnp.where(lane == 2, jnp.sum(m), 0.0))
        idx3 = (pl.ds(g, 1), slice(None), slice(None))
        pl.store(xtx_ref, idx3, pl.load(xtx_ref, idx3) + xtx_blk[None])
        idx2 = (pl.ds(g, 1), slice(None))
        pl.store(xty_ref, idx2, pl.load(xty_ref, idx2) + xty_blk)
        pl.store(mom_ref, idx2, pl.load(mom_ref, idx2) + mom_blk)


@functools.partial(jax.jit, static_argnames=("num_groups", "block_size",
                                             "interpret"))
def segment_linregr_padded(x, y, m, bgids, *, num_groups: int,
                           block_size: int, interpret: bool = True):
    """x (N2, K) f32 with K % 128 == 0, y/m (N2, 1) f32, bgids (nb,) i32,
    N2 == nb * block_size -> (xtx (G,K,K), xty (G,K), moments (G,128))."""
    n2, k = x.shape
    nb = bgids.shape[0]
    assert n2 == nb * block_size, (n2, nb, block_size)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_size, k), lambda i, g: (i, 0)),
            pl.BlockSpec((block_size, 1), lambda i, g: (i, 0)),
            pl.BlockSpec((block_size, 1), lambda i, g: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((num_groups, k, k), lambda i, g: (0, 0, 0)),
            pl.BlockSpec((num_groups, k), lambda i, g: (0, 0)),
            pl.BlockSpec((num_groups, 128), lambda i, g: (0, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_linregr_kernel, num_groups=num_groups),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((num_groups, k, k), jnp.float32),
            jax.ShapeDtypeStruct((num_groups, k), jnp.float32),
            jax.ShapeDtypeStruct((num_groups, 128), jnp.float32),
        ],
        interpret=interpret,
    )(bgids, x, y, m)


# ---------------------------------------------------------------------------
# sketch-class: Count-Min (sum-merge) and Flajolet-Martin (max-merge).
# ---------------------------------------------------------------------------

def _countmin_kernel(bgids_ref, items_ref, mask_ref, sk_ref, *,
                     depth: int, width: int, num_groups: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sk_ref[...] = jnp.zeros_like(sk_ref)

    g = bgids_ref[i]

    @pl.when(g < num_groups)
    def _update():
        items = items_ref[...][:, 0].astype(jnp.uint32)      # (BS,)
        mask = mask_ref[...][:, 0].astype(jnp.int32)
        t = items.shape[0]
        for d in range(depth):                               # static unroll
            mult = jnp.uint32(_PRIMES[d])
            h = _fmix32(items * mult + mult)
            idx = (h % jnp.uint32(width)).astype(jnp.int32)
            # scatter-free: iota-compare one-hot + VPU tile reduction
            onehot = (jax.lax.broadcasted_iota(jnp.int32, (t, width), 1)
                      == idx[:, None]).astype(jnp.int32) * mask[:, None]
            row = jnp.sum(onehot, axis=0, keepdims=True)     # (1, width)
            sl = (pl.ds(g, 1), pl.ds(d, 1), slice(None))
            pl.store(sk_ref, sl, pl.load(sk_ref, sl) + row[None])


@functools.partial(jax.jit, static_argnames=("depth", "width", "num_groups",
                                             "block_size", "interpret"))
def segment_countmin_padded(items, mask, bgids, *, depth: int, width: int,
                            num_groups: int, block_size: int,
                            interpret: bool = True):
    """items/mask (N2, 1) i32, bgids (nb,) i32 -> (G, depth, width) i32."""
    n2 = items.shape[0]
    nb = bgids.shape[0]
    assert n2 == nb * block_size, (n2, nb, block_size)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_size, 1), lambda i, g: (i, 0)),
            pl.BlockSpec((block_size, 1), lambda i, g: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_groups, depth, width),
                               lambda i, g: (0, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_countmin_kernel, depth=depth, width=width,
                          num_groups=num_groups),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_groups, depth, width), jnp.int32),
        interpret=interpret,
    )(bgids, items, mask)


def _fm_kernel(bgids_ref, items_ref, mask_ref, bm_ref, *,
               num_hashes: int, bits: int, num_groups: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        bm_ref[...] = jnp.zeros_like(bm_ref)

    g = bgids_ref[i]

    @pl.when(g < num_groups)
    def _update():
        items = items_ref[...][:, 0].astype(jnp.uint32)
        mask = mask_ref[...][:, 0].astype(jnp.int32)
        t = items.shape[0]
        pos = jax.lax.broadcasted_iota(jnp.uint32, (t, bits), 1)
        for hi in range(num_hashes):                         # static unroll
            mult = jnp.uint32(_PRIMES[hi])
            h = _fmix32(items * mult + mult)
            # lowest set bit, scatter/argmax-free: isolate it as a power
            # of two and compare against the lane's 1 << pos
            low = h & (jnp.uint32(0) - h)
            match = low[:, None] == (jnp.uint32(1) << pos)
            # no set bit in [0, bits) (h == 0 or lowest bit past the
            # window) falls back to position bits-1, as the oracle does
            none = ~jnp.any(match, axis=1)
            onehot = (match | ((pos == jnp.uint32(bits - 1))
                               & none[:, None]))
            onehot = onehot.astype(jnp.int32) * mask[:, None]
            row = jnp.max(onehot, axis=0, keepdims=True)     # (1, bits)
            sl = (pl.ds(g, 1), pl.ds(hi, 1), slice(None))
            pl.store(bm_ref, sl, jnp.maximum(pl.load(bm_ref, sl),
                                             row[None]))


@functools.partial(jax.jit, static_argnames=("num_hashes", "bits",
                                             "num_groups", "block_size",
                                             "interpret"))
def segment_fm_padded(items, mask, bgids, *, num_hashes: int, bits: int,
                      num_groups: int, block_size: int,
                      interpret: bool = True):
    """items/mask (N2, 1) i32, bgids (nb,) i32 -> (G, H, bits) i32."""
    n2 = items.shape[0]
    nb = bgids.shape[0]
    assert n2 == nb * block_size, (n2, nb, block_size)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_size, 1), lambda i, g: (i, 0)),
            pl.BlockSpec((block_size, 1), lambda i, g: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_groups, num_hashes, bits),
                               lambda i, g: (0, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_fm_kernel, num_hashes=num_hashes, bits=bits,
                          num_groups=num_groups),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_groups, num_hashes, bits),
                                       jnp.int32),
        interpret=interpret,
    )(bgids, items, mask)
