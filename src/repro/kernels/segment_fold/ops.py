"""Public wrappers + support gates for the segment-fold kernels.

The wrappers take the group-aligned layout exactly as ``segment_fold``
holds it — ``(N2, ...)`` permuted/padded columns, ``(N2,)`` validity,
``(nb,)`` block gids — pad feature dims to the 128-lane boundary, and
slice the state stacks back.  On non-TPU backends the kernels run in
interpret mode (the correctness path the parity matrix pins); TPU gets
the compiled kernels.

``*_supports`` answer "can the COMPILED TPU kernel take this call?"
from shapes/dtypes alone (they also run on ``ShapeDtypeStruct`` args —
the host-side resolution in ``run_grouped`` probes them before
tracing).  The registry consults them for auto dispatch on TPU and to
reject a forced ``impl="pallas"`` loudly; off-TPU interpret mode has no
layout constraints, so they are not consulted there.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import (
    segment_countmin_padded, segment_fm_padded, segment_linregr_padded,
)

# conservative VMEM budget for the persistent (G, ...) accumulators plus
# one streamed block (+ its one-hot intermediate): half the ~16 MB/core
_VMEM_BUDGET = 8 * 1024 * 1024
# block-gid vector resident in SMEM for the whole grid
_SMEM_MAX_BLOCKS = 4096


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _layout(n2: int, nb: int) -> int:
    """Block size of the group-aligned layout; loud on a torn layout —
    every caller (any impl) must hand equal whole blocks."""
    if nb <= 0 or n2 % nb:
        raise ValueError(f"segment_fold kernels: {n2} rows do not form "
                         f"{nb} equal group-aligned blocks")
    return n2 // nb


# ---------------------------------------------------------------------------
# linregr / xtx-class
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_groups",))
def segment_linregr(x, y, valid, bgids, *, num_groups: int):
    """(N2,K) x, (N2,) y, (N2,) valid, (nb,) bgids -> stacked (G, ...)
    linregr state dict (fold-from-zero)."""
    n2, k = x.shape
    bs = _layout(n2, bgids.shape[0])
    kp = max(_round_up(k, 128), 128)
    xp = jnp.pad(x, ((0, 0), (0, kp - k)))
    m = valid.astype(x.dtype)[:, None]
    interpret = jax.default_backend() != "tpu"
    xtx, xty, mom = segment_linregr_padded(
        xp, y[:, None], m, bgids.astype(jnp.int32),
        num_groups=num_groups, block_size=bs, interpret=interpret)
    return {"xtx": xtx[:, :k, :k], "xty": xty[:, :k],
            "y_sum": mom[:, 0], "y_sq": mom[:, 1], "n": mom[:, 2]}


def segment_linregr_supports(x, y, valid, bgids, *, num_groups: int):
    n2, k = x.shape
    nb = bgids.shape[0]
    if nb <= 0 or n2 % nb:
        return False
    bs = n2 // nb
    if x.dtype != jnp.float32 or bs % 8 or nb > _SMEM_MAX_BLOCKS:
        return False
    kp = max(_round_up(k, 128), 128)
    vmem = 4 * (num_groups * (kp * kp + kp + 128) + bs * (kp + 2))
    return vmem <= _VMEM_BUDGET


# ---------------------------------------------------------------------------
# sketch-class
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("depth", "width", "num_groups"))
def segment_countmin(items, valid, bgids, *, depth: int, width: int,
                     num_groups: int):
    """(N2,) items, (N2,) valid, (nb,) bgids -> (G, depth, width) i32."""
    bs = _layout(items.shape[0], bgids.shape[0])
    ip = items.astype(jnp.int32)[:, None]
    vp = valid.astype(jnp.int32)[:, None]
    interpret = jax.default_backend() != "tpu"
    return segment_countmin_padded(
        ip, vp, bgids.astype(jnp.int32), depth=depth, width=width,
        num_groups=num_groups, block_size=bs, interpret=interpret)


def segment_countmin_supports(items, valid, bgids, *, depth: int,
                              width: int, num_groups: int):
    n2 = items.shape[0]
    nb = bgids.shape[0]
    if nb <= 0 or n2 % nb:
        return False
    bs = n2 // nb
    if bs % 8 or nb > _SMEM_MAX_BLOCKS:
        return False
    if width % 128 or depth > 8:
        return False
    vmem = 4 * (num_groups * depth * width + bs * width + 2 * bs)
    return vmem <= _VMEM_BUDGET


@functools.partial(jax.jit, static_argnames=("num_hashes", "bits",
                                             "num_groups"))
def segment_fm(items, valid, bgids, *, num_hashes: int, bits: int,
               num_groups: int):
    """(N2,) items, (N2,) valid, (nb,) bgids -> (G, H, bits) i32 bitmaps."""
    bs = _layout(items.shape[0], bgids.shape[0])
    ip = items.astype(jnp.int32)[:, None]
    vp = valid.astype(jnp.int32)[:, None]
    interpret = jax.default_backend() != "tpu"
    return segment_fm_padded(
        ip, vp, bgids.astype(jnp.int32), num_hashes=num_hashes, bits=bits,
        num_groups=num_groups, block_size=bs, interpret=interpret)


def segment_fm_supports(items, valid, bgids, *, num_hashes: int, bits: int,
                        num_groups: int):
    n2 = items.shape[0]
    nb = bgids.shape[0]
    if nb <= 0 or n2 % nb:
        return False
    bs = n2 // nb
    if bs % 8 or nb > _SMEM_MAX_BLOCKS:
        return False
    # the (G, H, bits) stack is stored at dynamic group offsets; compiled
    # lowering wants the lane dim at the 128 boundary (default bits=32
    # stays on the jnp ref on TPU — interpret mode takes any bits)
    if bits % 128 or num_hashes > 8:
        return False
    vmem = 4 * (num_groups * num_hashes * bits + bs * bits + 2 * bs)
    return vmem <= _VMEM_BUDGET
