"""Micro-programming layer: Pallas TPU kernels for the compute hot spots.

Each kernel package has:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, dtype policy, interpret fallback)
  ref.py    — pure-jnp oracle used by tests (tests/test_kernels.py sweeps
              shapes/dtypes and asserts allclose)

Call sites do NOT import these packages directly: registry.py holds a
named (ref, pallas) pair per kernel and ``dispatch(name, *args)`` applies
the one backend/shape policy (compiled Pallas on TPU, jnp ref elsewhere,
interpret-mode Pallas on request) for every method.

Kernels:
  xtx            — blocked rank-TILE update accumulating X^T X and X^T y
                   (the paper's linregr hot spot, §4.4, MXU-adapted)
  kmeans_assign  — fused distance + argmin + per-centroid partial sums
  countmin       — count-min sketch block update (hash + one-hot matmul)
  flash_attention— causal GQA attention with online softmax (LM hot spot)
"""
