"""Pure-jnp oracle for flash attention (GQA, causal)."""

import jax.numpy as jnp


def attention_ref(q, k, v, *, scale: float, causal: bool = True):
    """q (B, Hq, S, D), k/v (B, Hk, S, D) -> (B, Hq, S, D)."""
    b, hq, s, d = q.shape
    hk = k.shape[1]
    group = hq // hk
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jnp.exp(logits - jnp.max(logits, -1, keepdims=True))
    w = w / jnp.sum(w, -1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", w,
                      vx.astype(jnp.float32)).astype(q.dtype)
