"""Pallas TPU kernel: causal GQA flash attention (forward).

Online-softmax tiling: grid (batch·q_heads, n_q_tiles, n_kv_tiles); the
innermost axis streams KV tiles through VMEM while (m, l, acc) running
statistics persist in VMEM scratch.  Causal tiles strictly above the
diagonal are skipped with ``pl.when`` (their DMA still happens — the block
index map is static — but the MXU work is elided; on TPU the bound is the
matmul, not the copy).

GQA: the q-head → kv-head mapping happens in the K/V BlockSpec index maps
(``bh // group``), so no KV replication ever materializes.

VMEM per step (f32): TILE_Q·D (q) + 2·TILE_K·D (k,v) + TILE_Q·TILE_K (s)
+ TILE_Q·(D+2) scratch.  TILE_Q=TILE_K=256, D=128: ≈ 0.8 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, tile_q: int, tile_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (qi * tile_q + tile_q - 1 >= ki * tile_k) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0]                               # (TQ, D)
        k = k_ref[0]                               # (TK, D)
        v = v_ref[0]                               # (TK, D)
        s = jax.lax.dot_general(                   # (TQ, TK)
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * tile_q + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0)
            cols = ki * tile_k + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]                        # (TQ, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                     # (TQ, TK)
        l_new = alpha * l_prev + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "tile_q", "tile_k", "interpret"))
def flash_attention_padded(q, k, v, *, scale: float, causal: bool = True,
                           tile_q: int = 256, tile_k: int = 256,
                           interpret: bool = True):
    """q (BHq, S, D), k/v (BHk, S, D); S % tile == 0, BHq % BHk == 0.

    Returns (BHq, S, D) in q.dtype.
    """
    bhq, s, d = q.shape
    bhk = k.shape[0]
    group = bhq // bhk
    grid = (bhq, s // tile_q, s // tile_k)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          tile_q=tile_q, tile_k=tile_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tile_k, d), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, tile_k, d), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_q, 1), jnp.float32),
            pltpu.VMEM((tile_q, 1), jnp.float32),
            pltpu.VMEM((tile_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
