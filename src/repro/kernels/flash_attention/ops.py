"""Public wrapper for flash attention: (B, H, S, D) layout handling,
tile-size selection, interpret fallback, jnp fallback for CPU training
speed (interpret-mode Pallas is for validation, not throughput)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_padded
from .ref import attention_ref


@functools.partial(jax.jit,
                   static_argnames=("causal", "tile_q", "tile_k", "force"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, tile_q: int = 256,
                    tile_k: int = 256, force: bool = False) -> jax.Array:
    """q (B, Hq, S, D), k/v (B, Hk, S, D) -> (B, Hq, S, D).

    On TPU (or with ``force=True``) runs the Pallas kernel; elsewhere the
    jnp oracle (XLA-fused) keeps CPU tests fast while kernel tests pin the
    Pallas body itself via force=True + interpret.
    """
    b, hq, s, d = q.shape
    hk = k.shape[1]
    scale = 1.0 / (d ** 0.5)
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force):
        return attention_ref(q, k, v, scale=scale, causal=causal)
    tq = min(tile_q, s)
    tk = min(tile_k, s)
    assert s % tq == 0 and s % tk == 0, (s, tq, tk)
    qf = q.reshape(b * hq, s, d)
    kf = k.reshape(b * hk, s, d)
    vf = v.reshape(b * hk, s, d)
    out = flash_attention_padded(
        qf, kf, vf, scale=scale, causal=causal, tile_q=tq, tile_k=tk,
        interpret=not on_tpu)
    return out.reshape(b, hq, s, d)
