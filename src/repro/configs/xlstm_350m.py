"""xlstm-350m — alternating sLSTM/mLSTM
[arXiv:2405.04517 [unverified]]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    
)

# Reduced same-family config for CPU smoke tests.
REDUCED = ModelConfig(
    name="xlstm-350m-reduced",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=512,
    dtype="float32",
    remat=False,
    
)
