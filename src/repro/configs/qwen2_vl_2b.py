"""qwen2-vl-2b — M-RoPE; dynamic-resolution patch frontend stubbed per brief
[arXiv:2409.12191 [hf]]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    mrope=True, mrope_sections=(16, 24, 24),
)

# Reduced same-family config for CPU smoke tests.
REDUCED = ModelConfig(
    name="qwen2-vl-2b-reduced",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    dtype="float32",
    remat=False,
    mrope=True, mrope_sections=(4, 6, 6),
)
