"""hubert-xlarge — encoder-only; frame-embedding frontend stubbed per brief
[arXiv:2106.07447 [unverified]]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
)

# Reduced same-family config for CPU smoke tests.
REDUCED = ModelConfig(
    name="hubert-xlarge-reduced",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    dtype="float32",
    remat=False,
    causal=False,
)
