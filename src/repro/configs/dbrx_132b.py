"""dbrx-132b — 16 experts top-4
[hf:databricks/dbrx-base [unverified]]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    n_experts=16, top_k=4,
)

# Reduced same-family config for CPU smoke tests.
REDUCED = ModelConfig(
    name="dbrx-132b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    dtype="float32",
    remat=False,
    n_experts=4, top_k=2,
)
