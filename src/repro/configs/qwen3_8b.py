"""qwen3-8b — qk_norm + GQA
[hf:Qwen/Qwen3-8B [hf]]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12288,
    vocab=151936,
    qk_norm=True,
)

# Reduced same-family config for CPU smoke tests.
REDUCED = ModelConfig(
    name="qwen3-8b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    dtype="float32",
    remat=False,
    qk_norm=True,
)
