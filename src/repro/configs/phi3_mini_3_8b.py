"""phi3-mini-3.8b — RoPE SwiGLU, MHA-equal GQA
[arXiv:2404.14219 [unverified]]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    
)

# Reduced same-family config for CPU smoke tests.
REDUCED = ModelConfig(
    name="phi3-mini-3.8b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    dtype="float32",
    remat=False,
    
)
