"""recurrentgemma-2b — RG-LRU + local attention, 1:2
[arXiv:2402.19427 [hf]]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    block_pattern=("rglru", "rglru", "local"), local_window=2048,
)

# Reduced same-family config for CPU smoke tests.
REDUCED = ModelConfig(
    name="recurrentgemma-2b-reduced",
    family="hybrid",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab=512,
    dtype="float32",
    remat=False,
    block_pattern=("rglru", "rglru", "local"), local_window=8,
)
