"""Assigned-architecture registry: ``get_config(name)``, reduced smoke
configs, and ShapeDtypeStruct input specs per (arch × shape) cell."""

from .base import (
    ARCHS,
    SHAPES,
    cells,
    get_config,
    input_specs,
    reduced_config,
    step_kind,
)

__all__ = ["ARCHS", "SHAPES", "cells", "get_config", "input_specs",
           "reduced_config", "step_kind"]
