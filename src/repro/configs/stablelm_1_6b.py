"""stablelm-1.6b — 
[hf:stabilityai/stablelm-2-1_6b [unverified]]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    
)

# Reduced same-family config for CPU smoke tests.
REDUCED = ModelConfig(
    name="stablelm-1.6b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    dtype="float32",
    remat=False,
    
)
