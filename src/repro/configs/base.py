"""Architecture registry + input-shape cells.

Ten architectures from the public pool, each with the four LM shapes:
  train_4k     seq 4096  x global_batch 256   (train_step)
  prefill_32k  seq 32768 x global_batch 32    (serve prefill)
  decode_32k   kv 32768  x global_batch 128   (serve decode, 1 new token)
  long_500k    kv 524288 x global_batch 1     (long-context decode)

Skips (DESIGN.md §7): encoder-only archs have no decode; long_500k only
for sub-quadratic families (hybrid, ssm).
"""

from __future__ import annotations

import importlib
from typing import Any

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig

ARCHS: tuple[str, ...] = (
    "moonshot-v1-16b-a3b",
    "dbrx-132b",
    "qwen3-8b",
    "phi3-mini-3.8b",
    "qwen3-14b",
    "stablelm-1.6b",
    "hubert-xlarge",
    "recurrentgemma-2b",
    "qwen2-vl-2b",
    "xlstm-350m",
)

SHAPES: dict[str, dict] = {
    "train_4k": {"seq": 4096, "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768, "batch": 32, "kind": "prefill"},
    "decode_32k": {"seq": 32768, "batch": 128, "kind": "decode"},
    "long_500k": {"seq": 524288, "batch": 1, "kind": "decode"},
}

_SUBQUADRATIC = {"recurrentgemma-2b", "xlstm-350m"}
_ENCODER_ONLY = {"hubert-xlarge"}


def _modname(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_modname(arch)}")
    return mod.CONFIG


def reduced_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_modname(arch)}")
    return mod.REDUCED


def step_kind(shape: str) -> str:
    return SHAPES[shape]["kind"]


def cell_supported(arch: str, shape: str) -> tuple[bool, str]:
    kind = SHAPES[shape]["kind"]
    if arch in _ENCODER_ONLY and kind == "decode":
        return False, "encoder-only: no decode step"
    if shape == "long_500k" and arch not in _SUBQUADRATIC:
        return False, "full quadratic attention at 512k indefensible"
    return True, ""


def cells(include_skipped: bool = False):
    """Yield (arch, shape, supported, reason)."""
    for arch in ARCHS:
        for shape in SHAPES:
            ok, why = cell_supported(arch, shape)
            if ok or include_skipped:
                yield arch, shape, ok, why


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(arch: str, shape: str, cfg: ModelConfig | None = None
                ) -> dict[str, Any]:
    """Batch pytree of ShapeDtypeStructs for the cell's step function."""
    cfg = cfg or get_config(arch)
    spec = SHAPES[shape]
    b, s = spec["batch"], spec["seq"]
    kind = spec["kind"]
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)

    if kind in ("train", "prefill"):
        if cfg.family == "audio":
            # modality frontend is a stub: precomputed frame embeddings
            return {
                "embeddings": jax.ShapeDtypeStruct((b, s, cfg.d_model), f),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
                "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
            }
        if cfg.family == "vlm":
            s_vis = 256                       # stub patch embeddings
            s_txt = s - s_vis
            return {
                "tokens": jax.ShapeDtypeStruct((b, s_txt), i32),
                "embeddings": jax.ShapeDtypeStruct((b, s_vis, cfg.d_model),
                                                   f),
                "mrope_positions": jax.ShapeDtypeStruct((3, b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
                "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
        }

    # decode: one new token against a seq-long cache
    return {
        "token": jax.ShapeDtypeStruct((b, 1), i32),
        "pos": jax.ShapeDtypeStruct((b,), i32),
    }


def decode_cache_len(arch: str, shape: str) -> int:
    return SHAPES[shape]["seq"]


def input_batch_axes(arch: str, shape: str, cfg: ModelConfig | None = None
                     ) -> dict[str, tuple]:
    """Logical sharding axes for every input leaf (same structure as
    input_specs).  Everything is batch-leading except M-RoPE positions."""
    spec = input_specs(arch, shape, cfg)
    out = {}
    for name, leaf in spec.items():
        if name == "mrope_positions":
            out[name] = (None, "batch", None)
        else:
            out[name] = ("batch",) + (None,) * (len(leaf.shape) - 1)
    return out
