"""moonshot-v1-16b-a3b — fine-grained 64-expert top-6 MoE (kimi/moonlight)
[hf:moonshotai/Moonlight-16B-A3B [hf]]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    n_experts=64, top_k=6,
)

# Reduced same-family config for CPU smoke tests.
REDUCED = ModelConfig(
    name="moonshot-v1-16b-a3b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    dtype="float32",
    remat=False,
    n_experts=8, top_k=2,
)
