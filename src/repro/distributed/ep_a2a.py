"""Sequence-sharded all-to-all expert parallelism (beyond-paper §Perf).

The baseline MoE (models/moe.py) keeps tokens replicated across the
tensor axis: every model shard gathers all tokens, runs its expert slice,
and the combine is a full (tokens × d_model) **all-reduce** per layer —
the dominant collective in the dbrx/moonshot train cells.

This implementation shards tokens over the tensor axis too (sequence
sharding at the MoE boundary) and moves only routed token embeddings with
two **all-to-alls** (dispatch + return), after which the combine is a
purely local segment-sum:

  wire/layer/device ≈ 2 · (n_loc · k · cf / EP) · d · bytes   (a2a)
    vs ≈ 2 · 2 · n_grp · d · bytes                            (all-reduce)

  — an ~EP/k× reduction (dbrx: 16/4 = 4×; moonshot: 16/6 ≈ 2.7× on wire
  plus the f32→bf16 payload halving).

Layout inside shard_map over (batch_axes…, "model"):
  x_loc (B_loc, S_loc, d); per-shard routing + capacity bucketing;
  (E, C_loc, d) -> reshape (EP, E_loc, C_loc, d) -> all_to_all ->
  (E_loc, EP·C_loc, d) -> local expert SwiGLU (weights all-gathered over
  the FSDP axis, as XLA does implicitly in the pjit path) -> reverse
  all_to_all -> local combine.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..models.layers import ParamStore

from ..core.compat import shard_map as _compat_shard_map


def init_moe_a2a(store: ParamStore, cfg, name="moe"):
    """Same parameter shapes as the baseline MoE; the router is replicated
    (tiny), expert weights are (expert × fsdp)-sharded."""
    sub = store.subtree(name)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    sub.add("router", (d, e), (None, None), scale=d ** -0.5)
    sub.add("w_gate", (e, d, f), ("expert", "fsdp", None))
    sub.add("w_up", (e, d, f), ("expert", "fsdp", None))
    sub.add("w_down", (e, f, d), ("expert", None, "fsdp"))
    return sub


def _local_dispatch(xf, logits, e, k, cap):
    """Per-shard capacity bucketing (same algorithm as the baseline)."""
    n = xf.shape[0]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), 1),
                  axis=0)
    aux = e * jnp.sum(me * ce)
    flat_e = top_e.reshape(-1)
    flat_p = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(n), k)
    order = jnp.argsort(flat_e, stable=True)
    se, sp, stok = flat_e[order], flat_p[order], flat_tok[order]
    pos_in_e = jnp.arange(n * k) - jnp.searchsorted(se, se, side="left")
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)
    tok_buf = jnp.zeros((e * cap + 1,), jnp.int32).at[slot].set(
        stok.astype(jnp.int32))
    w_buf = jnp.zeros((e * cap + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, sp, 0.0))
    v_buf = jnp.zeros((e * cap + 1,), jnp.float32).at[slot].set(
        keep.astype(jnp.float32))
    return (tok_buf[:-1].reshape(e, cap), w_buf[:-1].reshape(e, cap),
            v_buf[:-1].reshape(e, cap), aux)


def make_run_moe_a2a(mesh: Mesh, cfg, *, batch_axes=("pod", "data"),
                     expert_axis: str = "model", fsdp_axis: str = "data"):
    """Returns moe_fn(params, x) with x sharded
    P(batch_axes, expert_axis, None) — sequence-sharded at entry."""
    batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    ep = mesh.shape[expert_axis]
    e, k = cfg.n_experts, cfg.top_k
    assert e % ep == 0, (e, ep)
    e_loc = e // ep

    def shard_fn(router, w_gate, w_up, w_down, x):
        b_loc, s_loc, d = x.shape
        n_loc = b_loc * s_loc
        xf = x.reshape(n_loc, d)
        cap = max(8, -(-int(n_loc * k * cfg.capacity_factor / e) // 8) * 8)

        logits = (xf @ router).astype(jnp.float32)
        tok_ec, w_ec, v_ec, aux = _local_dispatch(xf, logits, e, k, cap)
        xe = (xf[tok_ec] * v_ec[..., None].astype(x.dtype))  # (E, C, d)

        # ---- dispatch all-to-all over the expert axis ----
        xe = xe.reshape(ep, e_loc, cap, d)
        recv = jax.lax.all_to_all(xe, expert_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv: (EP, e_loc, C, d) — [j] = tokens from source shard j
        recv = jnp.moveaxis(recv, 0, 1).reshape(e_loc, ep * cap, d)

        # ---- local experts (weights FSDP-gathered, as pjit would) ----
        # preferred_element_type keeps operands in bf16 across the FSDP
        # gathers (otherwise XLA hoists a f32 convert before the
        # all-gather and doubles the wire bytes)
        wg = jax.lax.all_gather(w_gate, fsdp_axis, axis=1, tiled=True)
        wu = jax.lax.all_gather(w_up, fsdp_axis, axis=1, tiled=True)
        wd = jax.lax.all_gather(w_down, fsdp_axis, axis=2, tiled=True)
        # pin the gather->compute boundary: stops XLA hoisting the f32
        # convert above the all-gather (which doubles wire bytes; the CPU
        # cost model is collective-blind)
        wg, wu, wd = jax.lax.optimization_barrier((wg, wu, wd))
        acc = jnp.float32
        gate = jnp.einsum("ecd,edf->ecf", recv, wg,
                          preferred_element_type=acc)
        up = jnp.einsum("ecd,edf->ecf", recv, wu,
                        preferred_element_type=acc)
        hidden = (jax.nn.silu(gate) * up).astype(x.dtype)
        out = jnp.einsum("ecf,efd->ecd", hidden, wd,
                         preferred_element_type=acc).astype(x.dtype)

        # ---- return all-to-all ----
        out = out.reshape(e_loc, ep, cap, d)
        out = jnp.moveaxis(out, 1, 0)                       # (EP, e_loc, C, d)
        back = jax.lax.all_to_all(out, expert_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        back = back.reshape(e, cap, d)

        # ---- local combine ----
        back = back * (w_ec * v_ec)[..., None].astype(x.dtype)
        combined = jnp.zeros((n_loc, d), back.dtype).at[
            tok_ec.reshape(-1)].add(back.reshape(e * cap, d))
        aux = jax.lax.pmean(jax.lax.pmean(aux, expert_axis),
                            batch_axes) if batch_axes else \
            jax.lax.pmean(aux, expert_axis)
        drop = 1.0 - jnp.sum(v_ec) / jnp.maximum(n_loc * k, 1)
        drop = jax.lax.pmean(jax.lax.pmean(drop, expert_axis),
                             batch_axes) if batch_axes else \
            jax.lax.pmean(drop, expert_axis)
        return (combined.reshape(b_loc, s_loc, d).astype(x.dtype),
                aux * cfg.router_aux_weight, drop)

    in_specs = (
        P(),                                    # router (replicated)
        P(expert_axis, fsdp_axis, None),        # w_gate
        P(expert_axis, fsdp_axis, None),        # w_up
        P(expert_axis, None, fsdp_axis),        # w_down
        P(batch_axes, expert_axis, None),       # x: batch x seq-shard x d
    )
    mapped = _compat_shard_map(
        shard_fn, mesh=mesh, in_specs=in_specs,
        out_specs=(P(batch_axes, expert_axis, None), P(), P()),
        check_vma=False)

    def moe_fn(p, x):
        out, aux, drop = mapped(p["router"], p["w_gate"], p["w_up"],
                                p["w_down"], x)
        return out, {"aux_loss": aux, "drop_frac": drop}

    return moe_fn
