"""Distributed runtime: sharding rules, decode split-K, EP all-to-all,
checkpointing, elastic scaling, gradient compression."""
