"""Gradient compression for the slow inter-pod axis.

int8 stochastic-rounding quantization with per-tensor scales + error
feedback (EF-SGD): the quantization residual is fed back into the next
round, preserving convergence.  Composes with the UDA abstraction — the
compressed all-reduce is just a merge whose transition quantizes:

    q = quantize(g + e);  merged = psum(q) / n;  e' = (g + e) - dequant(q)

``compressed_psum`` is the shard_map building block (used across the
"pod" axis where links are ~10× slower than ICI); tests exercise the
quantizer's statistical properties and EF convergence.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..core.compat import axis_size


def quantize_int8(x: jax.Array, key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Stochastic rounding to int8 with a per-tensor scale."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    scaled = x32 / scale
    low = jnp.floor(scaled)
    p_up = scaled - low
    up = jax.random.uniform(key, x.shape) < p_up
    q = jnp.clip(low + up.astype(jnp.float32), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, error, key):
    """Returns (quantized pytree, scales pytree, new error feedback)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    e_leaves = jax.tree_util.tree_leaves(error)
    qs, scales, new_e = [], [], []
    for g, e, k in zip(leaves, e_leaves, keys):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected, k)
        qs.append(q)
        scales.append(s)
        new_e.append(corrected - dequantize_int8(q, s))
    return (jax.tree_util.tree_unflatten(treedef, qs),
            jax.tree_util.tree_unflatten(treedef, scales),
            jax.tree_util.tree_unflatten(treedef, new_e))


def compressed_psum(grads, error, key, axis: str):
    """shard_map body fragment: int8-quantized mean over ``axis`` with
    error feedback.  The per-tensor scale is agreed FIRST (pmax across the
    axis) so every shard quantizes onto the same grid and the integer sum
    is exact; bytes on the wire: 1/4 of fp32 (plus one scalar/tensor)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    e_leaves = treedef.flatten_up_to(error)
    keys = jax.random.split(key, len(leaves))
    n = axis_size(axis)
    outs, new_es = [], []
    for g, e, k in zip(leaves, e_leaves, keys):
        corrected = g.astype(jnp.float32) + e
        scale = jax.lax.pmax(
            jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0, axis)
        scaled = corrected / scale
        low = jnp.floor(scaled)
        up = jax.random.uniform(k, g.shape) < (scaled - low)
        q = jnp.clip(low + up.astype(jnp.float32), -127, 127)
        summed = jax.lax.psum(q.astype(jnp.int32), axis)
        outs.append(summed.astype(jnp.float32) * scale / n)
        new_es.append(corrected - q * scale)
    return (jax.tree_util.tree_unflatten(treedef, outs),
            jax.tree_util.tree_unflatten(treedef, new_es))
