"""Checkpoint / restore with async double-buffering and elastic resharding.

Format: one ``.npy`` per pytree leaf + a JSON manifest (tree structure,
shapes, dtypes, step).  Writes go to a temp dir then atomically rename —
a crash mid-save never corrupts the latest checkpoint.  ``save_async``
snapshots device arrays to host (jax.device_get) on the caller thread
(cheap, bounded by PCIe) and does file IO on a background thread, so the
training loop loses only the snapshot time.

Restore takes a *target sharding pytree*: leaves are device_put against
whatever mesh the restart has — this is the elastic-scaling path (train on
512 chips, restart on 256: same call).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

_FLAT_SEP = "/"


def _flatten(tree) -> dict[str, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _FLAT_SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                             for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str, tree, step: int, *, keep: int = 3) -> str:
    """Synchronous checkpoint. Returns the checkpoint path."""
    flat = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    return _write(ckpt_dir, host, tree, step, keep)


class AsyncCheckpointer:
    """Background writer; at most one save in flight (newer saves wait)."""

    def __init__(self):
        self._thread: threading.Thread | None = None

    def save(self, ckpt_dir: str, tree, step: int, *, keep: int = 3):
        self.wait()
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

        def run():
            _write(ckpt_dir, host, tree, step, keep)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def _write(ckpt_dir: str, host: dict, tree, step: int, keep: int) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": {}, "time": time.time()}
    for k, v in host.items():
        fname = k.replace(_FLAT_SEP, "__") + ".npy"
        np.save(os.path.join(tmp, fname), v)
        manifest["leaves"][k] = {"file": fname, "shape": list(v.shape),
                                 "dtype": str(v.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    ckpts = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    ckpts = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return int(ckpts[-1].split("_")[1]) if ckpts else None


def restore(ckpt_dir: str, target_tree, *, step: int | None = None,
            shardings=None):
    """Load into the structure of ``target_tree``; device_put against
    ``shardings`` when given (elastic resharding)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_keys = list(_flatten(target_tree))
    arrays = {}
    for k in flat_keys:
        meta = manifest["leaves"][k]
        arrays[k] = np.load(os.path.join(path, meta["file"]))
    flat_target, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    flat_sh = (jax.tree_util.tree_flatten_with_path(shardings)[0]
               if shardings is not None else None)
    leaves = []
    for i, (pth, leaf) in enumerate(flat_target):
        key = _FLAT_SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                             for p in pth)
        arr = arrays[key].astype(leaf.dtype)
        if flat_sh is not None:
            leaves.append(jax.device_put(arr, flat_sh[i][1]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step
