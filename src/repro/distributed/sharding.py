"""Logical-axis sharding: the one place where model-code axis names meet
mesh axes.

Rules (DESIGN.md §6) — hierarchical DP/FSDP/TP:

  "batch"  -> ("pod", "data")   activations' example axis
  "fsdp"   -> "data"            ZeRO parameter sharding (intra-pod: fast ICI)
  "tensor" -> "model"           TP: heads / d_ff / recurrence channels
  "vocab"  -> "model"           vocab-parallel embedding + logits
  "expert" -> "model"           MoE expert parallelism
  "layers" -> None              scan-stacked layer axis (replicated)

Parameters carry no "pod" axis -> replicated across pods; XLA then emits
the inter-pod gradient all-reduce on the slow axis exactly once per step
(the hierarchical scheme that scales to 1000+ nodes).

``constrain`` is a contextual with_sharding_constraint: model code names
logical axes; outside any mesh context it is a no-op (single-device smoke
tests never see a mesh).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

def row_pspec(row_axes=("data",), ndim: int = 1) -> P:
    """PartitionSpec splitting the leading (row) axis over ``row_axes``,
    rest replicated — the in_spec of every row-leading array entering the
    sharded engines' ``shard_map`` programs."""
    return P(tuple(row_axes), *([None] * (ndim - 1)))


def row_sharding(mesh: Mesh, row_axes=("data",), ndim: int = 1
                 ) -> NamedSharding:
    """NamedSharding that partitions the leading (row) axis over
    ``row_axes`` and replicates the rest — the placement of every
    DISTRIBUTED BY table column, grouped block layout and base mask
    (``Table.distribute``, ``GroupedView.sharded_blocks``, the sharded
    engines' ``mask=``)."""
    return NamedSharding(mesh, row_pspec(row_axes, ndim))


def distribute_rows(mesh: Mesh, row_axes, columns: dict) -> dict:
    """device_put a dict of row-leading arrays with :func:`row_sharding`.
    Leading axes must divide the product of the ``row_axes`` extents."""
    return {k: jax.device_put(v, row_sharding(mesh, row_axes, v.ndim))
            for k, v in columns.items()}


def replicate(mesh: Mesh, array):
    """device_put one array fully replicated across ``mesh`` — the
    broadcast side of a star-schema join (core/join.py): a dimension's
    small sorted key/attr columns are copied to every device so the
    row-sharded fact side can searchsorted/gather against them without
    cross-device data movement per fact row.  The dual of
    :func:`distribute_rows` (which row-shards)."""
    return jax.device_put(array, NamedSharding(mesh, P()))


DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "fsdp": "data",
    "tensor": "model",
    "vocab": "model",
    "expert": "model",
    "layers": None,
}

_ctx = threading.local()


def _mesh_axes(mesh: Mesh) -> set[str]:
    return set(mesh.axis_names)


def to_pspec(logical: tuple, mesh: Mesh, rules: dict | None = None) -> P:
    """Map a tuple of logical axis names -> PartitionSpec valid on mesh."""
    rules = rules or DEFAULT_RULES
    axes = _mesh_axes(mesh)
    out = []
    for name in logical:
        if name is None:
            out.append(None)
            continue
        m = rules.get(name)
        if m is None:
            out.append(None)
            continue
        if isinstance(m, tuple):
            kept = tuple(a for a in m if a in axes)
            out.append(kept if kept else None)
        else:
            out.append(m if m in axes else None)
    return P(*out)


def _divisible(dim: int, spec_entry, mesh: Mesh) -> bool:
    if spec_entry is None:
        return True
    names = spec_entry if isinstance(spec_entry, tuple) else (spec_entry,)
    total = int(np.prod([mesh.shape[a] for a in names]))
    return dim % total == 0


def param_sharding(axes_tree, mesh: Mesh, params_tree,
                   rules: dict | None = None):
    """axes pytree (tuples of logical names) -> NamedSharding pytree.

    Any dimension not divisible by its assigned mesh extent falls back to
    replicated on that dim (correct, if less sharded — e.g. 10 heads on a
    16-way tensor axis)."""

    def one(logical, leaf):
        spec = to_pspec(tuple(logical), mesh, rules)
        entries = list(spec)
        shape = leaf.shape
        fixed = []
        used: set = set()
        for i, e in enumerate(entries):
            # a mesh axis may appear at most once per spec: first logical
            # dim wins (e.g. MoE "expert" takes the model axis; the
            # per-expert "tensor" dims fall back to replicated)
            names = e if isinstance(e, tuple) else ((e,) if e else ())
            if any(n in used for n in names):
                fixed.append(None)
                continue
            if i < len(shape) and not _divisible(shape[i], e, mesh):
                fixed.append(None)
            else:
                fixed.append(e)
                used.update(names)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree.map(one, axes_tree, params_tree,
                        is_leaf=lambda t: isinstance(t, tuple))


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: dict | None = None):
    """Enable logical with_sharding_constraint inside model code."""
    prev = getattr(_ctx, "active", None)
    _ctx.active = (mesh, rules or DEFAULT_RULES)
    try:
        yield
    finally:
        _ctx.active = prev


def get_active():
    """(mesh, rules) of the enclosing activation_sharding context, or
    None.  Lets model code build shard_map-based blocks (a2a MoE) against
    the live mesh."""
    return getattr(_ctx, "active", None)


def constrain(x, logical: tuple):
    active = getattr(_ctx, "active", None)
    if active is None:
        return x
    mesh, rules = active
    spec = to_pspec(logical, mesh, rules)
    # divisibility guard on every constrained dim
    entries = []
    for i, e in enumerate(spec):
        if e is not None and not _divisible(x.shape[i], e, mesh):
            entries.append(None)
        else:
            entries.append(e)
    # NOTE on dtype: XLA:CPU has no native bf16 ALU and promotes whole
    # activation chains (and their collectives) to f32; on the TPU target
    # these are bf16-native.  hlo_analysis detects promoted collectives
    # (convert-rooted producers) and counts them at bf16 width.  A
    # dtype-pinning optimization_barrier here was tried and REVERTED: it
    # blocks the partitioner's all-reduce -> reduce-scatter merge at
    # sequence-parallel boundaries (EXPERIMENTS.md §Perf, dbrx iter 5).
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


def batch_sharding(mesh: Mesh, tree, rules: dict | None = None,
                   logical_tree=None):
    """Shard batch pytrees.  By default the leading axis maps to "batch";
    ``logical_tree`` overrides with per-leaf logical tuples (e.g. M-RoPE
    position tensors are (3, B, S) -> (None, "batch", None)).  Dims not
    divisible by their mesh extent fall back to replicated."""

    def one(leaf, logical=None):
        logical = logical or (("batch",) + (None,) * (leaf.ndim - 1))
        spec = to_pspec(tuple(logical), mesh, rules)
        entries = []
        for i, e in enumerate(spec):
            if e is not None and not _divisible(leaf.shape[i], e, mesh):
                entries.append(None)
            else:
                entries.append(e)
        return NamedSharding(mesh, P(*entries))

    if logical_tree is None:
        return jax.tree.map(one, tree)
    flat, treedef = jax.tree_util.tree_flatten(tree)
    flat_logical = treedef.flatten_up_to(logical_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [one(l, tuple(lg)) for l, lg in zip(flat, flat_logical)])
