"""Pipeline parallelism (GPipe schedule) over ``collective_permute``.

Completes the DP/TP/PP/EP/SP matrix (DESIGN.md §6): stages are laid out
along a mesh axis (the "pod" axis in the production meshes — pipeline
stages across pods keep the high-volume within-stage collectives on fast
intra-pod ICI and move only (microbatch × d_model) activations across
the slow inter-pod links, once per microbatch per boundary).

Schedule: classic GPipe fill-drain over M microbatches and S stages —
``M + S − 1`` ticks; at each tick every stage runs its block on the
activation it received last tick and forwards the result one stage down
via ``collective_permute``.  Bubble fraction (S−1)/(M+S−1) is reported
by :func:`bubble_fraction` so launch configs can size M.

The stage function is arbitrary (a layer stack); parameters come in
stacked over the stage axis and shard_map slices them per stage.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core.compat import shard_map as _compat_shard_map


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def make_pipeline(mesh: Mesh, stage_fn, *, stage_axis: str = "pod",
                  n_microbatches: int | None = None):
    """Returns pipe(params_stacked, x) -> y.

    ``params_stacked``: pytree with leading axis = n_stages (sharded over
    ``stage_axis``).  ``x``: (M, mb, ...) microbatched input, replicated
    over the stage axis.  ``stage_fn(params, act) -> act`` must preserve
    the activation shape (a residual-block stack does).
    """
    n_stages = mesh.shape[stage_axis]

    def shard_fn(params, x):
        # params: (1, ...) local stage slice; x: (M, mb, ...)
        local = jax.tree.map(lambda p: p[0], params)
        m = x.shape[0]
        stage = jax.lax.axis_index(stage_axis)
        ticks = m + n_stages - 1
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (others use what they received)
            inject = jnp.where(t < m, t, m - 1)
            mb_in = jax.lax.dynamic_index_in_dim(x, inject, keepdims=False)
            act = jnp.where(stage == 0, mb_in, buf)
            act = stage_fn(local, act)
            # last stage writes its finished microbatch (valid once the
            # pipe has filled: tick >= stage index of last stage)
            out_idx = t - (n_stages - 1)
            valid = (out_idx >= 0) & (out_idx < m)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, act, jnp.maximum(out_idx, 0), 0),
                lambda o: o, outs)
            # forward activations one stage down the chain
            buf = jax.lax.ppermute(act, stage_axis, fwd_perm)
            return (buf, outs), None

        buf0 = jnp.zeros_like(x[0])
        outs0 = jnp.zeros_like(x)
        (buf, outs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(ticks))
        # only the last stage holds real outputs; broadcast them back
        # (masked psum — ppermute requires unique source/dest pairs)
        mask = (stage == n_stages - 1).astype(outs.dtype)
        return jax.lax.psum(outs * mask, stage_axis)

    def pipe(params_stacked, x):
        pspec = jax.tree.map(lambda _: P(stage_axis), params_stacked)
        return _compat_shard_map(
            shard_fn, mesh=mesh,
            in_specs=(pspec, P()),
            out_specs=P(),
            check_vma=False,
        )(params_stacked, x)

    return pipe
