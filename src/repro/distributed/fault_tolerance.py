"""Fault tolerance & straggler mitigation for multi-pod runs.

Three cooperating pieces (hardware-independent logic here; the launcher
wires them to real signals):

* :class:`HeartbeatMonitor` — per-host liveness with missed-beat
  thresholds; on failure the decision is *shrink* (elastic) or *halt and
  restart from checkpoint* depending on whether the surviving device
  count still factors into a valid mesh.
* :func:`plan_elastic_mesh` — given surviving device count and the
  desired (pod, data, model) proportions, pick the largest valid mesh —
  model-parallel degree is preserved (weights must still fit), the batch
  axes shrink.  Combined with checkpoint.restore(shardings=new), this is
  checkpoint-restart elasticity.
* :class:`StragglerMitigator` — EMA step-time tracker flagging hosts
  whose step time exceeds ``threshold ×`` the fleet median; the launcher
  responds by evicting the host (treated as a failure — shrink) once
  flagged ``patience`` times.  (On real fleets this catches the one slow
  HBM or thermally-throttled chip that gates every all-reduce.)
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np


@dataclasses.dataclass
class HostState:
    last_beat: float
    missed: int = 0
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, hosts: list[str], *, interval: float = 10.0,
                 max_missed: int = 3, clock: Callable[[], float] = time.time):
        self.interval = interval
        self.max_missed = max_missed
        self.clock = clock
        now = clock()
        self.hosts = {h: HostState(last_beat=now) for h in hosts}

    def beat(self, host: str):
        st = self.hosts[host]
        st.last_beat = self.clock()
        st.missed = 0
        st.alive = True

    def sweep(self) -> list[str]:
        """Advance the failure detector; returns newly-dead hosts."""
        now = self.clock()
        dead = []
        for h, st in self.hosts.items():
            if not st.alive:
                continue
            missed = int((now - st.last_beat) // self.interval)
            st.missed = missed
            if missed >= self.max_missed:
                st.alive = False
                dead.append(h)
        return dead

    @property
    def alive_hosts(self) -> list[str]:
        return [h for h, st in self.hosts.items() if st.alive]


def plan_elastic_mesh(n_devices: int, *, model_parallel: int,
                      pods: int = 1) -> tuple[int, ...] | None:
    """Largest (pod, data, model) mesh for ``n_devices`` that preserves the
    model-parallel degree.  Returns None if even one model group doesn't
    fit (must halt rather than shrink)."""
    if n_devices < model_parallel:
        return None
    for p in range(min(pods, n_devices // model_parallel), 0, -1):
        per_pod = n_devices // p
        data = per_pod // model_parallel
        if data >= 1:
            return (p, data, model_parallel)
    return None


class StragglerMitigator:
    def __init__(self, hosts: list[str], *, threshold: float = 1.5,
                 patience: int = 5, alpha: float = 0.2):
        self.ema = {h: None for h in hosts}
        self.flags = {h: 0 for h in hosts}
        self.threshold = threshold
        self.patience = patience
        self.alpha = alpha

    def record(self, host: str, step_time: float):
        prev = self.ema[host]
        self.ema[host] = (step_time if prev is None
                          else (1 - self.alpha) * prev
                          + self.alpha * step_time)

    def stragglers(self) -> list[str]:
        """Hosts persistently slower than threshold × fleet median."""
        vals = [v for v in self.ema.values() if v is not None]
        if len(vals) < 2:
            return []
        med = float(np.median(vals))
        out = []
        for h, v in self.ema.items():
            if v is not None and v > self.threshold * med:
                self.flags[h] += 1
                if self.flags[h] >= self.patience:
                    out.append(h)
            else:
                self.flags[h] = 0
        return out
