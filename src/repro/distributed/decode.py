"""Split-K (sequence-sharded) decode attention — FlashDecoding on pjit.

At decode, KV caches dwarf everything (32k × 128 batch ≈ GBs/layer) and
kv-head counts (1–8) are below the 16-way tensor axis, so head-sharding
cannot scale.  Instead the cache is sharded along the **sequence** axis
over "model"; each shard computes a partial attention (max, sumexp,
weighted V) over its KV slice and the shards combine with a stable
log-sum-exp reduction — two small psums instead of gathering the cache.

Works for any kv_head count including MQA (kv=1), i.e. every assigned
arch's decode shape.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core.compat import shard_map as _compat_shard_map


def splitk_partial(q, k_shard, v_shard, valid_shard):
    """Per-shard partials.  q (B,Hk,G,Dh); k/v (B,Sl,Hk,Dh);
    valid (B,Sl).  Returns (m (B,Hk,G), l (B,Hk,G), acc (B,Hk,G,Dh))."""
    dh = q.shape[-1]
    logits = jnp.einsum("bhgd,bkhd->bhgk", q.astype(jnp.float32),
                        k_shard.astype(jnp.float32)) / (dh ** 0.5)
    logits = jnp.where(valid_shard[:, None, None, :], logits, -1e30)
    m = jnp.max(logits, -1)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, -1)
    acc = jnp.einsum("bhgk,bkhd->bhgd", p, v_shard.astype(jnp.float32))
    return m, l, acc


def splitk_combine(m, l, acc, axis: str):
    """LSE-stable combine across the sequence-shard axis."""
    m_all = jax.lax.pmax(m, axis)
    corr = jnp.exp(m - m_all)
    l_all = jax.lax.psum(l * corr, axis)
    acc_all = jax.lax.psum(acc * corr[..., None], axis)
    return acc_all / jnp.maximum(l_all, 1e-30)[..., None]


def make_splitk_decode_attention(mesh: Mesh, *, seq_axis: str = "model",
                                 batch_axes=("pod", "data")):
    """Returns attn(q (B,1,H,Dh), cache_k/v (B,S,Hk,Dh), pos (B,)) with the
    cache sharded P(batch_axes, seq_axis, None, None)."""

    def inner(q, ck, cv, pos):
        # local shard of the sequence
        sl = ck.shape[1]
        shard_idx = jax.lax.axis_index(seq_axis)
        start = shard_idx * sl
        kpos = start + jnp.arange(sl)[None, :]
        valid = kpos <= pos[:, None]
        b, one, h, dh = q.shape
        hk = ck.shape[2]
        qg = q.reshape(b, hk, h // hk, dh)
        m, l, acc = splitk_partial(qg, ck, cv, valid)
        out = splitk_combine(m, l, acc, seq_axis)
        return out.reshape(b, 1, h, dh).astype(q.dtype)

    return _compat_shard_map(
        inner, mesh=mesh,
        in_specs=(P(batch_axes, None, None, None),
                  P(batch_axes, seq_axis, None, None),
                  P(batch_axes, seq_axis, None, None),
                  P(batch_axes)),
        out_specs=P(batch_axes, None, None, None),
        check_vma=False,
    )
