"""Data profiling (paper Table 1) — MADlib's ``profile`` emits one summary
row per column of an arbitrary table, and its whole point is doing so in a
SINGLE table scan.

Since the logical-plan layer, ``profile`` is a thin planned batch: it
emits one ``ScanAgg`` statement per constituent (the templated
ProfileAggregate plus one FM distinct-count sketch per eligible integer
column) into a :class:`~repro.core.session.Session`, and the shared-scan
optimizer fuses them into exactly one data pass — the PR-1 hand-built
``FusedAggregate`` wiring now falls out of the planner.
``benchmarks/bench_plan.py`` measures the pass-count and wall-time win of
planned batches over the sequential one-statement-per-scan baseline.
"""

from __future__ import annotations

import itertools

import jax.numpy as jnp

from ..core.plan import StreamAgg, execute
from ..core.session import Session
from ..core.table import Table
from ..core.templates import ProfileAggregate
from .sketches import FMAggregate

_STATS = "__stats__"
_FM = "__fm__"


def distinct_count_columns(table: Table) -> tuple[str, ...]:
    """Columns eligible for FM distinct-count enrichment (1-D integer)."""
    return tuple(
        name for name, col in sorted(table.columns.items())
        if jnp.issubdtype(col.dtype, jnp.integer) and col.ndim == 1)


def profile_aggregates(table: Table, *, distinct_counts: bool = False
                       ) -> dict:
    """The aggregate set a profile run plans as one batch (the optimizer
    fuses them into one scan)."""
    aggs = {_STATS: ProfileAggregate()}
    if distinct_counts:
        for name in distinct_count_columns(table):
            aggs[_FM + name] = FMAggregate(item_col=name)
    return aggs


def _shape_results(results: dict) -> dict:
    out = {name: dict(stats) for name, stats in results[_STATS].items()}
    for key, est in results.items():
        if key.startswith(_FM):
            out[key[len(_FM):]]["approx_distinct"] = est
    return out


def profile(table: Table, *, distinct_counts: bool = False,
            block_size: int | None = None, jit: bool = True) -> dict:
    """Univariate stats for every numeric column (+ approximate distinct
    counts for integer columns when requested) — ONE data pass total,
    by way of the scan-sharing planner (``Session.profile`` is the one
    place the batch is built)."""
    sess = Session()
    handle = sess.profile(table, distinct_counts=distinct_counts,
                          block_size=block_size, jit=jit)
    sess.run()
    return handle.result()


def profile_stream(blocks, *, distinct_counts: bool = False) -> dict:
    """Streaming fused profile — the out-of-core workload (ROADMAP item).

    ``blocks`` is a host-side iterable of column dicts (e.g. one per file
    of an out-of-core table).  Each constituent becomes a ``StreamAgg``
    statement over the SAME block iterator; the planner must (and does)
    fuse same-source stream statements into one ``run_stream`` fold, so
    the whole aggregate set — stats AND the FM sketch states — lives in
    ONE device-resident pytree donated between chunks.  Same numbers as
    :func:`profile` on the concatenated table, still exactly one pass.
    """
    it = iter(blocks)
    try:
        first = {k: jnp.asarray(v) for k, v in next(it).items()}
    except StopIteration:
        raise ValueError("profile_stream: empty block stream") from None
    aggs = profile_aggregates(Table.from_columns(first),
                              distinct_counts=distinct_counts)
    source = itertools.chain([first], it)
    sess = Session()
    handles = {name: sess.statement(StreamAgg(agg, source, label=name))
               for name, agg in aggs.items()}
    sess.run()
    return _shape_results({name: h.result() for name, h in handles.items()})
