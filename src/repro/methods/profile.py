"""Data profiling (paper Table 1) — driver wrapper over the templated
ProfileAggregate (core.templates), plus distinct-count enrichment via the
FM sketch: MADlib's ``profile`` emits one summary row per column of an
arbitrary table.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..core.aggregates import run_local, run_sharded
from ..core.table import Table
from ..core.templates import ProfileAggregate
from .sketches import FMAggregate


def profile(table: Table, *, distinct_counts: bool = False,
            block_size: int | None = None) -> dict:
    """Univariate stats for every numeric column (+ approximate distinct
    counts for integer columns when requested)."""
    run = (lambda a, t: run_sharded(a, t, block_size=block_size)
           if t.mesh is not None else run_local(a, t, block_size=block_size))
    out = dict(run(ProfileAggregate(), table))
    if distinct_counts:
        for name, col in table.columns.items():
            if jnp.issubdtype(col.dtype, jnp.integer) and col.ndim == 1:
                t = Table({"item": col}, table.mesh, table.row_axes)
                est = run(FMAggregate(item_col="item"), t)
                out[name]["approx_distinct"] = est
    return out
