"""Data profiling (paper Table 1) — MADlib's ``profile`` emits one summary
row per column of an arbitrary table, and its whole point is doing so in a
SINGLE table scan.

We reproduce that shared-scan execution with :class:`FusedAggregate`: the
templated ProfileAggregate (all per-column univariate stats) and one FM
distinct-count sketch per eligible integer column are packed into one
state pytree and folded in exactly one data pass — local or sharded,
chosen from the table's distribution.  ``benchmarks/bench_profile.py``
measures the pass-count and wall-time win over the sequential
one-aggregate-per-scan baseline.
"""

from __future__ import annotations

import itertools

import jax.numpy as jnp

from ..core.aggregates import FusedAggregate, run_local, run_sharded, \
    run_stream
from ..core.table import Table
from ..core.templates import ProfileAggregate
from .sketches import FMAggregate

_STATS = "__stats__"
_FM = "__fm__"


def distinct_count_columns(table: Table) -> tuple[str, ...]:
    """Columns eligible for FM distinct-count enrichment (1-D integer)."""
    return tuple(
        name for name, col in sorted(table.columns.items())
        if jnp.issubdtype(col.dtype, jnp.integer) and col.ndim == 1)


def profile_aggregates(table: Table, *, distinct_counts: bool = False
                       ) -> dict:
    """The aggregate set a profile run fuses into one scan."""
    aggs = {_STATS: ProfileAggregate()}
    if distinct_counts:
        for name in distinct_count_columns(table):
            aggs[_FM + name] = FMAggregate(item_col=name)
    return aggs


def _shape_results(results: dict) -> dict:
    out = {name: dict(stats) for name, stats in results[_STATS].items()}
    for key, est in results.items():
        if key.startswith(_FM):
            out[key[len(_FM):]]["approx_distinct"] = est
    return out


def profile(table: Table, *, distinct_counts: bool = False,
            block_size: int | None = None, jit: bool = True) -> dict:
    """Univariate stats for every numeric column (+ approximate distinct
    counts for integer columns when requested) — ONE data pass total."""
    fused = FusedAggregate(profile_aggregates(
        table, distinct_counts=distinct_counts))
    if table.mesh is not None:
        results = run_sharded(fused, table, block_size=block_size, jit=jit)
    else:
        results = run_local(fused, table, block_size=block_size, jit=jit)
    return _shape_results(results)


def profile_stream(blocks, *, distinct_counts: bool = False) -> dict:
    """Streaming fused profile — the out-of-core workload (ROADMAP item).

    ``blocks`` is a host-side iterable of column dicts (e.g. one per file
    of an out-of-core table).  The whole fused aggregate set — stats AND
    the FM/CM sketch states — lives in ONE device-resident pytree that is
    donated between chunks, so no chunk is ever re-read and the host only
    schedules.  Same numbers as :func:`profile` on the concatenated
    table, still exactly one pass over the data.
    """
    it = iter(blocks)
    try:
        first = {k: jnp.asarray(v) for k, v in next(it).items()}
    except StopIteration:
        raise ValueError("profile_stream: empty block stream") from None
    fused = FusedAggregate(profile_aggregates(
        Table.from_columns(first), distinct_counts=distinct_counts))
    results = run_stream(fused, itertools.chain([first], it))
    return _shape_results(results)
