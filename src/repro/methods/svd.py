"""SVD matrix factorization (paper Table 1).

Two in-database-shaped algorithms over a row-distributed matrix table:

* :func:`svd_power` — subspace (block power) iteration: each round is one
  UDA computing ``A^T (A Q)`` over row blocks (two matmuls per block,
  merge = sum), followed by a thin QR on the driver (k×k-scale work —
  exactly the paper's "final operations are comparatively cheap" split).
* :func:`svd_randomized` — Halko-style randomized range finder using the
  same aggregate with a random test matrix, then a small direct SVD.

Also :func:`lowrank_sgd` — the Table-2 "Recommendation" model: factorize a
sparse ratings table ``(i, j, v)`` by SGD on ``Σ (L_i R_j − M_ij)² + μ‖·‖²``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.aggregates import Aggregate, MERGE_SUM
from ..core.convex import ConvexProgram, sgd as sgd_solver
from ..core.plan import ScanAgg, execute
from ..core.table import Table


class AtAQAggregate(Aggregate):
    """Accumulate A^T (A Q) over row blocks (A row-sharded, Q replicated)."""

    merge_ops = MERGE_SUM

    def __init__(self, q: jax.Array):
        self.q = q

    def init(self, block):
        d = block["a"].shape[-1]
        return jnp.zeros((d, self.q.shape[1]), self.q.dtype)

    def transition(self, state, block, mask):
        a = block["a"] * mask[:, None].astype(block["a"].dtype)
        return state + a.T @ (a @ self.q)


def _run(agg, table, block_size):
    return execute(ScanAgg(agg, table, block_size=block_size,
                           label="svd:AtAQ"))


def svd_power(table: Table, k: int, *, n_iters: int = 20,
              key: jax.Array | None = None, a_col: str = "a",
              block_size: int | None = None):
    """Top-k SVD by block power iteration on A^T A (driver + UDA rounds)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    t = Table({"a": table[a_col]}, table.mesh, table.row_axes)
    d = t["a"].shape[-1]
    q, _ = jnp.linalg.qr(jax.random.normal(key, (d, k)))
    for _ in range(n_iters):
        z = _run(AtAQAggregate(q), t, block_size)    # A^T A Q
        q, _ = jnp.linalg.qr(z)
    # Rayleigh-Ritz: B = A^T A restricted to span(q)
    z = _run(AtAQAggregate(q), t, block_size)
    b = q.T @ z                                       # (k, k), symmetric
    w, u = jnp.linalg.eigh(b)
    order = jnp.argsort(-w)
    sing = jnp.sqrt(jnp.maximum(w[order], 0.0))
    v = q @ u[:, order]                               # right singular vectors
    return sing, v


def svd_randomized(table: Table, k: int, *, oversample: int = 8,
                   n_power_iters: int = 2, key: jax.Array | None = None,
                   a_col: str = "a", block_size: int | None = None):
    """Randomized SVD (Halko): range finding + power sharpening + small
    eigendecomp.  Power iterations matter for flat spectra."""
    key = key if key is not None else jax.random.PRNGKey(0)
    t = Table({"a": table[a_col]}, table.mesh, table.row_axes)
    d = t["a"].shape[-1]
    omega = jax.random.normal(key, (d, k + oversample))
    y = _run(AtAQAggregate(omega), t, block_size)     # A^T A Ω
    q, _ = jnp.linalg.qr(y)
    for _ in range(n_power_iters):
        y = _run(AtAQAggregate(q), t, block_size)
        q, _ = jnp.linalg.qr(y)
    z = _run(AtAQAggregate(q), t, block_size)
    b = q.T @ z
    w, u = jnp.linalg.eigh(b)
    order = jnp.argsort(-w)[:k]
    return jnp.sqrt(jnp.maximum(w[order], 0.0)), q @ u[:, order]


# ---------------------------------------------------------------------------
# Table 2 "Recommendation": low-rank matrix factorization by SGD.
# ---------------------------------------------------------------------------

def lowrank_program(n_rows: int, n_cols: int, rank: int, mu: float = 1e-2
                    ) -> ConvexProgram:
    def loss(params, block, mask):
        l = params["L"][block["i"].astype(jnp.int32)]
        r = params["R"][block["j"].astype(jnp.int32)]
        pred = jnp.sum(l * r, -1)
        return jnp.sum(((pred - block["v"]) ** 2) * mask.astype(jnp.float32))

    def reg(params):
        return 0.5 * mu * (jnp.sum(params["L"] ** 2) + jnp.sum(params["R"] ** 2))

    return ConvexProgram(loss=loss, regularizer=reg)


def lowrank_sgd(table: Table, n_rows: int, n_cols: int, rank: int, *,
                mu: float = 1e-5, epochs: int = 80, stepsize: float = 0.1,
                batch: int = 256, key: jax.Array | None = None,
                init_scale: float = 0.5):
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    # init away from the L=R=0 saddle; constant stepsize (annealing stalls
    # the plateau escape on this non-convex objective)
    params = {
        "L": init_scale * jax.random.normal(k1, (n_rows, rank)),
        "R": init_scale * jax.random.normal(k2, (n_cols, rank)),
    }
    prog = lowrank_program(n_rows, n_cols, rank, mu)
    return sgd_solver(prog, table, params, stepsize=stepsize, epochs=epochs,
                      batch=batch, key=k3, anneal=False)
