"""Latent Dirichlet Allocation (paper Table 1) — variational EM as UDA + driver.

Documents are table rows holding bag-of-words count vectors.  One EM round
is one aggregate pass: the transition runs a few mean-field updates per
document (γ, φ) against the current topics β and accumulates expected
topic-word counts; merge = sum; the M-step renormalization is the driver
update of :class:`LDATask` under the unified iterative executor, with
perplexity-change convergence.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.aggregates import Aggregate, MERGE_SUM
from ..core.iterative import IterativeTask
from ..core.plan import IterativeFit, execute
from ..core.table import Table


class LDAEStepAggregate(Aggregate):
    """E-step + sufficient stats: state = (topics expected counts, bound)."""

    merge_ops = MERGE_SUM

    def __init__(self, log_beta: jax.Array, alpha: float = 0.1,
                 inner_iters: int = 12):
        self.log_beta = log_beta           # (K, V) log topic-word probs
        self.alpha = alpha
        self.inner_iters = inner_iters

    def init(self, block):
        return {
            "counts": jnp.zeros_like(self.log_beta),
            "bound": jnp.zeros(()),
            "n_tokens": jnp.zeros(()),
        }

    def transition(self, state, block, mask):
        docs = block["counts"].astype(jnp.float32)       # (B, V)
        m = mask.astype(jnp.float32)
        K = self.log_beta.shape[0]

        def per_doc(doc):
            gamma = jnp.full((K,), self.alpha + doc.sum() / K)

            def step(gamma, _):
                elog_th = jax.scipy.special.digamma(gamma) \
                    - jax.scipy.special.digamma(gamma.sum())
                log_phi = elog_th[:, None] + self.log_beta   # (K, V)
                log_phi = log_phi - jax.scipy.special.logsumexp(
                    log_phi, axis=0, keepdims=True)
                gamma = self.alpha + jnp.exp(log_phi) @ doc
                return gamma, log_phi

            gamma, log_phi = jax.lax.scan(
                step, gamma, None, length=self.inner_iters)
            log_phi = log_phi[-1] if log_phi.ndim == 3 else log_phi
            phi = jnp.exp(log_phi)
            stats = phi * doc[None, :]                      # (K, V)
            ll = jnp.sum(doc * jax.scipy.special.logsumexp(
                log_phi + self.log_beta, axis=0))
            return stats, ll

        stats, lls = jax.vmap(per_doc)(docs)
        return {
            "counts": state["counts"] + jnp.einsum("bkv,b->kv", stats, m),
            "bound": state["bound"] + jnp.sum(lls * m),
            "n_tokens": state["n_tokens"] + jnp.sum(docs.sum(-1) * m),
        }


class LDATask(IterativeTask):
    """Variational EM as an executor task: state = (log topics, perplexity);
    one pass = the E-step aggregate; driver update = the M-step
    renormalization; metric = relative perplexity change."""

    def __init__(self, log_beta0: jax.Array, alpha: float, eta: float):
        self.log_beta0 = log_beta0
        self.alpha = alpha
        self.eta = eta

    def init_state(self, columns):
        return {"log_beta": self.log_beta0, "perp": jnp.float32(jnp.inf)}

    def make_aggregate(self, state):
        return LDAEStepAggregate(state["log_beta"], self.alpha)

    def update(self, state, out):
        counts = out["counts"] + self.eta
        log_beta = jnp.log(counts) - jnp.log(
            jnp.sum(counts, -1, keepdims=True))
        perp = jnp.exp(-out["bound"] / jnp.maximum(out["n_tokens"], 1))
        return {"log_beta": log_beta, "perp": perp}

    def metric(self, prev, new, out):
        return jnp.abs(prev["perp"] - new["perp"]) \
            / jnp.maximum(new["perp"], 1e-9)

    def trace_record(self, state, out, m):
        return state["perp"]


def lda_fit(table: Table, n_topics: int, vocab: int, *,
            alpha: float = 0.1, eta: float = 0.01, max_iters: int = 30,
            tol: float = 1e-4, key: jax.Array | None = None,
            block_size: int | None = None, mode: str = "compiled"):
    """Variational EM; returns (topics (K,V), perplexity trace)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    beta = jax.random.dirichlet(key, jnp.full((vocab,), 1.0), (n_topics,))
    log_beta = jnp.log(jnp.maximum(beta, 1e-12))
    res = execute(IterativeFit(LDATask(log_beta, alpha, eta), table,
                               max_iters=max_iters, tol=tol,
                               block_size=block_size, mode=mode,
                               label="lda"))
    return jnp.exp(res.state["log_beta"]), [float(p) for p in res.trace]
