"""Count-Min and Flajolet-Martin sketches (paper Table 1, "Descriptive
Statistics") as UDAs.

Both are the canonical examples of why the UDA/merge contract matters:
* Count-Min merge = elementwise **sum** of the (d, w) counter matrix.
* FM merge = elementwise **OR** of bitmaps (= max over {0,1}) — this is
  the aggregate that exercises the non-sum merge combinator.

Hashing is a vectorized multiply-shift family (no data-dependent Python),
so the transition compiles to pure gather/scatter-adds.  The Count-Min
transition can be routed through the kernel registry ("countmin").
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.aggregates import Aggregate, MERGE_MAX, MERGE_SUM
from ..core.plan import GroupedScanAgg, ScanAgg, execute
from ..core.table import Table
from ..kernels.registry import dispatch, resolve_impl

# multiply-shift hash constants (odd 64→32-bit multipliers per row)
_PRIMES = jnp.array(
    [0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F, 0x165667B1,
     0xD3A2646C, 0xFD7046C5, 0xB55A4F09], dtype=jnp.uint32)


def _fmix32(h: jax.Array) -> jax.Array:
    """murmur3 finalizer: full-avalanche mixing (uniform low bits — needed
    for the FM lowest-set-bit statistic)."""
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def _hash_rows(items: jax.Array, depth: int, width: int) -> jax.Array:
    """(n,) int32 items -> (depth, n) bucket indices in [0, width)."""
    x = items.astype(jnp.uint32)
    mults = _PRIMES[:depth][:, None]
    h = _fmix32(x[None, :] * mults + mults)
    return (h % jnp.uint32(width)).astype(jnp.int32)


class CountMinAggregate(Aggregate):
    """ε-δ frequency sketch: state (depth, width) int32 counters."""

    merge_ops = MERGE_SUM
    segment_kernel = "segment_countmin"   # fused grouped fold (registry)
    cost_class = "sketch"                 # planner calibration bucket

    def __init__(self, depth: int = 4, width: int = 1024,
                 use_kernel: bool | str = False, item_col: str = "item"):
        self.depth, self.width = depth, width
        self.kernel_impl = resolve_impl(use_kernel)
        self.item_col = item_col

    def cache_key(self):
        return ("countmin", self.depth, self.width, self.item_col,
                self.kernel_impl)

    def segment_kernel_args(self, columns, valid, block_gids, num_groups):
        return ((columns[self.item_col], valid, block_gids),
                {"depth": self.depth, "width": self.width,
                 "num_groups": num_groups})

    def init(self, block):
        return jnp.zeros((self.depth, self.width), jnp.int32)

    def transition(self, state, block, mask):
        items = block[self.item_col].astype(jnp.int32)
        if self.kernel_impl is not None:
            return state + dispatch("countmin", items, mask, self.depth,
                                    self.width, impl=self.kernel_impl)
        idx = _hash_rows(items, self.depth, self.width)  # (depth, n)
        upd = mask.astype(jnp.int32)
        def row(s, i):
            return s.at[i].add(upd)
        return jax.vmap(row)(state, idx)


def countmin_query(sketch: jax.Array, items: jax.Array) -> jax.Array:
    """Point-estimate frequencies: min over depth rows."""
    depth, width = sketch.shape
    idx = _hash_rows(items.astype(jnp.int32), depth, width)
    vals = jax.vmap(lambda row, i: row[i])(sketch, idx)  # (depth, n)
    return jnp.min(vals, axis=0)


class FMAggregate(Aggregate):
    """Flajolet-Martin distinct-count sketch.

    State: (num_hashes, bits) {0,1} bitmaps; transition ORs in the bit at
    the position of the lowest set bit of each item hash; merge = OR (max).
    Final: harmonic-ish FM estimate 2^E[r] / φ, φ ≈ 0.77351.
    """

    merge_ops = MERGE_MAX
    segment_kernel = "segment_fm"         # fused grouped fold (registry)
    cost_class = "sketch"                 # planner calibration bucket

    def __init__(self, num_hashes: int = 8, bits: int = 32,
                 item_col: str = "item", use_kernel: bool | str = False):
        self.num_hashes, self.bits = num_hashes, bits
        self.item_col = item_col
        self.kernel_impl = resolve_impl(use_kernel)

    def cache_key(self):
        return ("fm", self.num_hashes, self.bits, self.item_col,
                self.kernel_impl)

    def segment_kernel_args(self, columns, valid, block_gids, num_groups):
        return ((columns[self.item_col], valid, block_gids),
                {"num_hashes": self.num_hashes, "bits": self.bits,
                 "num_groups": num_groups})

    def init(self, block):
        return jnp.zeros((self.num_hashes, self.bits), jnp.int32)

    def transition(self, state, block, mask):
        items = block[self.item_col].astype(jnp.uint32)
        mults = _PRIMES[:self.num_hashes][:, None]
        h = _fmix32(items[None, :] * mults + mults)
        # position of lowest set bit; full-zero hash -> bits-1
        r = _lowest_set_bit(h, self.bits)               # (H, n)
        onehots = jax.nn.one_hot(r, self.bits, dtype=jnp.int32)
        onehots = onehots * mask.astype(jnp.int32)[None, :, None]
        return jnp.maximum(state, jnp.max(onehots, axis=1))

    def final(self, state):
        # R_i = index of lowest UNSET bit in bitmap i.
        unset = state == 0
        idx = jnp.argmax(unset, axis=1)
        all_set = jnp.all(~unset, axis=1)
        r = jnp.where(all_set, self.bits, idx).astype(jnp.float32)
        # geometric mean over hash functions (Jensen-corrected FM estimate)
        return 2.0 ** jnp.mean(r) / 0.77351


def _lowest_set_bit(h: jax.Array, bits: int) -> jax.Array:
    positions = jnp.arange(bits, dtype=jnp.uint32)
    bitset = (h[..., None] >> positions) & jnp.uint32(1)
    has = bitset == 1
    first = jnp.argmax(has, axis=-1)
    none_set = ~jnp.any(has, axis=-1)
    return jnp.where(none_set, bits - 1, first).astype(jnp.int32)


def countmin_sketch(table: Table, *, depth: int = 4, width: int = 1024,
                    item_col: str = "item",
                    block_size: int | None = None) -> jax.Array:
    agg = CountMinAggregate(depth, width, item_col=item_col)
    return execute(ScanAgg(agg, table, block_size=block_size,
                           label="countmin"))


def fm_distinct_count(table: Table, *, num_hashes: int = 8, bits: int = 32,
                      item_col: str = "item",
                      block_size: int | None = None) -> jax.Array:
    agg = FMAggregate(num_hashes, bits, item_col=item_col)
    return execute(ScanAgg(agg, table, block_size=block_size,
                           label="fm_distinct"))


def countmin_sketch_grouped(table: Table, key_col: str,
                            num_groups: int | None = None, *,
                            depth: int = 4, width: int = 1024,
                            item_col: str = "item",
                            block_size: int | None = None,
                            use_kernel: bool | str = False,
                            mesh=None) -> jax.Array:
    """One Count-Min sketch per group (``GROUP BY`` frequency sketching):
    a ``(num_groups, depth, width)`` counter stack from one partitioned
    grouped scan.  Counters are integers, so the grouped result is
    bit-identical to sketching each group's rows alone — on the sharded
    grouped engine (``mesh``, defaulting to the table's) too.  Emitted as
    a ``GroupedScanAgg`` over the ORIGINAL table with an ``item_col``
    projection, so batched grouped statements share one partitioning
    sort through the ``group_by`` memo."""
    return execute(GroupedScanAgg(
        CountMinAggregate(depth, width, use_kernel=use_kernel,
                          item_col=item_col), table, key_col,
        num_groups, columns=(item_col,), block_size=block_size, mesh=mesh,
        label="countmin_grouped"))


def fm_distinct_count_grouped(table: Table, key_col: str,
                              num_groups: int | None = None, *,
                              num_hashes: int = 8, bits: int = 32,
                              item_col: str = "item",
                              block_size: int | None = None,
                              use_kernel: bool | str = False,
                              mesh=None) -> jax.Array:
    """Per-group Flajolet-Martin distinct-count estimates
    (``SELECT g, count(DISTINCT item) GROUP BY g``, approximated): the
    max-merge bitmaps segment-fold in one grouped scan (sharded across
    ``mesh`` when given); returns a ``(num_groups,)`` estimate vector."""
    return execute(GroupedScanAgg(
        FMAggregate(num_hashes, bits, item_col=item_col,
                    use_kernel=use_kernel), table, key_col,
        num_groups, columns=(item_col,), block_size=block_size, mesh=mesh,
        label="fm_grouped"))
