"""Quantiles (paper Table 1) via a mergeable histogram sketch UDA.

A fixed-range equi-width histogram is the classic in-database quantile
sketch: transition bins values; merge = sum of bins; final interpolates
the requested quantiles from the cumulative histogram.  A preliminary
min/max UDA pass fixes the range (two passes total — the paper's driver
pattern, with the first pass being the ProfileAggregate).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.aggregates import Aggregate, MERGE_SUM, run_local, run_sharded
from ..core.templates import ProfileAggregate
from ..core.table import Table


class HistogramAggregate(Aggregate):
    merge_ops = MERGE_SUM

    def __init__(self, lo: float, hi: float, bins: int = 4096,
                 value_col: str = "v"):
        self.lo, self.hi, self.bins = float(lo), float(hi), bins
        self.value_col = value_col

    def init(self, block):
        return jnp.zeros((self.bins,), jnp.float32)

    def transition(self, state, block, mask):
        v = block[self.value_col].astype(jnp.float32)
        t = (v - self.lo) / max(self.hi - self.lo, 1e-30)
        idx = jnp.clip((t * self.bins).astype(jnp.int32), 0, self.bins - 1)
        return state.at[idx].add(mask.astype(jnp.float32))


def quantiles(table: Table, qs, *, value_col: str = "v", bins: int = 4096,
              block_size: int | None = None) -> jax.Array:
    """Approximate quantiles with error ≤ range/bins."""
    t = Table({value_col: table[value_col]}, table.mesh, table.row_axes)
    run = (lambda a: run_sharded(a, t, block_size=block_size)
           if t.mesh is not None else run_local(a, t, block_size=block_size))
    prof = run(ProfileAggregate())[value_col]
    lo, hi = float(prof["min"]), float(prof["max"])
    hist = run(HistogramAggregate(lo, hi, bins, value_col))
    cdf = jnp.cumsum(hist) / jnp.maximum(jnp.sum(hist), 1.0)
    qs = jnp.asarray(qs, jnp.float32)
    idx = jnp.searchsorted(cdf, qs)
    idx = jnp.clip(idx, 0, bins - 1)
    width = (hi - lo) / bins
    return lo + (idx.astype(jnp.float32) + 0.5) * width
