"""Quantiles (paper Table 1) via a mergeable histogram sketch UDA.

A fixed-range equi-width histogram is the classic in-database quantile
sketch: transition bins values; merge = sum of bins; final interpolates
the requested quantiles from the cumulative histogram.  A preliminary
min/max UDA pass fixes the range (two passes total — the paper's driver
pattern, with the first pass being the ProfileAggregate).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.aggregates import Aggregate, MERGE_SUM
from ..core.plan import GroupedScanAgg, ScanAgg, execute
from ..core.templates import ProfileAggregate
from ..core.table import Table


class HistogramAggregate(Aggregate):
    merge_ops = MERGE_SUM

    def __init__(self, lo: float, hi: float, bins: int = 4096,
                 value_col: str = "v"):
        self.lo, self.hi, self.bins = float(lo), float(hi), bins
        self.value_col = value_col

    def cache_key(self):
        return ("histogram", self.lo, self.hi, self.bins, self.value_col)

    def init(self, block):
        return jnp.zeros((self.bins,), jnp.float32)

    def transition(self, state, block, mask):
        v = block[self.value_col].astype(jnp.float32)
        t = (v - self.lo) / max(self.hi - self.lo, 1e-30)
        idx = jnp.clip((t * self.bins).astype(jnp.int32), 0, self.bins - 1)
        return state.at[idx].add(mask.astype(jnp.float32))


class GroupedHistogramAggregate(Aggregate):
    """Per-group-range histogram: ``lo``/``hi`` are ``(G,)`` arrays and
    each row bins against ITS group's range, looked up through a group-id
    data column — the state stays one ``(bins,)`` histogram, per-group
    isolation comes from the grouped engine."""

    merge_ops = MERGE_SUM

    def __init__(self, lo: jax.Array, hi: jax.Array, bins: int = 4096,
                 value_col: str = "v", gid_col: str = "__g__"):
        self.lo, self.hi, self.bins = lo, hi, bins
        self.value_col = value_col
        self.gid_col = gid_col

    def init(self, block):
        return jnp.zeros((self.bins,), jnp.float32)

    def transition(self, state, block, mask):
        g = jnp.clip(block[self.gid_col].astype(jnp.int32), 0,
                     self.lo.shape[0] - 1)
        v = block[self.value_col].astype(jnp.float32)
        lo, hi = self.lo[g], self.hi[g]
        t = (v - lo) / jnp.maximum(hi - lo, 1e-30)
        idx = jnp.clip((t * self.bins).astype(jnp.int32), 0, self.bins - 1)
        return state.at[idx].add(mask.astype(jnp.float32))


def _interp_quantiles(hist, lo, hi, qs, bins):
    cdf = jnp.cumsum(hist) / jnp.maximum(jnp.sum(hist), 1.0)
    idx = jnp.clip(jnp.searchsorted(cdf, qs), 0, bins - 1)
    width = (hi - lo) / bins
    return lo + (idx.astype(jnp.float32) + 0.5) * width


def quantiles(table: Table, qs, *, value_col: str = "v", bins: int = 4096,
              block_size: int | None = None) -> jax.Array:
    """Approximate quantiles with error ≤ range/bins.  Two planned
    statements with a data dependency (the profile pass fixes the
    histogram's range), so they execute as two sequential plans."""
    prof = execute(ScanAgg(ProfileAggregate(), table,
                           columns=(value_col,), block_size=block_size,
                           label="quantiles:range"))[value_col]
    lo, hi = float(prof["min"]), float(prof["max"])
    hist = execute(ScanAgg(HistogramAggregate(lo, hi, bins, value_col),
                           table, block_size=block_size,
                           label="quantiles:hist"))
    qs = jnp.asarray(qs, jnp.float32)
    return _interp_quantiles(hist, lo, hi, qs, bins)


def quantiles_grouped(table: Table, key_col: str, qs, *,
                      num_groups: int | None = None, value_col: str = "v",
                      bins: int = 4096, block_size: int | None = None,
                      mesh=None) -> jax.Array:
    """Per-group approximate quantiles (``... GROUP BY g``), two grouped
    passes through the partitioned core: a grouped profile fixes each
    group's range, then one grouped histogram pass bins every row against
    its own group's range.  Returns ``(num_groups, len(qs))``; groups with
    no rows yield non-finite values (their range is empty).  Both passes
    run on the sharded grouped engine when ``mesh`` (defaulting to the
    table's) is set.

    The two grouped statements share ONE partitioning sort through the
    ``Table.group_by`` memo — no hand-threaded ``GroupedView``; the group
    id rides along as a data column for the histogram's range lookup."""
    gcol = table[key_col]
    t = Table({value_col: table[value_col], "__g__": gcol, key_col: gcol},
              table.mesh, table.row_axes)
    prof = execute(GroupedScanAgg(
        ProfileAggregate(), t, key_col, num_groups,
        columns=(value_col,), block_size=block_size, mesh=mesh,
        label="quantiles_grouped:range"))[value_col]
    lo, hi = prof["min"], prof["max"]
    hist = execute(GroupedScanAgg(
        GroupedHistogramAggregate(lo, hi, bins, value_col), t, key_col,
        num_groups, block_size=block_size, mesh=mesh,
        label="quantiles_grouped:hist"))
    qs = jnp.asarray(qs, jnp.float32)
    return jax.vmap(
        lambda h, l, u: _interp_quantiles(h, l, u, qs, bins))(hist, lo, hi)
