"""Association rules (Apriori, paper Table 1) — level-wise driver + UDAs.

Transactions are rows of a boolean item-incidence matrix ``(n, n_items)``.
Support counting for a batch of candidate itemsets is one aggregate pass:
transition computes, per row, whether each candidate is contained
(min over the candidate's item columns) and accumulates counts; merge=sum.
Candidate generation (join + prune) is k×k-scale driver work.

Itemsets are fixed-width index tuples padded with -1 — static shapes,
XLA-friendly.
"""

from __future__ import annotations

import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.aggregates import Aggregate, MERGE_SUM
from ..core.plan import ScanAgg, execute
from ..core.table import Table


class SupportAggregate(Aggregate):
    """Counts how many rows contain each candidate itemset."""

    merge_ops = MERGE_SUM

    def __init__(self, candidates: jax.Array):
        self.candidates = candidates   # (C, width) int32, -1 padded

    def init(self, block):
        return jnp.zeros((self.candidates.shape[0],), jnp.float32)

    def transition(self, state, block, mask):
        items = block["items"].astype(jnp.float32)       # (B, I)
        cand = self.candidates
        padded = jnp.concatenate(
            [items, jnp.ones((items.shape[0], 1), items.dtype)], axis=1)
        idx = jnp.where(cand < 0, items.shape[1], cand)  # -1 -> always-true col
        gathered = padded[:, idx]                        # (B, C, width)
        contained = jnp.min(gathered, axis=-1)           # (B, C)
        return state + jnp.sum(
            contained * mask.astype(jnp.float32)[:, None], axis=0)


@dataclasses.dataclass
class AssocRules:
    itemsets: list       # list of tuples
    supports: dict       # itemset -> support fraction
    rules: list          # (antecedent, consequent, support, confidence)


def _count(table, candidates, block_size):
    agg = SupportAggregate(jnp.asarray(candidates, jnp.int32))
    return execute(ScanAgg(agg, table, block_size=block_size,
                           label="assoc:support"))


def apriori(table: Table, *, min_support: float = 0.1,
            min_confidence: float = 0.5, max_len: int = 3,
            block_size: int | None = None) -> AssocRules:
    n = table.n_rows
    n_items = table["items"].shape[1]
    supports: dict[tuple, float] = {}

    # level 1
    c1 = np.full((n_items, max_len), -1, np.int32)
    c1[:, 0] = np.arange(n_items)
    counts = np.asarray(_count(table, c1, block_size))
    frequent = [
        (i,) for i in range(n_items) if counts[i] / n >= min_support]
    for i, s in zip(range(n_items), counts):
        if s / n >= min_support:
            supports[(i,)] = float(s / n)

    level = frequent
    for width in range(2, max_len + 1):
        # join step: union of (width-1)-itemsets sharing a prefix
        cands = sorted({tuple(sorted(set(a) | set(b)))
                        for a in level for b in level
                        if len(set(a) | set(b)) == width})
        # prune step: all (width-1)-subsets must be frequent
        cands = [c for c in cands
                 if all(tuple(s) in supports
                        for s in itertools.combinations(c, width - 1))]
        if not cands:
            break
        arr = np.full((len(cands), max_len), -1, np.int32)
        for r, c in enumerate(cands):
            arr[r, :width] = c
        counts = np.asarray(_count(table, arr, block_size))
        level = []
        for c, s in zip(cands, counts):
            if s / n >= min_support:
                supports[c] = float(s / n)
                level.append(c)
        if not level:
            break

    rules = []
    for itemset, supp in supports.items():
        if len(itemset) < 2:
            continue
        for r in range(1, len(itemset)):
            for ante in itertools.combinations(itemset, r):
                conf = supp / supports[tuple(sorted(ante))]
                if conf >= min_confidence:
                    cons = tuple(sorted(set(itemset) - set(ante)))
                    rules.append((ante, cons, supp, conf))
    return AssocRules(sorted(supports), supports, rules)
