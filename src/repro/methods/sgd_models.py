"""The §5.1 Table-2 model zoo under ONE abstraction.

Every model is a ConvexProgram (sum-decomposable objective over table
rows) handed to the same SGD solver — the Wisconsin contribution's thesis:
"specify the model, not the algorithm".  The benchmark harness
(benchmarks/bench_sgd_models.py) fits all six rows of Table 2 through
this registry.

The solver side is equally unified: ``sgd``/``parallel_sgd`` are counted
iterations of ``SGDEpochTask`` under ``repro.core.iterative``, so every
registry model inherits the compiled epoch loop and the sharded
(Zinkevich model-averaging) engine with no per-model code.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..core.convex import ConvexProgram, sgd, parallel_sgd
from ..core.table import Table
from .logregr import logistic_program
from .svm import svm_program
from .svd import lowrank_program
from .crf import crf_program, crf_init_params


def least_squares_program(mu: float = 0.0) -> ConvexProgram:
    """Σ (xᵀw − y)²"""

    def loss(params, block, mask):
        r = block["x"] @ params - block["y"]
        return jnp.sum(r * r * mask.astype(jnp.float32))

    reg = (lambda p: 0.5 * mu * jnp.sum(p ** 2)) if mu > 0 else None
    return ConvexProgram(loss=loss, regularizer=reg)


def lasso_program(mu: float = 0.1) -> ConvexProgram:
    """Σ (xᵀw − y)² + μ‖w‖₁ (subgradient of the L1 term)."""

    def loss(params, block, mask):
        r = block["x"] @ params - block["y"]
        return jnp.sum(r * r * mask.astype(jnp.float32))

    return ConvexProgram(loss=loss,
                         regularizer=lambda p: mu * jnp.sum(jnp.abs(p)))


# name -> (program factory, params initializer)
REGISTRY: dict[str, Callable] = {
    "least_squares": least_squares_program,
    "lasso": lasso_program,
    "logistic": logistic_program,
    "svm": svm_program,
    "recommendation": lowrank_program,
    "crf": crf_program,
}


def fit_sgd_model(name: str, table: Table, params0, *, epochs: int = 5,
                  stepsize: float = 0.1, batch: int = 128, key=None,
                  **prog_kwargs):
    prog = REGISTRY[name](**prog_kwargs)
    if table.mesh is not None:
        return parallel_sgd(prog, table, params0, stepsize=stepsize,
                            epochs=epochs, batch=batch, key=key)
    return sgd(prog, table, params0, stepsize=stepsize, epochs=epochs,
               batch=batch, key=key)
