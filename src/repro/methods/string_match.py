"""Approximate string matching via q-grams (paper §5.2, Table 3).

The paper builds a PostgreSQL trigram index; here a corpus of strings is a
table of fixed-width byte arrays, the "index" is a hashed 3-gram incidence
matrix built by one UDA pass, and a query is a similarity join: hash the
query's trigrams, score every document by Jaccard similarity against the
incidence matrix (one matmul), threshold.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.aggregates import Aggregate, MERGE_MAX
from ..core.table import Table


def encode_strings(strings: list[str], width: int = 64) -> jax.Array:
    """Pack python strings into (n, width) uint8 (0-padded)."""
    out = np.zeros((len(strings), width), np.uint8)
    for i, s in enumerate(strings):
        b = s.lower().encode("utf-8")[:width]
        out[i, :len(b)] = np.frombuffer(b, np.uint8)
    return jnp.asarray(out)


def trigram_signature(chars: jax.Array, n_buckets: int = 512) -> jax.Array:
    """(n, W) uint8 -> (n, n_buckets) {0,1} hashed-trigram incidence."""
    c = chars.astype(jnp.uint32)
    t1, t2, t3 = c[:, :-2], c[:, 1:-1], c[:, 2:]
    valid = (t1 > 0) & (t2 > 0) & (t3 > 0)
    h = (t1 * jnp.uint32(0x9E3779B1) + t2 * jnp.uint32(0x85EBCA77)
         + t3 * jnp.uint32(0xC2B2AE3D))
    h = (h ^ (h >> 13)) % jnp.uint32(n_buckets)
    onehot = jax.nn.one_hot(h.astype(jnp.int32), n_buckets, dtype=jnp.float32)
    onehot = onehot * valid.astype(jnp.float32)[..., None]
    return jnp.clip(jnp.sum(onehot, axis=1), 0.0, 1.0)


class TrigramIndexAggregate(Aggregate):
    """Builds the corpus incidence matrix; merge = elementwise OR (max).

    State is (n_docs, n_buckets) — rows for documents outside the shard
    stay zero, so OR-merge reassembles the full index (the scatter-style
    UDA the paper implements with a GIN index)."""

    merge_ops = MERGE_MAX

    def __init__(self, n_docs: int, n_buckets: int = 512):
        self.n_docs, self.n_buckets = n_docs, n_buckets

    def init(self, block):
        return jnp.zeros((self.n_docs, self.n_buckets), jnp.float32)

    def transition(self, state, block, mask):
        sig = trigram_signature(block["chars"], self.n_buckets)
        sig = sig * mask.astype(jnp.float32)[:, None]
        ids = block["doc_id"].astype(jnp.int32)
        return jnp.maximum(state, jnp.zeros_like(state).at[ids].max(sig))


@jax.jit
def jaccard_scores(index: jax.Array, query_sig: jax.Array) -> jax.Array:
    """(D, B), (B,) -> (D,) Jaccard similarities."""
    inter = index @ query_sig
    union = jnp.sum(index, -1) + jnp.sum(query_sig) - inter
    return inter / jnp.maximum(union, 1.0)


def approx_match(corpus_index: jax.Array, query: str, *,
                 threshold: float = 0.3, width: int = 64,
                 n_buckets: int | None = None):
    """Return (doc indices, scores) of approximate matches for ``query``."""
    n_buckets = n_buckets or corpus_index.shape[1]
    q = encode_strings([query], width)
    sig = trigram_signature(q, n_buckets)[0]
    scores = jaccard_scores(corpus_index, sig)
    idx = jnp.nonzero(scores >= threshold, size=corpus_index.shape[0],
                      fill_value=-1)[0]
    return idx, scores
