"""Support vector machines (paper Table 1; Table 2 "Classification" row).

Linear SVM via the §5.1 convex abstraction: hinge loss Σ (1 − y·xᵀw)₊ with
L2 regularization, solved by SGD (the paper's own SVM is SGD-based) — plus
a deterministic subgradient descent path for reproducible tests.

No loop lives here: both solvers run under the unified iterative executor
through :class:`~repro.core.convex.ConvexProgram`, so SVM inherits the
compiled epoch scan, sharded (model-averaging) execution and warm starts
from ``repro.core.iterative`` without SVM-specific code.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.convex import ConvexProgram, gradient_descent, parallel_sgd, sgd
from ..core.table import Table


def svm_program(mu: float = 1e-3) -> ConvexProgram:
    def loss(params, block, mask):
        sgn = 2.0 * block["y"] - 1.0          # {0,1} -> {-1,+1}
        margin = jnp.maximum(0.0, 1.0 - sgn * (block["x"] @ params))
        return jnp.sum(margin * mask.astype(jnp.float32))

    return ConvexProgram(
        loss=loss, regularizer=lambda p: 0.5 * mu * jnp.sum(p ** 2))


def svm_fit(table: Table, *, mu: float = 1e-3, epochs: int = 10,
            stepsize: float = 0.1, batch: int = 128, key=None,
            solver: str = "sgd") -> jax.Array:
    d = table["x"].shape[-1]
    prog = svm_program(mu)
    w0 = jnp.zeros((d,))
    if solver == "gd":
        w, _, _ = gradient_descent(prog, table, w0, stepsize=stepsize / 100,
                                   max_iters=200, tol=1e-5)
        return w
    if table.mesh is not None:
        return parallel_sgd(prog, table, w0, stepsize=stepsize, epochs=epochs,
                            batch=batch, key=key)
    return sgd(prog, table, w0, stepsize=stepsize, epochs=epochs, batch=batch,
               key=key)


@jax.jit
def svm_predict(w: jax.Array, x: jax.Array) -> jax.Array:
    return (x @ w > 0).astype(jnp.int32)
