"""Decision trees (C4.5-style, paper Table 1) — scalable level-wise growth.

The in-database formulation: growing one tree level is ONE aggregate pass.
The transition routes each row to its current leaf, bins each feature, and
accumulates per-(leaf, feature, bin, class) counts; merge = sum; final
picks, per leaf, the (feature, threshold) maximizing C4.5's gain ratio.
A counted driver grows the tree breadth-first to ``max_depth`` — the
classic MPP pattern (one scan per level, not per node).

The tree is stored as fixed-capacity arrays (a complete binary tree of
2^depth − 1 internal slots), so prediction is a pure vectorized map of
``depth`` gather steps — no recursion, XLA-friendly.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.aggregates import Aggregate, MERGE_SUM
from ..core.plan import ScanAgg, execute
from ..core.table import Table


@dataclasses.dataclass
class TreeModel:
    feature: jax.Array    # (nodes,) int32, -1 = leaf
    threshold: jax.Array  # (nodes,) float32
    leaf_class: jax.Array  # (nodes,) int32 majority class
    depth: int


jax.tree_util.register_pytree_node(
    TreeModel,
    lambda t: ((t.feature, t.threshold, t.leaf_class), t.depth),
    lambda d, c: TreeModel(*c, d),
)


class SplitStatsAggregate(Aggregate):
    """Histogram sufficient statistics for one tree level.

    State: (n_leaves, n_features, n_bins, n_classes) counts.  Bins are
    equi-width over per-feature [lo, hi] fixed by a profile pre-pass.
    """

    merge_ops = MERGE_SUM

    def __init__(self, model: TreeModel, level: int, lo: jax.Array,
                 hi: jax.Array, n_bins: int, n_classes: int):
        self.model = model
        self.level = level
        self.lo, self.hi = lo, hi
        self.n_bins, self.n_classes = n_bins, n_classes

    def init(self, block):
        d = block["x"].shape[-1]
        n_leaves = 2 ** self.level
        return jnp.zeros((n_leaves, d, self.n_bins, self.n_classes),
                         jnp.float32)

    def transition(self, state, block, mask):
        x, y = block["x"], block["y"].astype(jnp.int32)
        leaf = _route(self.model, x, self.level)        # (n,) in [0, 2^level)
        t = (x - self.lo) / jnp.maximum(self.hi - self.lo, 1e-30)
        bins = jnp.clip((t * self.n_bins).astype(jnp.int32), 0,
                        self.n_bins - 1)                # (n, d)
        upd = mask.astype(jnp.float32)
        n, d = x.shape
        feat = jnp.broadcast_to(jnp.arange(d)[None, :], (n, d))
        leaf_b = jnp.broadcast_to(leaf[:, None], (n, d))
        y_b = jnp.broadcast_to(y[:, None], (n, d))
        return state.at[leaf_b, feat, bins, y_b].add(upd[:, None])


def _route(model: TreeModel, x: jax.Array, level: int) -> jax.Array:
    """Position of each row among the 2^level frontier nodes."""
    node = jnp.zeros(x.shape[0], jnp.int32)   # root = heap index 0
    for _ in range(level):
        f = model.feature[node]
        thr = model.threshold[node]
        go_right = jnp.take_along_axis(x, f[:, None].clip(0), axis=1)[:, 0] > thr
        node = 2 * node + 1 + go_right.astype(jnp.int32)
    return node - (2 ** level - 1)            # frontier-local index


def _entropy(counts: jax.Array) -> jax.Array:
    """counts (..., C) -> entropy (...)."""
    n = jnp.sum(counts, -1, keepdims=True)
    p = counts / jnp.maximum(n, 1e-30)
    return -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0),
                    axis=-1)


def _best_splits(stats: jax.Array, lo, hi, min_rows: float):
    """Per-leaf best (feature, threshold) by C4.5 gain ratio.

    stats: (L, D, B, C).  Candidate thresholds are bin edges; left/right
    class counts come from cumulative sums along the bin axis.
    """
    L, D, B, C = stats.shape
    total = jnp.sum(stats, axis=(2,))                       # (L, D, C)
    node_counts = total[:, 0, :]                            # (L, C)
    n_node = jnp.sum(node_counts, -1)                       # (L,)
    parent_h = _entropy(node_counts)                        # (L,)

    cum = jnp.cumsum(stats, axis=2)                          # (L,D,B,C)
    left = cum[:, :, :-1, :]                                 # split after bin b
    right = total[:, :, None, :] - left
    nl = jnp.sum(left, -1)
    nr = jnp.sum(right, -1)
    n = jnp.maximum(nl + nr, 1e-30)
    child_h = (nl * _entropy(left) + nr * _entropy(right)) / n
    gain = parent_h[:, None, None] - child_h                 # (L,D,B-1)
    # C4.5 gain ratio: penalize by split information
    pl = nl / n
    split_info = -(pl * jnp.log2(jnp.maximum(pl, 1e-30))
                   + (1 - pl) * jnp.log2(jnp.maximum(1 - pl, 1e-30)))
    ratio = gain / jnp.maximum(split_info, 1e-3)
    valid = (nl >= min_rows) & (nr >= min_rows)
    ratio = jnp.where(valid, ratio, -jnp.inf)

    flat = ratio.reshape(L, -1)
    best = jnp.argmax(flat, axis=1)
    best_gain = jnp.take_along_axis(flat, best[:, None], 1)[:, 0]
    feat = (best // (B - 1)).astype(jnp.int32)
    b = (best % (B - 1)).astype(jnp.int32)
    width = (hi - lo) / B
    thr = lo[feat] + (b + 1).astype(jnp.float32) * width[feat]
    majority = jnp.argmax(node_counts, -1).astype(jnp.int32)
    no_split = (best_gain <= 0.0) | (n_node < 2 * min_rows)
    return feat, thr, majority, no_split


def decision_tree_fit(table: Table, *, num_classes: int, max_depth: int = 4,
                      n_bins: int = 32, min_rows: float = 8.0,
                      block_size: int | None = None) -> TreeModel:
    x = table["x"]
    d = x.shape[-1]
    nodes = 2 ** (max_depth + 1) - 1
    model = TreeModel(
        feature=-jnp.ones((nodes,), jnp.int32),
        threshold=jnp.zeros((nodes,), jnp.float32),
        leaf_class=jnp.zeros((nodes,), jnp.int32),
        depth=max_depth,
    )
    lo = jnp.min(x, axis=0)
    hi = jnp.max(x, axis=0) + 1e-6

    def run(agg):
        return execute(ScanAgg(agg, table, block_size=block_size,
                               label="dtree:split_stats"))

    for level in range(max_depth):
        stats = run(SplitStatsAggregate(model, level, lo, hi, n_bins,
                                        num_classes))
        feat, thr, majority, no_split = _best_splits(stats, lo, hi, min_rows)
        base = 2 ** level - 1
        idx = base + jnp.arange(2 ** level)
        model = TreeModel(
            feature=model.feature.at[idx].set(
                jnp.where(no_split, -1, feat)),
            threshold=model.threshold.at[idx].set(thr),
            leaf_class=model.leaf_class.at[idx].set(majority),
            depth=max_depth,
        )
    # final level: set leaf classes from one more stats pass
    stats = run(SplitStatsAggregate(model, max_depth, lo, hi, n_bins,
                                    num_classes))
    counts = jnp.sum(stats, axis=(1, 2)) / d    # class counts per leaf
    base = 2 ** max_depth - 1
    idx = base + jnp.arange(2 ** max_depth)
    model = TreeModel(
        feature=model.feature,
        threshold=model.threshold,
        leaf_class=model.leaf_class.at[idx].set(
            jnp.argmax(counts, -1).astype(jnp.int32)),
        depth=max_depth,
    )
    return model


@jax.jit
def decision_tree_predict(model: TreeModel, x: jax.Array) -> jax.Array:
    node = jnp.zeros(x.shape[0], jnp.int32)
    cls = model.leaf_class[node]
    for _ in range(model.depth):
        f = model.feature[node]
        is_leaf = f < 0
        thr = model.threshold[node]
        go_right = jnp.take_along_axis(x, f.clip(0)[:, None], 1)[:, 0] > thr
        nxt = 2 * node + 1 + go_right.astype(jnp.int32)
        node = jnp.where(is_leaf, node, nxt)
        cls = model.leaf_class[node]
    return cls
