"""Statistical text analytics (paper §5.2, Tables 2 & 3).

Linear-chain CRF with:

* **Text feature extraction** — hashed word features, position features
  (first/last), and dictionary features, vectorized over token blocks
  (the paper's feature-extractor set, micro-programming layer).
* **Training** — the Table-2 "Labeling (CRF)" objective
  ``Σ_k [Σ_j x_j F_j(y_k, z_k) − log Z(z_k)]`` as a ConvexProgram: the
  log-partition is a forward (logsumexp) scan; gradients via jax.grad;
  each table row is one sequence (one f_i).
* **Viterbi inference** — max-product ``lax.scan`` with backpointers (the
  paper's recursive-SQL / iterative-UDF implementations, done natively).
* **MCMC inference** — Gibbs sampling and Metropolis-Hastings over label
  sequences; the chain is a ``lax.scan`` carrying state across iterations
  (the paper's window-aggregate macro-coordination pattern).

Parameters: ``{"emit": (F, L), "trans": (L, L)}`` over hashed feature ids.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core.convex import ConvexProgram
from ..core.table import Table

NEG = -1e9


# ---------------------------------------------------------------------------
# Feature extraction (hashed; static shapes).
# ---------------------------------------------------------------------------

def extract_features(tokens: jax.Array, n_features: int,
                     dictionary: jax.Array | None = None) -> jax.Array:
    """(B, T) int tokens -> (B, T, K) int feature ids (K small, static).

    Features per position: hashed word id; hashed previous word (edge-ish
    context); is-first / is-last position flags; optional dictionary
    membership.  All map into one shared hashed feature space of size
    ``n_features`` (feature hashing — in-database-friendly since the
    schema stays fixed).
    """
    B, T = tokens.shape
    word = (tokens.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)) \
        % jnp.uint32(n_features)
    prev = jnp.concatenate([jnp.zeros((B, 1), tokens.dtype),
                            tokens[:, :-1]], axis=1)
    prev_h = (prev.astype(jnp.uint32) * jnp.uint32(0x85EBCA77) + 1) \
        % jnp.uint32(n_features)
    pos = jnp.zeros((B, T), jnp.uint32)
    pos = pos.at[:, 0].set(1)
    pos = pos.at[:, -1].set(2)
    pos_h = (pos * jnp.uint32(0xC2B2AE3D) + 7) % jnp.uint32(n_features)
    feats = [word, prev_h, pos_h]
    if dictionary is not None:
        in_dict = dictionary[tokens.clip(0, dictionary.shape[0] - 1)]
        feats.append(((in_dict.astype(jnp.uint32) * jnp.uint32(0x27D4EB2F))
                      + 13) % jnp.uint32(n_features))
    return jnp.stack(feats, axis=-1).astype(jnp.int32)   # (B, T, K)


def emissions(params, feats: jax.Array) -> jax.Array:
    """(B,T,K) feature ids -> (B,T,L) emission scores (sum of feat weights)."""
    return jnp.sum(params["emit"][feats], axis=2)


# ---------------------------------------------------------------------------
# Training objective (forward algorithm).
# ---------------------------------------------------------------------------

def crf_log_likelihood(params, feats: jax.Array, labels: jax.Array,
                       mask: jax.Array) -> jax.Array:
    """Sum over batch of log p(y|z); mask (B,T) marks valid positions."""
    emit = emissions(params, feats)                      # (B, T, L)
    trans = params["trans"]                              # (L, L)
    B, T, L = emit.shape
    m = mask.astype(jnp.float32)

    # score of the gold path
    gold_emit = jnp.take_along_axis(emit, labels[..., None], -1)[..., 0]
    gold_trans = trans[labels[:, :-1], labels[:, 1:]]
    path = jnp.sum(gold_emit * m, 1) + jnp.sum(gold_trans * m[:, 1:], 1)

    # log partition by forward scan
    def step(alpha, xs):
        e_t, m_t = xs                                    # (B, L), (B,)
        nxt = jax.scipy.special.logsumexp(
            alpha[:, :, None] + trans[None], axis=1) + e_t
        return jnp.where(m_t[:, None] > 0, nxt, alpha), None

    alpha0 = emit[:, 0]
    alpha, _ = jax.lax.scan(
        step, alpha0,
        (jnp.swapaxes(emit[:, 1:], 0, 1), jnp.swapaxes(m[:, 1:], 0, 1)))
    log_z = jax.scipy.special.logsumexp(alpha, axis=-1)
    return jnp.sum(path - log_z)


def crf_program(n_features: int, n_labels: int, mu: float = 1e-4
                ) -> ConvexProgram:
    """Table-2 CRF row as a ConvexProgram over rows {feats, labels, mask}."""

    def loss(params, block, mask_rows):
        ll = _per_seq_ll(params, block["feats"], block["labels"],
                         block["mask"])
        return -jnp.sum(ll * mask_rows.astype(jnp.float32))

    def reg(params):
        return 0.5 * mu * (jnp.sum(params["emit"] ** 2)
                           + jnp.sum(params["trans"] ** 2))

    return ConvexProgram(loss=loss, regularizer=reg)


def _per_seq_ll(params, feats, labels, mask):
    def one(f, y, m):
        return crf_log_likelihood(params, f[None], y[None], m[None])
    return jax.vmap(one)(feats, labels, mask)


def crf_init_params(n_features: int, n_labels: int, key=None, scale=0.01):
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    return {
        "emit": scale * jax.random.normal(k1, (n_features, n_labels)),
        "trans": scale * jax.random.normal(k2, (n_labels, n_labels)),
    }


# ---------------------------------------------------------------------------
# Viterbi (most-likely labeling).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=())
def viterbi_decode(params, feats: jax.Array, mask: jax.Array) -> jax.Array:
    """(B,T,K) -> (B,T) argmax labelings via max-product scan."""
    emit = emissions(params, feats)
    trans = params["trans"]
    B, T, L = emit.shape
    m = mask.astype(jnp.float32)

    def fwd(delta, xs):
        e_t, m_t = xs
        scores = delta[:, :, None] + trans[None]          # (B, L, L)
        best = jnp.max(scores, axis=1) + e_t
        ptr = jnp.argmax(scores, axis=1).astype(jnp.int32)
        keep = m_t[:, None] > 0
        new = jnp.where(keep, best, delta)
        ptr = jnp.where(keep, ptr,
                        jnp.broadcast_to(jnp.arange(L)[None], (B, L)))
        return new, ptr

    delta0 = emit[:, 0]
    delta, ptrs = jax.lax.scan(
        fwd, delta0,
        (jnp.swapaxes(emit[:, 1:], 0, 1), jnp.swapaxes(m[:, 1:], 0, 1)))
    last = jnp.argmax(delta, axis=-1).astype(jnp.int32)   # (B,)

    def bwd(nxt, ptr_t):
        cur = jnp.take_along_axis(ptr_t, nxt[:, None], 1)[:, 0]
        return cur, nxt

    # bwd consumes ptrs[T-2..0]; y[i] = label at position i+1, final carry =
    # label at position 0.
    first, path_rev = jax.lax.scan(bwd, last, ptrs, reverse=True)
    path = jnp.concatenate([first[None], path_rev], axis=0)  # (T, B)
    return jnp.swapaxes(path, 0, 1)


# ---------------------------------------------------------------------------
# MCMC inference (Gibbs, Metropolis-Hastings).
# ---------------------------------------------------------------------------

def _site_logits(emit, trans, labels, t):
    """Conditional logits for position t given neighbors (B, L)."""
    B, T, L = emit.shape
    left = jnp.where(t > 0, trans[labels[:, (t - 1) % T]], 0.0)
    right = jnp.where(t < T - 1, trans[:, labels[:, (t + 1) % T]].T, 0.0)
    return emit[:, t] + left + right


def gibbs_sample(params, feats: jax.Array, mask: jax.Array, key: jax.Array,
                 n_sweeps: int = 20):
    """Systematic-scan Gibbs over label sequences; returns final sample and
    per-position marginal estimates from the last half of the chain."""
    emit = emissions(params, feats)
    trans = params["trans"]
    B, T, L = emit.shape
    labels0 = jnp.argmax(emit, axis=-1).astype(jnp.int32)

    def sweep(carry, key_s):
        labels = carry

        def site(labels, t):
            logits = _site_logits(emit, trans, labels, t)
            logits = jnp.where(mask[:, t, None] > 0, logits, 0.0)
            k = jax.random.fold_in(key_s, t)
            new = jax.random.categorical(k, logits).astype(jnp.int32)
            new = jnp.where(mask[:, t] > 0, new, labels[:, t])
            return labels.at[:, t].set(new), None

        labels, _ = jax.lax.scan(site, labels, jnp.arange(T))
        return labels, jax.nn.one_hot(labels, L)

    keys = jax.random.split(key, n_sweeps)
    labels, samples = jax.lax.scan(sweep, labels0, keys)
    marginals = jnp.mean(samples[n_sweeps // 2:], axis=0)
    return labels, marginals


def mh_sample(params, feats: jax.Array, mask: jax.Array, key: jax.Array,
              n_steps: int = 200):
    """Single-site Metropolis-Hastings with uniform proposals."""
    emit = emissions(params, feats)
    trans = params["trans"]
    B, T, L = emit.shape
    labels0 = jnp.argmax(emit, axis=-1).astype(jnp.int32)

    def step(carry, key_s):
        labels = carry
        kt, kl, ka = jax.random.split(key_s, 3)
        t = jax.random.randint(kt, (), 0, T)
        prop = jax.random.randint(kl, (B,), 0, L)
        logits = _site_logits(emit, trans, labels, t)
        cur = labels[:, t]
        lp_cur = jnp.take_along_axis(logits, cur[:, None], 1)[:, 0]
        lp_prop = jnp.take_along_axis(logits, prop[:, None], 1)[:, 0]
        accept = jnp.log(jax.random.uniform(ka, (B,))) < (lp_prop - lp_cur)
        accept = accept & (mask[:, t] > 0)
        new = jnp.where(accept, prop, cur)
        return labels.at[:, t].set(new), jnp.mean(accept.astype(jnp.float32))

    keys = jax.random.split(key, n_steps)
    labels, acc = jax.lax.scan(step, labels0, keys)
    return labels, jnp.mean(acc)
