"""Run-length-encoded sparse vectors (paper §3.2 support module).

MADlib wrote a C RLE sparse-vector library because standard math libraries
handle sparse poorly.  Same story on TPU: scatter/gather-heavy formats are
hostile; RLE with *fixed capacity* keeps shapes static.  A vector is
``(values[cap], runs[cap], n_runs)`` meaning ``values[i]`` repeated
``runs[i]`` times.  Ops: encode/decode, scale, dot with dense, and an
RLE×RLE dot via a two-pointer ``lax.while_loop`` (no densification).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class RLEVector:
    values: jax.Array   # (cap,) float32
    runs: jax.Array     # (cap,) int32
    n_runs: jax.Array   # () int32
    length: int         # logical (dense) length — static


jax.tree_util.register_pytree_node(
    RLEVector,
    lambda v: ((v.values, v.runs, v.n_runs), v.length),
    lambda l, c: RLEVector(*c, l),
)


def rle_encode(dense: jax.Array, capacity: int) -> RLEVector:
    """Dense (n,) -> RLE with static capacity (must cover #runs)."""
    n = dense.shape[0]
    change = jnp.concatenate(
        [jnp.array([True]), dense[1:] != dense[:-1]])
    run_id = jnp.cumsum(change.astype(jnp.int32)) - 1       # (n,)
    n_runs = run_id[-1] + 1
    values = jnp.zeros((capacity,), dense.dtype).at[run_id].set(dense)
    runs = jnp.zeros((capacity,), jnp.int32).at[run_id].add(1)
    return RLEVector(values, runs, n_runs, n)


def rle_decode(v: RLEVector) -> jax.Array:
    starts = jnp.cumsum(v.runs) - v.runs                     # (cap,)
    pos = jnp.arange(v.length)
    # position -> run index: count of starts <= pos, over valid runs only
    valid = jnp.arange(v.runs.shape[0]) < v.n_runs
    s = jnp.where(valid, starts, v.length + 1)
    idx = jnp.searchsorted(s, pos, side="right") - 1
    return v.values[idx]


def rle_scale(v: RLEVector, a: float) -> RLEVector:
    return RLEVector(v.values * a, v.runs, v.n_runs, v.length)


def rle_dot_dense(v: RLEVector, dense: jax.Array) -> jax.Array:
    """Σ values[i] * sum(dense over run i) via segment sums."""
    starts = jnp.cumsum(v.runs) - v.runs
    valid = jnp.arange(v.runs.shape[0]) < v.n_runs
    s = jnp.where(valid, starts, v.length + 1)
    pos = jnp.arange(v.length)
    idx = jnp.clip(jnp.searchsorted(s, pos, side="right") - 1, 0,
                   v.runs.shape[0] - 1)
    seg = jax.ops.segment_sum(dense, idx, num_segments=v.runs.shape[0])
    return jnp.sum(seg * v.values)


def rle_dot_rle(a: RLEVector, b: RLEVector) -> jax.Array:
    """Two-pointer merge over runs — data-dependent control flow via
    ``lax.while_loop`` (the paper's C inner loop, TPU-scalar edition)."""
    def cond(c):
        i, j, ra, rb, acc = c
        return jnp.logical_and(i < a.n_runs, j < b.n_runs)

    def body(c):
        i, j, ra, rb, acc = c
        step = jnp.minimum(ra, rb)
        acc = acc + a.values[i] * b.values[j] * step.astype(a.values.dtype)
        ra2, rb2 = ra - step, rb - step
        adv_a = ra2 == 0
        adv_b = rb2 == 0
        i2 = i + adv_a.astype(jnp.int32)
        j2 = j + adv_b.astype(jnp.int32)
        ra2 = jnp.where(adv_a, a.runs[jnp.clip(i2, 0, a.runs.shape[0] - 1)],
                        ra2)
        rb2 = jnp.where(adv_b, b.runs[jnp.clip(j2, 0, b.runs.shape[0] - 1)],
                        rb2)
        return i2, j2, ra2, rb2, acc

    init = (jnp.int32(0), jnp.int32(0), a.runs[0], b.runs[0],
            jnp.zeros((), a.values.dtype))
    *_, acc = jax.lax.while_loop(cond, body, init)
    return acc
