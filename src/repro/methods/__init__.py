"""MADlib method library (paper Table 1 + Table 2 + Table 3), in JAX.

Supervised:   linregr, logregr, naive_bayes, decision_tree, svm
Unsupervised: kmeans, svd, lda, assoc_rules
Descriptive:  sketches (count-min, Flajolet-Martin), quantiles, profile
Support:      sparse_vector, array_ops, conjugate gradient (core.convex)
Text (§5.2):  crf (features, Viterbi, MCMC), string_match (q-grams)
SGD models (§5.1 Table 2): sgd_models

Execution conventions: method wrappers are DECLARATIVE — they emit
logical plan nodes (``core.plan``: ``ScanAgg`` / ``GroupedScanAgg`` /
``IterativeFit`` / ``StreamAgg``) and never call
``run_local``/``run_sharded`` directly (CI greps for it); the planner
picks engines cost-based, fuses compatible statements into shared scans
(batch several via ``core.session.Session``) and dedups partitioning
sorts.  ``profile`` is a thin planned batch whose single-pass execution
falls out of the optimizer.  Methods with a Pallas hot loop (linregr,
sketches, kmeans) take ``use_kernel`` (True = backend-aware auto
dispatch through ``kernels.registry``, "pallas"/"ref" force an
implementation).

Iterative methods (logregr IRLS, kmeans Lloyd, lda EM, the convex
solvers) register an ``IterativeTask`` and run under
``core.iterative.fit`` — never a hand-rolled loop — which gives every
one of them the compiled while-loop fast path, sharded and streaming
execution, warm starts, and per-group (GROUP BY) fitting via
``fit_grouped`` (``logregr_grouped`` / ``linregr_grouped`` /
``kmeans_grouped``).

GROUP BY execution goes through the partitioned grouped-scan core
(``core.aggregates.run_grouped`` / ``core.iterative.fit_grouped``) —
methods never build their own per-group equality masks over the id
column (CI greps for it).  One-pass grouped forms:
``naive_bayes_grouped``, ``quantiles_grouped``,
``countmin_sketch_grouped``, ``fm_distinct_count_grouped``.  Every
grouped wrapper forwards ``mesh=`` (defaulting to the table's) to the
sharded grouped engine, so GROUP BY methods scale across the mesh with
no per-method code.
"""

from . import (  # noqa: F401
    array_ops,
    assoc_rules,
    crf,
    decision_tree,
    kmeans,
    lda,
    linregr,
    logregr,
    naive_bayes,
    profile,
    quantiles,
    sgd_models,
    sketches,
    sparse_vector,
    string_match,
    svd,
    svm,
)
