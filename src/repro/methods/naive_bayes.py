"""Naive Bayes classification (paper Table 1) as a single-pass UDA.

Gaussian NB over continuous features: per-class sufficient statistics
(count, per-feature sum, sum-of-squares) accumulate in the transition;
merge = sum; final converts to class priors + per-class feature
mean/variance.  Prediction is a pure map (a templated SELECT).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.aggregates import Aggregate, MERGE_SUM
from ..core.plan import GroupedScanAgg, ScanAgg, execute
from ..core.table import Table


@dataclasses.dataclass
class NaiveBayesModel:
    log_prior: jax.Array   # (C,)
    mean: jax.Array        # (C, d)
    var: jax.Array         # (C, d)


jax.tree_util.register_pytree_node(
    NaiveBayesModel,
    lambda m: ((m.log_prior, m.mean, m.var), None),
    lambda _, c: NaiveBayesModel(*c),
)


class NaiveBayesAggregate(Aggregate):
    merge_ops = MERGE_SUM

    def __init__(self, num_classes: int, var_smoothing: float = 1e-6):
        self.num_classes = num_classes
        self.var_smoothing = var_smoothing

    def cache_key(self):
        return ("naive_bayes", self.num_classes, self.var_smoothing)

    def init(self, block):
        d = block["x"].shape[-1]
        c = self.num_classes
        return {
            "count": jnp.zeros((c,)),
            "sum": jnp.zeros((c, d)),
            "sumsq": jnp.zeros((c, d)),
        }

    def transition(self, state, block, mask):
        x = block["x"]
        y = block["y"].astype(jnp.int32)
        onehot = jax.nn.one_hot(y, self.num_classes) * \
            mask.astype(jnp.float32)[:, None]
        return {
            "count": state["count"] + jnp.sum(onehot, 0),
            "sum": state["sum"] + onehot.T @ x,
            "sumsq": state["sumsq"] + onehot.T @ (x * x),
        }

    def final(self, s):
        n = jnp.maximum(s["count"][:, None], 1.0)
        mean = s["sum"] / n
        var = jnp.maximum(s["sumsq"] / n - mean ** 2, 0.0) + self.var_smoothing
        total = jnp.maximum(jnp.sum(s["count"]), 1.0)
        log_prior = jnp.log(jnp.maximum(s["count"], 1e-12) / total)
        return NaiveBayesModel(log_prior, mean, var)


def naive_bayes_fit(table: Table, num_classes: int, *,
                    block_size: int | None = None) -> NaiveBayesModel:
    agg = NaiveBayesAggregate(num_classes)
    return execute(ScanAgg(agg, table, columns=("x", "y"),
                           block_size=block_size, label="naive_bayes"))


def naive_bayes_grouped(table: Table, key_col: str, num_classes: int,
                        num_groups: int | None = None, *,
                        block_size: int | None = None,
                        method: str = "auto", mesh=None) -> NaiveBayesModel:
    """``SELECT g, naive_bayes(...) FROM data GROUP BY g`` — one NB model
    per group through the partitioned grouped-scan core; every model field
    carries a leading group axis.  ``mesh`` (defaulting to the table's)
    engages the sharded grouped engine."""
    return execute(GroupedScanAgg(
        NaiveBayesAggregate(num_classes), table, key_col, num_groups,
        columns=("x", "y"), block_size=block_size, method=method,
        mesh=mesh, label="naive_bayes_grouped"))


@jax.jit
def naive_bayes_predict(model: NaiveBayesModel, x: jax.Array) -> jax.Array:
    """argmax_c [ log p(c) + Σ_j log N(x_j; μ_cj, σ²_cj) ]"""
    ll = -0.5 * jnp.sum(
        jnp.log(2.0 * jnp.pi * model.var)[None]
        + (x[:, None, :] - model.mean[None]) ** 2 / model.var[None],
        axis=-1,
    )
    return jnp.argmax(model.log_prior[None] + ll, axis=-1)
