"""k-means clustering — the paper's large-state iteration example (§4.3).

Two execution variants, per DESIGN.md §2:

* ``two_pass`` (paper-faithful): PostgreSQL executes queries one at a time,
  so one Lloyd round = an UPDATE of the ``centroid_id`` column (pass 1) and
  a barycenter aggregate (pass 2).  We reproduce that dataflow: an explicit
  assignment column plus a separate aggregation, with reassignment counting
  for the paper's convergence criterion ("no or only few points got
  reassigned").
* ``fused`` (beyond-paper): XLA has no one-statement-at-a-time limitation —
  assignment + barycenter + reassignment count fuse into ONE pass (the
  paper's footnote 1 says standard SQL *cannot* express this).  Optionally
  routed through the kernels/kmeans_assign Pallas kernel.

Seeding: k-means++ [5], one distance UDA per seed pick.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from ..core.aggregates import Aggregate, MERGE_SUM, run_local, run_sharded
from ..core.table import Table
from ..kernels.registry import dispatch, resolve_impl


def _sq_dists(x: jax.Array, c: jax.Array) -> jax.Array:
    """(n,d),(k,d) -> (n,k) squared distances via the matmul identity."""
    xx = jnp.sum(x * x, -1, keepdims=True)
    cc = jnp.sum(c * c, -1)
    return xx - 2.0 * (x @ c.T) + cc[None, :]


class KMeansAggregate(Aggregate):
    """One Lloyd round as a UDA.

    Inter-iteration state = centroids (closed over, device-resident);
    intra-iteration state = {sums, counts, sse, moved} — exactly the
    paper's inter/intra split (§4.3.1).  ``moved`` is computed against the
    previous assignment column when provided (two-pass mode) or against the
    previous centroids' assignment (fused mode does both assigns in one
    pass — still one data read)."""

    merge_ops = MERGE_SUM

    def __init__(self, centroids: jax.Array, prev_centroids: jax.Array | None,
                 use_kernel: bool | str = False):
        self.centroids = centroids
        self.prev_centroids = prev_centroids
        self.kernel_impl = resolve_impl(use_kernel)

    def init(self, block):
        k, d = self.centroids.shape
        f = self.centroids.dtype
        return {
            "sums": jnp.zeros((k, d), f),
            "counts": jnp.zeros((k,), f),
            "sse": jnp.zeros((), f),
            "moved": jnp.zeros((), f),
        }

    def transition(self, state, block, mask):
        x = block["x"]
        m = mask.astype(x.dtype)
        if "centroid_id" in block:
            # two-pass mode: barycenters by the STORED assignment column
            # (this pass does no closest-centroid computation — the paper's
            # "avoid half of the closest-centroid calculations").
            assign = block["centroid_id"].astype(jnp.int32)
            d2 = _sq_dists(x, self.centroids)
            mind = jnp.take_along_axis(d2, assign[:, None], 1)[:, 0]
            onehot = jax.nn.one_hot(assign, self.centroids.shape[0],
                                    dtype=x.dtype) * m[:, None]
            sums = onehot.T @ x
            counts = jnp.sum(onehot, axis=0)
            moved = jnp.zeros((), x.dtype)
        else:
            if self.kernel_impl is not None:
                assign, mind, sums, counts = dispatch(
                    "kmeans_assign", x, self.centroids, m,
                    impl=self.kernel_impl)
            else:
                d2 = _sq_dists(x, self.centroids)
                assign = jnp.argmin(d2, axis=-1)
                mind = jnp.min(d2, axis=-1)
                onehot = jax.nn.one_hot(assign, self.centroids.shape[0],
                                        dtype=x.dtype) * m[:, None]
                sums = onehot.T @ x
                counts = jnp.sum(onehot, axis=0)
            if self.prev_centroids is not None:
                # fused mode: both assignments in ONE data read (footnote 1:
                # SQL can't; XLA can).
                prev_assign = jnp.argmin(_sq_dists(x, self.prev_centroids),
                                         -1)
                moved = jnp.sum((prev_assign != assign) * m)
            else:
                moved = jnp.zeros((), x.dtype)
        return {
            "sums": state["sums"] + sums,
            "counts": state["counts"] + counts,
            "sse": state["sse"] + jnp.sum(mind * m),
            "moved": state["moved"] + moved,
        }

    def final(self, s):
        safe = jnp.maximum(s["counts"][:, None], 1.0)
        new_c = jnp.where(s["counts"][:, None] > 0, s["sums"] / safe,
                          self.centroids)
        return {"centroids": new_c, "sse": s["sse"], "moved": s["moved"],
                "counts": s["counts"]}


@dataclasses.dataclass
class KMeansResult:
    centroids: jax.Array
    sse: float
    n_iters: int
    converged: bool
    sse_trace: list


def _run(agg, table, block_size):
    if table.mesh is not None:
        return run_sharded(agg, table, block_size=block_size)
    return run_local(agg, table, block_size=block_size)


def kmeans_pp_seed(table: Table, k: int, key: jax.Array,
                   x_col: str = "x") -> jax.Array:
    """k-means++ seeding [5]: one D² pass per pick (k UDA rounds)."""
    x = table[x_col]
    n = x.shape[0]
    key, sub = jax.random.split(key)
    first = x[jax.random.randint(sub, (), 0, n)]
    cents = first[None, :]
    for _ in range(1, k):
        d2 = jnp.min(_sq_dists(x, cents), axis=-1)
        key, sub = jax.random.split(key)
        probs = d2 / jnp.maximum(jnp.sum(d2), 1e-30)
        idx = jax.random.choice(sub, n, p=probs)
        cents = jnp.concatenate([cents, x[idx][None, :]], axis=0)
    return cents


def kmeans_fit(table: Table, k: int, *, key: jax.Array | None = None,
               max_iters: int = 50, reassign_frac_tol: float = 0.0,
               variant: str = "fused", block_size: int | None = None,
               init_centroids: jax.Array | None = None,
               use_kernel: bool | str = False, x_col: str = "x"
               ) -> KMeansResult:
    """Lloyd's algorithm under a MADlib driver (§3.1.2 pattern)."""
    assert variant in ("fused", "two_pass")
    key = key if key is not None else jax.random.PRNGKey(0)
    t = Table({"x": table[x_col]}, table.mesh, table.row_axes)
    cents = (init_centroids if init_centroids is not None
             else kmeans_pp_seed(t, k, key))
    n = t.n_rows
    prev = None
    assign_col = None
    sse_trace = []
    converged = False
    it = 0

    if variant == "two_pass":
        # statement 0: materialize the assignment column
        # (UPDATE points SET centroid_id = closest_column(centroids, coords))
        assign_col = jnp.argmin(_sq_dists(t["x"], cents), axis=-1)

    for it in range(1, max_iters + 1):
        if variant == "two_pass":
            # statement 1 (data pass 1): barycenters by stored assignment
            data = t.with_column("centroid_id", assign_col)
            out = _run(KMeansAggregate(cents, None, use_kernel), data,
                       block_size)
            # statement 2 (data pass 2): refresh assignments, count moves
            new_assign = jnp.argmin(
                _sq_dists(t["x"], out["centroids"]), -1)
            moved = float(jnp.sum(new_assign != assign_col))
            assign_col = new_assign
        else:
            out = _run(KMeansAggregate(cents, prev, use_kernel), t,
                       block_size)
            moved = float(out["moved"])
        prev = cents
        cents = out["centroids"]
        sse_trace.append(float(out["sse"]))
        if it > 1 and moved <= reassign_frac_tol * n:
            converged = True
            break
    return KMeansResult(cents, sse_trace[-1], it, converged, sse_trace)
