"""k-means clustering — the paper's large-state iteration example (§4.3).

Both execution variants are tasks under the unified iterative executor
(:mod:`repro.core.iterative`) — no hand-rolled Lloyd loop remains:

* :class:`KMeansTask` (``variant="fused"``, beyond-paper): assignment +
  barycenter + reassignment count fuse into ONE pass per round (the
  paper's footnote 1 says standard SQL *cannot* express this).
  Optionally routed through the kernels/kmeans_assign Pallas kernel.
* :class:`KMeansTwoPassTask` (``variant="two_pass"``, paper-faithful):
  PostgreSQL executes one statement at a time, so a Lloyd round is TWO
  passes — barycenters by the *stored* assignment column (statement 1),
  then an UPDATE of that column counting reassignments (statement 2).
  The assignment column is driver state; blocks address it through a
  ``__row__`` index column, and the update pass writes it back as a
  scatter-valued UDA (each row owned by exactly one block ⇒ sum-merge).

Through the executor, both variants inherit sharded execution, warm
starts (``init_centroids``), and — for the fused task — per-group
fitting (:func:`kmeans_grouped`).

Seeding: k-means++ [5], with each round's D² statistics computed in ONE
fused scan via ``run_many`` (a sum aggregate for the normalizer/potential
plus a Gumbel-max argmax aggregate that samples the next seed ∝ D²
without materializing the CDF) instead of a fresh all-centers distance
pass per pick.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core.aggregates import Aggregate, MERGE_SUM
from ..core.iterative import IterativeTask
from ..core.plan import IterativeFit, execute
from ..core.session import Session
from ..core.table import Table
from ..kernels.registry import dispatch, resolve_impl


def _sq_dists(x: jax.Array, c: jax.Array) -> jax.Array:
    """(n,d),(k,d) -> (n,k) squared distances via the matmul identity."""
    xx = jnp.sum(x * x, -1, keepdims=True)
    cc = jnp.sum(c * c, -1)
    return xx - 2.0 * (x @ c.T) + cc[None, :]


class KMeansAggregate(Aggregate):
    """One fused Lloyd round as a UDA.

    Inter-iteration state = centroids (closed over, device-resident);
    intra-iteration state = {sums, counts, sse, moved} — exactly the
    paper's inter/intra split (§4.3.1).  ``moved`` counts rows whose
    assignment changed vs ``prev_centroids`` — both assigns happen in the
    same data read (footnote 1: SQL can't; XLA can)."""

    merge_ops = MERGE_SUM

    def __init__(self, centroids: jax.Array, prev_centroids: jax.Array | None,
                 use_kernel: bool | str = False):
        self.centroids = centroids
        self.prev_centroids = prev_centroids
        self.kernel_impl = resolve_impl(use_kernel)

    def init(self, block):
        k, d = self.centroids.shape
        f = self.centroids.dtype
        return {
            "sums": jnp.zeros((k, d), f),
            "counts": jnp.zeros((k,), f),
            "sse": jnp.zeros((), f),
            "moved": jnp.zeros((), f),
        }

    def transition(self, state, block, mask):
        x = block["x"]
        m = mask.astype(x.dtype)
        if self.kernel_impl is not None:
            assign, mind, sums, counts = dispatch(
                "kmeans_assign", x, self.centroids, m,
                impl=self.kernel_impl)
        else:
            d2 = _sq_dists(x, self.centroids)
            assign = jnp.argmin(d2, axis=-1)
            mind = jnp.min(d2, axis=-1)
            onehot = jax.nn.one_hot(assign, self.centroids.shape[0],
                                    dtype=x.dtype) * m[:, None]
            sums = onehot.T @ x
            counts = jnp.sum(onehot, axis=0)
        if self.prev_centroids is not None:
            prev_assign = jnp.argmin(_sq_dists(x, self.prev_centroids), -1)
            moved = jnp.sum((prev_assign != assign) * m)
        else:
            moved = jnp.zeros((), x.dtype)
        return {
            "sums": state["sums"] + sums,
            "counts": state["counts"] + counts,
            "sse": state["sse"] + jnp.sum(mind * m),
            "moved": state["moved"] + moved,
        }

    def final(self, s):
        safe = jnp.maximum(s["counts"][:, None], 1.0)
        new_c = jnp.where(s["counts"][:, None] > 0, s["sums"] / safe,
                          self.centroids)
        return {"centroids": new_c, "sse": s["sse"], "moved": s["moved"],
                "counts": s["counts"]}


class KMeansStoredAssignAggregate(Aggregate):
    """Statement 1 of the two-pass round: barycenters by the STORED
    assignment column (no closest-centroid computation beyond the sse
    lookup — the paper's "avoid half of the closest-centroid
    calculations").  The (n,) assignment lives in driver state; blocks
    address it through the ``__row__`` index column."""

    merge_ops = MERGE_SUM

    def __init__(self, centroids: jax.Array, assign: jax.Array):
        self.centroids = centroids
        self.assign = assign

    def init(self, block):
        k, d = self.centroids.shape
        f = self.centroids.dtype
        return {
            "sums": jnp.zeros((k, d), f),
            "counts": jnp.zeros((k,), f),
            "sse": jnp.zeros((), f),
        }

    def transition(self, state, block, mask):
        x = block["x"]
        m = mask.astype(x.dtype)
        assign = self.assign[block["__row__"]]
        d2 = _sq_dists(x, self.centroids)
        mind = jnp.take_along_axis(d2, assign[:, None], 1)[:, 0]
        onehot = jax.nn.one_hot(assign, self.centroids.shape[0],
                                dtype=x.dtype) * m[:, None]
        return {
            "sums": state["sums"] + onehot.T @ x,
            "counts": state["counts"] + jnp.sum(onehot, axis=0),
            "sse": state["sse"] + jnp.sum(mind * m),
        }

    def final(self, s):
        safe = jnp.maximum(s["counts"][:, None], 1.0)
        new_c = jnp.where(s["counts"][:, None] > 0, s["sums"] / safe,
                          self.centroids)
        return {"centroids": new_c, "sse": s["sse"], "counts": s["counts"]}


class KMeansReassignAggregate(Aggregate):
    """Statement 2: ``UPDATE points SET centroid_id = closest(...)`` as a
    scatter-valued UDA plus the reassignment count.  Each row is owned by
    exactly one block/shard, so the scattered column sum-merges."""

    merge_ops = MERGE_SUM

    def __init__(self, centroids: jax.Array, prev_assign: jax.Array):
        self.centroids = centroids
        self.prev_assign = prev_assign

    def init(self, block):
        n = self.prev_assign.shape[0]
        return {"assign": jnp.zeros((n,), jnp.int32),
                "moved": jnp.zeros(())}

    def transition(self, state, block, mask):
        rows = block["__row__"]
        assign = jnp.argmin(_sq_dists(block["x"], self.centroids), -1) \
            .astype(jnp.int32)
        m32 = mask.astype(jnp.int32)
        prev = self.prev_assign[rows]
        moved = jnp.sum(((assign != prev) & mask).astype(jnp.float32))
        return {
            "assign": state["assign"].at[rows].add(assign * m32),
            "moved": state["moved"] + moved,
        }


class KMeansTask(IterativeTask):
    """Fused Lloyd iteration: ONE shared scan per round."""

    def __init__(self, init_centroids: jax.Array,
                 use_kernel: bool | str = False):
        self.init_centroids = init_centroids
        self.use_kernel = use_kernel

    def init_state(self, columns):
        c = jnp.asarray(self.init_centroids)
        return {"cents": c, "prev": c, "it": jnp.int32(0)}

    def make_aggregate(self, state):
        return KMeansAggregate(state["cents"], state["prev"],
                               self.use_kernel)

    def update(self, state, out):
        return {"cents": out["centroids"], "prev": state["cents"],
                "it": state["it"] + 1}

    def metric(self, prev, new, out):
        # reassignment fraction; first round has no meaningful count
        n = jnp.maximum(jnp.sum(out["counts"]), 1.0)
        return jnp.where(new["it"] <= 1, jnp.inf, out["moved"] / n)

    def trace_record(self, state, out, m):
        return out["sse"]


class KMeansTwoPassTask(IterativeTask):
    """Paper-faithful Lloyd iteration: two statements (= two data passes)
    per round, with the assignment column as driver state.  (No
    ``use_kernel``: neither statement computes the fused assign+barycenter
    the kmeans_assign kernel implements — matching pre-refactor, which
    never dispatched it on the two-pass path either.)"""

    def __init__(self, init_centroids: jax.Array):
        self.init_centroids = init_centroids

    def init_state(self, columns):
        c = jnp.asarray(self.init_centroids)
        # statement 0: materialize the assignment column
        assign = jnp.argmin(_sq_dists(columns["x"], c), -1).astype(jnp.int32)
        return {"cents": c, "assign": assign, "it": jnp.int32(0)}

    def iteration(self, state, run_pass):
        # statement 1 (data pass 1): barycenters by stored assignment
        out = run_pass(KMeansStoredAssignAggregate(state["cents"],
                                                   state["assign"]))
        # statement 2 (data pass 2): refresh assignments, count moves
        upd = run_pass(KMeansReassignAggregate(out["centroids"],
                                               state["assign"]))
        new = {"cents": out["centroids"], "assign": upd["assign"],
               "it": state["it"] + 1}
        n = jnp.maximum(jnp.sum(out["counts"]), 1.0)
        m = jnp.where(new["it"] <= 1, jnp.inf, upd["moved"] / n)
        return new, {"sse": out["sse"], "counts": out["counts"]}, m

    def trace_record(self, state, out, m):
        return out["sse"]


@dataclasses.dataclass
class KMeansResult:
    centroids: jax.Array
    sse: float
    n_iters: int
    converged: bool
    sse_trace: list


# ---------------------------------------------------------------------------
# k-means++ seeding: one fused scan per round (ROADMAP open item).
# ---------------------------------------------------------------------------

class SumD2Aggregate(Aggregate):
    """Normalizer Σ D² (the k-means++ "potential") of the running d2 column."""

    merge_ops = MERGE_SUM

    def init(self, block):
        return jnp.zeros(())

    def transition(self, state, block, mask):
        return state + jnp.sum(block["d2"] * mask.astype(jnp.float32))


class GumbelPickAggregate(Aggregate):
    """Samples one row index ∝ its ``d2`` column in a single scan via the
    Gumbel-max trick: argmax(log d2 + Gumbel) over rows.  The argmax
    state (score, winning row's x) uses a generic merge."""

    merge_ops = None  # generic: compare-and-keep is not leaf-wise

    def __init__(self, key: jax.Array, d: int):
        self.key = key
        self.d = d

    def init(self, block):
        return {"score": jnp.full((), -jnp.inf),
                "x": jnp.zeros((self.d,), block["x"].dtype)}

    def transition(self, state, block, mask):
        rows = block["__row__"]
        keys = jax.vmap(partial(jax.random.fold_in, self.key))(rows)
        u = jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)
        gumbel = -jnp.log(-jnp.log(jnp.clip(u, 1e-12, 1.0 - 1e-12)))
        score = jnp.where(
            mask & (block["d2"] > 0.0),
            jnp.log(jnp.maximum(block["d2"], 1e-30)) + gumbel, -jnp.inf)
        i = jnp.argmax(score)
        cand = {"score": score[i], "x": block["x"][i]}
        return self.merge(state, cand)

    def merge(self, a, b):
        take_b = b["score"] > a["score"]
        return jax.tree.map(lambda xa, xb: jnp.where(take_b, xb, xa), a, b)


def kmeans_pp_seed(table: Table, k: int, key: jax.Array,
                   x_col: str = "x") -> jax.Array:
    """k-means++ seeding [5] in ONE fused scan per pick: the D² normalizer
    (potential) and the Gumbel-max sampler are two planned statements
    over the same round table, and the scan-sharing optimizer fuses them
    into one pass; the running D² column is refreshed against only the
    newest center (instead of re-scanning all centers each round)."""
    x = table[x_col]
    n, d = x.shape
    key, sub = jax.random.split(key)
    cents = [x[jax.random.randint(sub, (), 0, n)]]
    rows = jnp.arange(n, dtype=jnp.int32)
    d2 = jnp.sum((x - cents[0][None, :]) ** 2, -1)
    for r in range(1, k):
        key, sub = jax.random.split(key)
        t = Table({"x": x, "d2": d2, "__row__": rows}, table.mesh,
                  table.row_axes)
        sess = Session()
        z = sess.scan(SumD2Aggregate(), t, label="kmeans++:potential")
        pick = sess.scan(GumbelPickAggregate(sub, d), t,
                         label="kmeans++:pick")
        sess.run()
        # degenerate potential (all points on centers): fall back to row 0
        newc = jnp.where(z.result() > 0.0, pick.result()["x"], x[0])
        cents.append(newc)
        d2 = jnp.minimum(d2, jnp.sum((x - newc[None, :]) ** 2, -1))
    return jnp.stack(cents)


# ---------------------------------------------------------------------------
# Drivers.
# ---------------------------------------------------------------------------

def kmeans_fit(table: Table, k: int, *, key: jax.Array | None = None,
               max_iters: int = 50, reassign_frac_tol: float = 0.0,
               variant: str = "fused", block_size: int | None = None,
               init_centroids: jax.Array | None = None,
               init: str = "kmeans++", use_kernel: bool | str = False,
               x_col: str = "x", mode: str = "compiled") -> KMeansResult:
    """Lloyd's algorithm under the unified executor (§3.1.2 pattern).

    ``init_centroids`` warm-starts the task; otherwise ``init`` picks the
    seeding ("kmeans++" = the fused one-scan-per-round seeding, "random"
    = uniform rows).  Converges when the reassignment fraction drops to
    ``reassign_frac_tol`` (checked from round 2, like the paper's "no or
    only few points got reassigned")."""
    assert variant in ("fused", "two_pass")
    key = key if key is not None else jax.random.PRNGKey(0)
    t = Table({"x": table[x_col]}, table.mesh, table.row_axes)
    n = t.n_rows
    if init_centroids is not None:
        cents = jnp.asarray(init_centroids)
    elif init == "kmeans++":
        cents = kmeans_pp_seed(t, k, key)
    elif init == "random":
        cents = t["x"][jax.random.choice(key, n, (k,), replace=False)]
    else:
        raise ValueError(f"unknown init {init!r}")

    if variant == "two_pass":
        t = t.with_column("__row__", jnp.arange(n, dtype=jnp.int32))
        task: IterativeTask = KMeansTwoPassTask(cents)
    else:
        task = KMeansTask(cents, use_kernel)
    # moved/n is an integer multiple of 1/n, so +0.5/n makes "< tol"
    # exactly the paper's "moved <= reassign_frac_tol * n"
    res = execute(IterativeFit(task, t, max_iters=max_iters,
                               tol=reassign_frac_tol + 0.5 / n,
                               block_size=block_size, mode=mode,
                               label="kmeans"))
    sse_trace = [float(v) for v in res.trace]
    return KMeansResult(res.state["cents"], sse_trace[-1], res.n_iters,
                        res.converged, sse_trace)


def kmeans_grouped(table: Table, key_col: str, k: int,
                   num_groups: int | None = None, *,
                   init_centroids: jax.Array, max_iters: int = 50,
                   reassign_frac_tol: float = 0.0,
                   x_col: str = "x", mesh=None) -> KMeansResult:
    """One k-means model per group in shared scans (GROUP BY fitting).

    ``init_centroids`` is required — either one ``(k, d)`` seeding shared
    by every group or a stacked ``(G, k, d)`` per-group seeding.  Returns
    a :class:`KMeansResult` whose fields carry a leading group axis.
    ``mesh`` (defaulting to the table's) runs the whole grouped Lloyd
    loop on the sharded segment layout."""
    t = Table({"x": table[x_col], key_col: table[key_col]}, table.mesh,
              table.row_axes)
    init_centroids = jnp.asarray(init_centroids)
    task = KMeansTask(init_centroids if init_centroids.ndim == 2
                      else init_centroids[0])
    warm = None
    if init_centroids.ndim == 3:
        warm = {"cents": init_centroids, "prev": init_centroids,
                "it": jnp.zeros((init_centroids.shape[0],), jnp.int32)}
    n = t.n_rows
    res = execute(IterativeFit(task, t, group_col=key_col,
                               num_groups=num_groups, max_iters=max_iters,
                               tol=reassign_frac_tol + 0.5 / n,
                               warm_start=warm, mesh=mesh,
                               label="kmeans_grouped"))
    sse = res.trace[np.arange(len(res.n_iters)), res.n_iters - 1] \
        if res.trace.size else res.trace
    return KMeansResult(res.state["cents"], sse, res.n_iters,
                        res.converged, res.trace)
