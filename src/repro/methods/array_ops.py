"""Array operations support module (paper Table 1).

Thin, typed wrappers over jnp — the MADlib ``array_*`` UDF surface.  These
exist so method code (and users) write intent-revealing calls; XLA fuses
them away.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def array_add(a, b):
    return jnp.add(a, b)


def array_sub(a, b):
    return jnp.subtract(a, b)


def array_mult(a, b):
    return jnp.multiply(a, b)


def array_div(a, b):
    return jnp.divide(a, b)


def array_dot(a, b):
    return jnp.vdot(a, b)


def array_scalar_mult(a, s):
    return a * s


def array_sum(a, axis=None):
    return jnp.sum(a, axis=axis)


def array_mean(a, axis=None):
    return jnp.mean(a, axis=axis)


def array_max(a, axis=None):
    return jnp.max(a, axis=axis)


def array_min(a, axis=None):
    return jnp.min(a, axis=axis)


def array_sqrt(a):
    return jnp.sqrt(a)


def array_pow(a, p):
    return jnp.power(a, p)


def norm1(a):
    return jnp.sum(jnp.abs(a))


def norm2(a):
    return jnp.sqrt(jnp.sum(a * a))


def array_filter(a, predicate, fill=0.0):
    """Masked filter with static shape (SQL WHERE over array elements)."""
    return jnp.where(predicate(a), a, fill)


def closest_column(matrix: jax.Array, vec: jax.Array):
    """MADlib's closest_column(a, b) used by k-means (§4.3): index of the
    matrix ROW closest to ``vec`` (MADlib stores centroids column-wise;
    row-wise here) plus the distance."""
    d2 = jnp.sum((matrix - vec[None, :]) ** 2, axis=-1)
    idx = jnp.argmin(d2)
    return idx, jnp.sqrt(d2[idx])
