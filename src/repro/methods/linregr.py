"""Ordinary least squares — the paper's single-pass UDA example (§4.1).

State: ``X^T X`` (symmetric, accumulated as a blocked rank-TILE MXU update —
see kernels/xtx for the Pallas hot loop), ``X^T y``, and scalar moments of
``y``.  merge = sum (associative ⇒ data parallelism "for free", §4.1);
final = pseudo-inverse solve + the output statistics MADlib's linregr
returns (R², std errors, t-stats, p-values, condition number — Listing 2
computes the condition number of ``X^T X``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.aggregates import Aggregate, MERGE_SUM
from ..core.iterative import IterativeTask
from ..core.join import Join
from ..core.plan import GroupedScanAgg, JoinedGroupedScanAgg, ScanAgg, \
    execute
from ..core.table import Table
from ..kernels.registry import dispatch, resolve_impl


@dataclasses.dataclass
class LinregrResult:
    coef: jax.Array
    r2: jax.Array
    std_err: jax.Array
    t_stats: jax.Array
    p_values: jax.Array
    condition_no: jax.Array
    num_rows: jax.Array


class LinregrAggregate(Aggregate):
    """(init, transition, merge, final) for OLS.  ``use_kernel`` routes the
    inner X^T X update through the kernel registry: True = backend-aware
    auto dispatch (compiled Pallas on TPU, jnp ref elsewhere); "pallas" /
    "ref" force an implementation."""

    merge_ops = MERGE_SUM
    # grouped hot path: the whole segment fold as one fused Pallas grid
    # loop (kernels/segment_fold), dispatched by name via the registry
    segment_kernel = "segment_linregr"
    # planner calibration bucket (measured cost tables key on this)
    cost_class = "xtx"

    def __init__(self, use_kernel: bool | str = False):
        self.kernel_impl = resolve_impl(use_kernel)

    def cache_key(self):
        return ("linregr", self.kernel_impl)

    def segment_kernel_args(self, columns, valid, block_gids, num_groups):
        return ((columns["x"], columns["y"], valid, block_gids),
                {"num_groups": num_groups})

    def init(self, block):
        d = block["x"].shape[-1]
        f = block["x"].dtype
        return {
            "xtx": jnp.zeros((d, d), f),
            "xty": jnp.zeros((d,), f),
            "y_sum": jnp.zeros((), f),
            "y_sq": jnp.zeros((), f),
            "n": jnp.zeros((), jnp.float32),
        }

    def transition(self, state, block, mask):
        x = block["x"] * mask[:, None].astype(block["x"].dtype)
        y = block["y"] * mask.astype(block["y"].dtype)
        if self.kernel_impl is not None:
            xtx, xty = dispatch("xtx", x, y, impl=self.kernel_impl)
        else:
            # The paper's v0.3 lesson: express the rank-1 updates as one
            # rank-B update (k,B)@(B,k) — systolic-array native.
            xtx = x.T @ x
            xty = x.T @ y
        return {
            "xtx": state["xtx"] + xtx,
            "xty": state["xty"] + xty,
            "y_sum": state["y_sum"] + jnp.sum(y),
            "y_sq": state["y_sq"] + jnp.sum(y * y),
            "n": state["n"] + jnp.sum(mask.astype(jnp.float32)),
        }

    def final(self, s):
        xtx, xty, n = s["xtx"], s["xty"], s["n"]
        d = xtx.shape[0]
        # SymmetricPositiveDefiniteEigenDecomposition + pseudo-inverse
        # (Listing 2), via eigh.
        w, v = jnp.linalg.eigh(xtx)
        eps = jnp.finfo(xtx.dtype).eps * d * jnp.max(jnp.abs(w))
        inv_w = jnp.where(w > eps, 1.0 / w, 0.0)
        pinv = (v * inv_w) @ v.T
        coef = pinv @ xty
        cond = jnp.max(jnp.abs(w)) / jnp.maximum(jnp.min(jnp.abs(w)), 1e-30)

        sse = s["y_sq"] - 2.0 * coef @ xty + coef @ (xtx @ coef)
        tss = s["y_sq"] - (s["y_sum"] ** 2) / n
        r2 = 1.0 - sse / jnp.maximum(tss, 1e-30)
        dof = jnp.maximum(n - d, 1.0)
        sigma2 = sse / dof
        std_err = jnp.sqrt(jnp.maximum(jnp.diag(pinv) * sigma2, 0.0))
        t = coef / jnp.maximum(std_err, 1e-30)
        p = 2.0 * (1.0 - jax.scipy.stats.norm.cdf(jnp.abs(t)))
        return LinregrResult(coef, r2, std_err, t, p, cond, n)


jax.tree_util.register_pytree_node(
    LinregrResult,
    lambda r: ((r.coef, r.r2, r.std_err, r.t_stats, r.p_values,
                r.condition_no, r.num_rows), None),
    lambda _, c: LinregrResult(*c),
)


class LinregrTask(IterativeTask):
    """OLS as a degenerate (single-pass, counted) executor task — which is
    exactly what buys it ``GROUP BY`` fitting via :func:`fit_grouped`:
    the paper's grouped linregr (§4.1) is ``linregr_grouped`` below."""

    def __init__(self, use_kernel: bool | str = False):
        self.use_kernel = use_kernel

    def init_state(self, columns):
        return jnp.zeros(())  # stateless: everything lives in the pass

    def make_aggregate(self, state):
        return LinregrAggregate(use_kernel=self.use_kernel)

    def update(self, state, out):
        return state

    def finalize(self, state, out):
        return out


def linregr(table: Table, *, x_col: str = "x", y_col: str = "y",
            block_size: int | None = None, use_kernel: bool | str = False
            ) -> LinregrResult:
    """``SELECT (linregr(y, x)).* FROM data`` — one ``ScanAgg`` statement;
    the planner picks local vs sharded from the table's distribution, and
    batching it with other one-pass statistics (via ``Session``) shares
    the scan."""
    return execute(ScanAgg(LinregrAggregate(use_kernel), table,
                           columns={"x": x_col, "y": y_col},
                           block_size=block_size, label="linregr"))


def linregr_grouped(table: Table, key_col: str,
                    num_groups: int | None = None, *, x_col: str = "x",
                    y_col: str = "y", block_size: int | None = None,
                    use_kernel: bool | str = False,
                    mesh=None) -> LinregrResult:
    """``SELECT g, (linregr(y, x)).* FROM data GROUP BY g`` — one model per
    group in a shared scan; every result field has a leading group axis.
    ``mesh`` (defaulting to the table's) runs the scan on the sharded
    grouped engine; the partitioning sort is shared with every other
    grouped statement over the same (table, key) via the group_by memo."""
    return execute(GroupedScanAgg(
        LinregrAggregate(use_kernel), table, key_col, num_groups,
        columns={"x": x_col, "y": y_col}, block_size=block_size,
        mesh=mesh, label="linregr_grouped"))


def linregr_joined(fact: Table, dim: Table, *, fact_key: str,
                   dim_key: str, attr_col: str,
                   on_missing: str = "error",
                   num_groups: int | None = None, x_col: str = "x",
                   y_col: str = "y", block_size: int | None = None,
                   use_kernel: bool | str = False, mesh=None
                   ) -> LinregrResult:
    """``SELECT dim.attr, (linregr(y, x)).* FROM fact JOIN dim ON
    fact.fk = dim.key GROUP BY dim.attr`` — one model per dimension
    attribute, as ONE joined-grouped statement.  The join resolves
    device-side through the :class:`~repro.core.join.Join` node (sort-
    merge against the memoized dimension key sort; the dimension's
    columns are never gathered onto fact rows) and the scan runs on the
    unchanged grouped core; batched with other statements over the same
    star triple it fuses into one pass."""
    return execute(JoinedGroupedScanAgg(
        LinregrAggregate(use_kernel),
        Join(fact, dim, fact_key, dim_key, attr_col,
             on_missing=on_missing),
        num_groups, columns={"x": x_col, "y": y_col},
        block_size=block_size, mesh=mesh, label="linregr_joined"))
