"""Binary logistic regression — the paper's multipass example (§4.2).

Paper-faithful solver: Newton's method as *iteratively reweighted least
squares*, ``β ← (X^T D X)^{-1} X^T D z`` with ``D = diag(p(1-p))`` and
``z = Xβ + D^{-1}(y - p)``.  Each iteration is one UDA execution
(transition accumulates ``X^T D X`` and ``X^T D z``; merge = sum); the
outer loop is :class:`IRLSTask` under the unified iterative executor
(§3.1.2 driver pattern) — which means IRLS inherits the compiled
``lax.while_loop`` fast path, sharded/streaming execution and per-group
(GROUP BY) fitting (:func:`logregr_grouped`) for free.

Also provided: the §5.1 SGD solver over the same objective, for the
Table-2 benchmark.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.aggregates import Aggregate, MERGE_SUM
from ..core.convex import ConvexProgram, sgd as sgd_solver, parallel_sgd
from ..core.iterative import IterativeTask
from ..core.plan import IterativeFit, execute
from ..core.table import Table


@dataclasses.dataclass
class LogregrResult:
    coef: jax.Array
    log_likelihood: jax.Array
    std_err: jax.Array
    z_stats: jax.Array
    p_values: jax.Array
    n_iters: int
    converged: bool


class IRLSAggregate(Aggregate):
    """One IRLS round: accumulate X^T D X, X^T D z, and the log-likelihood."""

    merge_ops = MERGE_SUM

    def __init__(self, beta: jax.Array):
        self.beta = beta

    def init(self, block):
        d = block["x"].shape[-1]
        return {
            "xdx": jnp.zeros((d, d)),
            "xdz": jnp.zeros((d,)),
            "ll": jnp.zeros(()),
            "n": jnp.zeros(()),
        }

    def transition(self, state, block, mask):
        x = block["x"]
        y = block["y"]
        m = mask.astype(x.dtype)
        eta = x @ self.beta
        p = jax.nn.sigmoid(eta)
        w = jnp.maximum(p * (1.0 - p), 1e-10) * m          # D diagonal
        z = eta + (y - p) / jnp.maximum(p * (1.0 - p), 1e-10)
        xw = x * w[:, None]
        ll = jnp.sum(m * (y * eta - jax.nn.softplus(eta)))
        return {
            "xdx": state["xdx"] + xw.T @ x,
            "xdz": state["xdz"] + xw.T @ z,
            "ll": state["ll"] + ll,
            "n": state["n"] + jnp.sum(m),
        }


class IRLSTask(IterativeTask):
    """IRLS as an executor task: state = β; one pass = one IRLSAggregate;
    driver update = the weighted-least-squares solve; metric = relative
    coefficient change; finalize = Wald statistics from the last pass's
    Fisher information."""

    def __init__(self, ridge: float = 1e-8):
        self.ridge = ridge

    def init_state(self, columns):
        return {"beta": jnp.zeros((columns["x"].shape[-1],))}

    def make_aggregate(self, state):
        return IRLSAggregate(state["beta"])

    def update(self, state, out):
        d = out["xdx"].shape[0]
        beta = jnp.linalg.solve(out["xdx"] + self.ridge * jnp.eye(d),
                                out["xdz"])
        return {"beta": beta}

    def metric(self, prev, new, out):
        return jnp.linalg.norm(new["beta"] - prev["beta"]) \
            / (jnp.linalg.norm(prev["beta"]) + 1e-12)

    def finalize(self, state, out):
        # Wald statistics from the final Fisher information (X^T D X)^{-1}.
        beta = state["beta"]
        d = beta.shape[0]
        cov = jnp.linalg.inv(out["xdx"] + 1e-8 * jnp.eye(d))
        se = jnp.sqrt(jnp.maximum(jnp.diag(cov), 0.0))
        z = beta / jnp.maximum(se, 1e-30)
        p = 2.0 * (1.0 - jax.scipy.stats.norm.cdf(jnp.abs(z)))
        return {"coef": beta, "ll": out["ll"], "se": se, "z": z, "p": p}


def _result(res) -> LogregrResult:
    f = res.result
    return LogregrResult(f["coef"], f["ll"], f["se"], f["z"], f["p"],
                         res.n_iters, res.converged)


def logregr(table: Table, *, x_col: str = "x", y_col: str = "y",
            max_iters: int = 30, tol: float = 1e-6,
            block_size: int | None = None, mode: str = "compiled",
            warm_start: jax.Array | None = None) -> LogregrResult:
    """``SELECT * FROM logregr('y', 'x', 'data')`` — IRLS under the
    unified executor (sharded automatically when the table is)."""
    t = Table({"x": table[x_col], "y": table[y_col]}, table.mesh,
              table.row_axes)
    ws = None if warm_start is None else {"beta": jnp.asarray(warm_start)}
    res = execute(IterativeFit(IRLSTask(), t, max_iters=max_iters, tol=tol,
                               block_size=block_size, mode=mode,
                               warm_start=ws, label="logregr"))
    return _result(res)


def logregr_stream(blocks_factory, *, max_iters: int = 30,
                   tol: float = 1e-6) -> LogregrResult:
    """Out-of-core IRLS: each iteration streams the blocks from a fresh
    ``blocks_factory()`` (dicts with "x"/"y") with device-resident state."""
    res = execute(IterativeFit(IRLSTask(), blocks=blocks_factory,
                               max_iters=max_iters, tol=tol,
                               label="logregr_stream"))
    return _result(res)


def logregr_grouped(table: Table, key_col: str,
                    num_groups: int | None = None, *,
                    x_col: str = "x", y_col: str = "y",
                    max_iters: int = 30, tol: float = 1e-6,
                    block_size: int | None = None,
                    mesh=None) -> LogregrResult:
    """One logistic model per group, fit in shared scans
    (``SELECT g, (logregr(y, x)).* FROM data GROUP BY g``).  Every field
    of the result carries a leading group axis; ``n_iters``/``converged``
    are per-group vectors.  ``mesh`` (defaulting to the table's) runs the
    whole frozen-group IRLS loop inside one ``shard_map`` program."""
    t = Table({"x": table[x_col], "y": table[y_col],
               key_col: table[key_col]}, table.mesh, table.row_axes)
    res = execute(IterativeFit(IRLSTask(), t, group_col=key_col,
                               num_groups=num_groups, max_iters=max_iters,
                               tol=tol, block_size=block_size, mesh=mesh,
                               label="logregr_grouped"))
    return _result(res)


# ---------------------------------------------------------------------------
# §5.1 SGD path (Table 2 "Logistic Regression" row).
# ---------------------------------------------------------------------------

def logistic_program(mu: float = 0.0) -> ConvexProgram:
    """Σ log(1 + exp(-y·xᵀw)) with y ∈ {−1,+1} encoded from {0,1}."""

    def loss(params, block, mask):
        sgn = 2.0 * block["y"] - 1.0
        return jnp.sum(jax.nn.softplus(-sgn * (block["x"] @ params))
                       * mask.astype(jnp.float32))

    reg = (lambda p: 0.5 * mu * jnp.sum(p ** 2)) if mu > 0 else None
    return ConvexProgram(loss=loss, regularizer=reg)


def logregr_sgd(table: Table, *, epochs: int = 5, stepsize: float = 0.5,
                batch: int = 128, key=None, mu: float = 0.0) -> jax.Array:
    d = table["x"].shape[-1]
    prog = logistic_program(mu)
    if table.mesh is not None:
        return parallel_sgd(prog, table, jnp.zeros((d,)), stepsize=stepsize,
                            epochs=epochs, batch=batch, key=key)
    return sgd_solver(prog, table, jnp.zeros((d,)), stepsize=stepsize,
                      epochs=epochs, batch=batch, key=key)
