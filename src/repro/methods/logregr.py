"""Binary logistic regression — the paper's multipass example (§4.2).

Paper-faithful solver: Newton's method as *iteratively reweighted least
squares*, ``β ← (X^T D X)^{-1} X^T D z`` with ``D = diag(p(1-p))`` and
``z = Xβ + D^{-1}(y - p)``.  Each iteration is one UDA execution
(transition accumulates ``X^T D X`` and ``X^T D z``; merge = sum); the
outer loop is a driver that keeps state device-resident (§3.1.2).

Also provided: the §5.1 SGD solver over the same objective, for the
Table-2 benchmark.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.aggregates import Aggregate, MERGE_SUM, run_local, run_sharded
from ..core.convex import ConvexProgram, sgd as sgd_solver, parallel_sgd
from ..core.table import Table


@dataclasses.dataclass
class LogregrResult:
    coef: jax.Array
    log_likelihood: jax.Array
    std_err: jax.Array
    z_stats: jax.Array
    p_values: jax.Array
    n_iters: int
    converged: bool


class IRLSAggregate(Aggregate):
    """One IRLS round: accumulate X^T D X, X^T D z, and the log-likelihood."""

    merge_ops = MERGE_SUM

    def __init__(self, beta: jax.Array):
        self.beta = beta

    def init(self, block):
        d = block["x"].shape[-1]
        return {
            "xdx": jnp.zeros((d, d)),
            "xdz": jnp.zeros((d,)),
            "ll": jnp.zeros(()),
            "n": jnp.zeros(()),
        }

    def transition(self, state, block, mask):
        x = block["x"]
        y = block["y"]
        m = mask.astype(x.dtype)
        eta = x @ self.beta
        p = jax.nn.sigmoid(eta)
        w = jnp.maximum(p * (1.0 - p), 1e-10) * m          # D diagonal
        z = eta + (y - p) / jnp.maximum(p * (1.0 - p), 1e-10)
        xw = x * w[:, None]
        ll = jnp.sum(m * (y * eta - jax.nn.softplus(eta)))
        return {
            "xdx": state["xdx"] + xw.T @ x,
            "xdz": state["xdz"] + xw.T @ z,
            "ll": state["ll"] + ll,
            "n": state["n"] + jnp.sum(m),
        }


def _run(agg, table, block_size):
    if table.mesh is not None:
        return run_sharded(agg, table, block_size=block_size)
    return run_local(agg, table, block_size=block_size)


def logregr(table: Table, *, x_col: str = "x", y_col: str = "y",
            max_iters: int = 30, tol: float = 1e-6,
            block_size: int | None = None) -> LogregrResult:
    """``SELECT * FROM logregr('y', 'x', 'data')`` — IRLS driver."""
    t = Table({"x": table[x_col], "y": table[y_col]}, table.mesh,
              table.row_axes)
    d = t["x"].shape[-1]
    beta = jnp.zeros((d,))
    converged = False
    it = 0
    state = None
    for it in range(1, max_iters + 1):
        state = _run(IRLSAggregate(beta), t, block_size)
        ridge = 1e-8 * jnp.eye(d)
        new_beta = jnp.linalg.solve(state["xdx"] + ridge, state["xdz"])
        delta = float(jnp.linalg.norm(new_beta - beta)
                      / (jnp.linalg.norm(beta) + 1e-12))
        beta = new_beta
        if delta < tol:
            converged = True
            break
    # Wald statistics from the final Fisher information (X^T D X)^{-1}.
    cov = jnp.linalg.inv(state["xdx"] + 1e-8 * jnp.eye(d))
    se = jnp.sqrt(jnp.maximum(jnp.diag(cov), 0.0))
    z = beta / jnp.maximum(se, 1e-30)
    p = 2.0 * (1.0 - jax.scipy.stats.norm.cdf(jnp.abs(z)))
    return LogregrResult(beta, state["ll"], se, z, p, it, converged)


# ---------------------------------------------------------------------------
# §5.1 SGD path (Table 2 "Logistic Regression" row).
# ---------------------------------------------------------------------------

def logistic_program(mu: float = 0.0) -> ConvexProgram:
    """Σ log(1 + exp(-y·xᵀw)) with y ∈ {−1,+1} encoded from {0,1}."""

    def loss(params, block, mask):
        sgn = 2.0 * block["y"] - 1.0
        return jnp.sum(jax.nn.softplus(-sgn * (block["x"] @ params))
                       * mask.astype(jnp.float32))

    reg = (lambda p: 0.5 * mu * jnp.sum(p ** 2)) if mu > 0 else None
    return ConvexProgram(loss=loss, regularizer=reg)


def logregr_sgd(table: Table, *, epochs: int = 5, stepsize: float = 0.5,
                batch: int = 128, key=None, mu: float = 0.0) -> jax.Array:
    d = table["x"].shape[-1]
    prog = logistic_program(mu)
    if table.mesh is not None:
        return parallel_sgd(prog, table, jnp.zeros((d,)), stepsize=stepsize,
                            epochs=epochs, batch=batch, key=key)
    return sgd_solver(prog, table, jnp.zeros((d,)), stepsize=stepsize,
                      epochs=epochs, batch=batch, key=key)
