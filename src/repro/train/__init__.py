from .trainer import TrainState, make_train_step, make_serve_step, \
    init_train_state

__all__ = ["TrainState", "make_train_step", "make_serve_step",
           "init_train_state"]
