"""Trainer: the LM train step as a MADlib SGD-UDA instance (DESIGN.md §3).

The decomposition is literal:

  transition — per-microbatch gradient of the sum-decomposable loss
               (``jax.lax.scan`` over gradient-accumulation microbatches:
               the blocked fold of core.aggregates, same contract)
  merge      — the data/pod-axis psum XLA inserts from the shardings
               (associative — the Figure-4 parallelism)
  final      — optimizer update (AdamW = the "comparatively cheap final
               function" of §4.1, k×k-scale work)

The driver around it (launch/train.py) is a MADlib host driver: state
stays donated on device, only scalar metrics cross per round.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import model as M
from ..models.config import ModelConfig
from ..optim import adamw_init, adamw_update, clip_by_global_norm, \
    linear_warmup_cosine
from ..distributed.sharding import (DEFAULT_RULES, activation_sharding,
                                    batch_sharding, param_sharding)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: jax.Array

    def tree_flatten(self):
        return (self.params, self.opt, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_train_state(cfg: ModelConfig, key) -> tuple[TrainState, dict]:
    params, axes = M.init_model(cfg, key)
    opt = adamw_init(params)
    return TrainState(params, opt, jnp.zeros((), jnp.int32)), axes


def make_train_step(cfg: ModelConfig, *, base_lr=3e-4, warmup=100,
                    total_steps=10_000, grad_clip=1.0,
                    grad_accum: int = 1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return M.train_loss(params, cfg, batch)

    def grad_transition(params, batch):
        """UDA transition: gradient of one microbatch block."""
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(state: TrainState, batch):
        if grad_accum == 1:
            loss, metrics, grads = grad_transition(state.params, batch)
        else:
            # blocked fold over microbatches (transition + sum-merge).
            # Keep the per-microbatch example axis on the batch mesh axes.
            from ..distributed.sharding import constrain as _constrain

            def split(x):
                if x.shape[0] % grad_accum == 0:
                    r = x.reshape((grad_accum, x.shape[0] // grad_accum)
                                  + x.shape[1:])
                    return _constrain(r, (None, "batch")
                                      + (None,) * (x.ndim - 1))
                # batch axis is second (e.g. M-RoPE positions (3, B, S))
                assert x.shape[1] % grad_accum == 0, x.shape
                r = x.reshape(x.shape[:1]
                              + (grad_accum, x.shape[1] // grad_accum)
                              + x.shape[2:])
                r = jnp.moveaxis(r, 1, 0)
                return _constrain(r, (None, None, "batch")
                                  + (None,) * (x.ndim - 2))

            micro = jax.tree.map(split, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def fold(acc, mb):
                l_acc, g_acc = acc
                l, mets, g = grad_transition(state.params, mb)
                return (l_acc + l,
                        jax.tree.map(lambda a, b_: a + b_, g_acc, g)), mets

            from ..launch.scan_registry import tagged_scan
            (loss, grads), metrics = tagged_scan(
                "tagscan_grad_accum", fold, (jnp.zeros(()), zero), micro,
                length=grad_accum)
            loss = loss / grad_accum
            metrics = jax.tree.map(lambda m: m[-1], metrics)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)

        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = linear_warmup_cosine(state.step, base_lr=base_lr,
                                  warmup_steps=warmup,
                                  total_steps=total_steps)
        new_params, new_opt = adamw_update(grads, state.opt, state.params,
                                           lr=lr)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """serve_step((params, cache), token, pos) -> (logits, cache)."""

    def serve_step(params, cache, token, pos):
        return M.decode_step(params, cfg, cache, token, pos)

    return serve_step


# ---------------------------------------------------------------------------
# Sharded jit assembly
# ---------------------------------------------------------------------------

def shardings_for_state(state: TrainState, axes, mesh: Mesh,
                        rules=None):
    """NamedShardings for a TrainState: params + fp32 moments share the
    parameter sharding; step is replicated."""
    p_sh = param_sharding(axes, mesh, state.params, rules)
    return TrainState(
        params=p_sh,
        opt=type(state.opt)(p_sh, p_sh,
                            NamedSharding(mesh, P())),
        step=NamedSharding(mesh, P()),
    )


def jit_train_step(train_step, state, axes, batch_spec, mesh,
                   rules=None, donate=True):
    """Wrap train_step in jit with explicit in/out shardings + the logical
    activation-constraint context."""
    rules = rules or DEFAULT_RULES
    state_sh = shardings_for_state(state, axes, mesh, rules)
    batch_sh = batch_sharding(mesh, batch_spec, rules)

    def wrapped(s, b):
        with activation_sharding(mesh, rules):
            return train_step(s, b)

    return jax.jit(
        wrapped,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, NamedSharding(mesh, P())),
        donate_argnums=(0,) if donate else (),
    )
