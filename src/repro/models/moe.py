"""Mixture-of-experts FFN with capacity-factor routing (GShard-style).

Baseline dispatch (this file): sort-based — tokens are bucketed per expert
up to capacity C by an argsort over expert assignments, gathered into an
(E, C, d) tensor sharded over the ``expert``→"model" axis, pushed through
per-expert SwiGLU (one batched einsum on the MXU), and combined back with
the router weights.  Overflow tokens are dropped (recorded in aux stats) —
the classic capacity trade-off; the paper-era alternative (dense one-hot
dispatch) is O(N·E·C) memory and indefensible at LM scale.

The shard_map all-to-all dispatch variant (beyond-paper §Perf candidate)
lives in repro.distributed.ep_a2a.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .layers import ParamStore


def init_moe(store: ParamStore, cfg, name="moe"):
    sub = store.subtree(name)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    sub.add("router", (d, e), ("fsdp", None), scale=d ** -0.5)
    sub.add("w_gate", (e, d, f), ("expert", "fsdp", "tensor"))
    sub.add("w_up", (e, d, f), ("expert", "fsdp", "tensor"))
    sub.add("w_down", (e, f, d), ("expert", "tensor", "fsdp"))
    return sub


def _capacity(n_tokens: int, cfg) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def run_moe(p, cfg, x):
    """x (B, S, d) -> (B, S, d), aux dict with load-balance loss.

    When cfg.moe_token_chunk is set and the batch is larger, tokens stream
    through the experts in chunks (a tagged scan) so the (E, C, d_ff)
    intermediates stay bounded — the prefill memory cap."""
    b, s, d = x.shape
    n = b * s
    chunk = cfg.moe_token_chunk
    if chunk and n > chunk and n % chunk == 0:
        xc = x.reshape(n // chunk, 1, chunk, d)

        def step(_, xi):
            out, aux = _moe_tokens(p, cfg, xi)
            return None, (out, aux)

        from ..launch.scan_registry import tagged_scan
        _, (outs, auxs) = tagged_scan("tagscan_moe_tokens", step, None, xc,
                                      length=n // chunk)
        out = outs.reshape(b, s, d)
        aux = jax.tree.map(lambda a: jnp.mean(a), auxs)
        return out, aux
    return _moe_tokens_reshaped(p, cfg, x)


def _moe_tokens_reshaped(p, cfg, x):
    out, aux = _moe_tokens(p, cfg, x)
    return out, aux


def _moe_tokens(p, cfg, x):
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(n, d)

    logits = (xf @ p["router"]).astype(jnp.float32)          # (N, E)
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, k)                   # (N, k)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)        # renormalize

    # --- load-balance auxiliary loss (Switch/GShard form) ---
    me = jnp.mean(probs, axis=0)                             # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1), axis=0)
    aux_loss = e * jnp.sum(me * ce) * cfg.router_aux_weight

    # --- sort-based capacity dispatch ---
    cap = _capacity(n, cfg)
    flat_e = top_e.reshape(-1)                               # (N*k,)
    flat_p = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(n), k)
    order = jnp.argsort(flat_e, stable=True)                 # group by expert
    se, sp, stok = flat_e[order], flat_p[order], flat_tok[order]
    # position of each assignment within its expert bucket
    pos_in_e = jnp.arange(n * k) - jnp.searchsorted(se, se, side="left")
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)     # overflow slot
    # scatter token ids / weights into (E*C [+1 overflow],) buckets
    tok_buf = jnp.full((e * cap + 1,), 0, jnp.int32).at[slot].set(
        stok.astype(jnp.int32))
    w_buf = jnp.zeros((e * cap + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, sp, 0.0))
    valid_buf = jnp.zeros((e * cap + 1,), jnp.float32).at[slot].set(
        keep.astype(jnp.float32))
    tok_ec = tok_buf[:-1].reshape(e, cap)
    w_ec = w_buf[:-1].reshape(e, cap)
    valid_ec = valid_buf[:-1].reshape(e, cap)

    xe = xf[tok_ec] * valid_ec[..., None].astype(x.dtype)    # (E, C, d)
    # per-expert SwiGLU — batched over the (sharded) expert axis
    gate = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    down = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up, p["w_down"])
    down = down * (w_ec * valid_ec)[..., None].astype(x.dtype)

    # combine: scatter-add back to tokens
    out = jnp.zeros((n, d), down.dtype).at[tok_ec.reshape(-1)].add(
        down.reshape(e * cap, d))
    dropped = 1.0 - jnp.sum(valid_ec) / jnp.maximum(n * k, 1)
    return out.reshape(b, s, d).astype(x.dtype), {
        "aux_loss": aux_loss, "drop_frac": dropped}
