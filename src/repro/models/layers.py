"""Shared neural layers (pure functional, explicit param pytrees).

Sharding is expressed via *logical axis names* attached at init time
(see repro.distributed.sharding): every parameter leaf is created through
``param(key, shape, logical_axes)`` which records the mapping in a
parallel pytree of PartitionSpecs-by-name.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

# Logical axis vocabulary (mapped to mesh axes in distributed/sharding.py):
#   "batch"   — per-example axis               -> ("pod", "data")
#   "fsdp"    — parameter shard axis (ZeRO)    -> "data"
#   "tensor"  — tensor-parallel axis           -> "model"
#   "vocab"   — vocabulary shards              -> "model"
#   "expert"  — MoE expert shards              -> "model"
#   None      — replicated


@dataclasses.dataclass
class ParamStore:
    """Accumulates parameter arrays + their logical axis annotations."""
    params: dict
    axes: dict
    key: jax.Array
    dtype: Any

    def __init__(self, key, dtype=jnp.float32):
        self.params, self.axes = {}, {}
        self.key = key
        self.dtype = dtype

    def _next(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def add(self, name: str, shape, logical, scale=None, init="normal"):
        fan_in = shape[0] if len(shape) > 1 else max(shape[0], 1)
        scale = scale if scale is not None else fan_in ** -0.5
        if init == "normal":
            w = scale * jax.random.normal(self._next(), shape, jnp.float32)
        elif init == "zeros":
            w = jnp.zeros(shape, jnp.float32)
        elif init == "ones":
            w = jnp.ones(shape, jnp.float32)
        else:
            raise ValueError(init)
        self.params[name] = w.astype(self.dtype)
        self.axes[name] = logical
        return self.params[name]

    def subtree(self, name: str):
        sub = ParamStore.__new__(ParamStore)
        sub.params, sub.axes = {}, {}
        sub.key = self._next()
        sub.dtype = self.dtype
        self.params[name] = sub.params
        self.axes[name] = sub.axes
        return sub


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps=1e-6):
    """Variance in f32, data path in the input dtype — keeps the residual
    stream and its COTANGENTS bf16 (an f32 normalize chain drags f32
    activation gradients through every TP all-reduce; §Perf iteration)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    scale = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * scale * gamma


def swiglu(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float):
    return theta ** (-jnp.arange(0, d_head // 2, dtype=jnp.float32)
                     / (d_head // 2))


def apply_rope(x, positions, theta=10_000.0):
    """x (..., S, H, Dh), positions (..., S) -> rotated x."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                     # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,Dh/2)
    cos = jnp.cos(angles)[..., None, :]                     # (...,S,1,Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_thw, sections, theta=10_000.0):
    """Qwen2-VL M-RoPE: positions_thw (3, ..., S) give separate temporal /
    height / width indices; frequency bands are split by ``sections``
    (summing to d_head//2) and each band rotates by its own component."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                     # (Dh/2,)
    # static band assignment (numpy at trace time — no device control flow)
    import numpy as np
    sec = np.cumsum((0,) + tuple(sections))
    band = jnp.asarray(
        np.clip(np.searchsorted(sec[1:], np.arange(dh // 2), side="right"),
                0, 2))                                      # (Dh/2,) {0,1,2}
    pos = jnp.take_along_axis(
        positions_thw[..., None].astype(jnp.float32),       # (3,...,S,1)
        jnp.broadcast_to(band, positions_thw.shape[1:] + (dh // 2,))[None]
        .astype(jnp.int32),
        axis=0)[0]                                          # (...,S,Dh/2)
    angles = pos * freqs
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (training: full or windowed; GQA by construction)
# ---------------------------------------------------------------------------

def attention_scores(q, k, v, *, causal: bool, window: int | None = None,
                     use_flash: bool = False):
    """q (B,S,H,Dh), k/v (B,S,Hk,Dh) -> (B,S,H,Dh).

    ``window``: local (sliding) attention half-width in tokens.
    ``use_flash``: route through the kernel registry ("flash_attention":
    compiled Pallas on TPU, jnp reference elsewhere).
    """
    b, s, h, dh = q.shape
    hk = k.shape[2]
    if use_flash and window is None:
        from ..kernels.registry import dispatch
        out = dispatch(
            "flash_attention", jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
            jnp.moveaxis(v, 2, 1), causal=causal)
        return jnp.moveaxis(out, 1, 2)
    group = h // hk
    qg = q.reshape(b, s, hk, group, dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) / (dh ** 0.5)
    idx = jnp.arange(s)
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= idx[:, None] >= idx[None, :]
    if window is not None:
        mask &= idx[:, None] - idx[None, :] < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h, dh).astype(q.dtype)


def attention_chunked(q, k, v, *, causal: bool, window: int | None = None,
                      chunk_q: int = 1024, chunk_k: int = 1024):
    """Flash-style chunked attention in pure JAX: scans query chunks
    (outer) and KV chunks (inner) with online-softmax running stats, so the
    (S, S) score matrix never materializes — required for the 32k/500k
    shapes.  Same semantics as attention_scores (tests assert)."""
    b, s, h, dh = q.shape
    hk = k.shape[2]
    group = h // hk
    cq = min(chunk_q, s)
    ck = min(chunk_k, s)
    assert s % cq == 0 and s % ck == 0
    nq, nk = s // cq, s // ck
    scale = dh ** -0.5
    qs = jnp.swapaxes(q.reshape(b, nq, cq, hk, group, dh), 0, 1)
    ks = jnp.swapaxes(k.reshape(b, nk, ck, hk, dh), 0, 1)
    vs = jnp.swapaxes(v.reshape(b, nk, ck, hk, dh), 0, 1)
    rows = jnp.arange(cq)
    cols = jnp.arange(ck)

    def q_step(_, qin):
        qi, qc = qin                                   # (B,cq,Hk,G,D)
        qcs = (qc * jnp.asarray(scale, qc.dtype))

        def kv_step(carry, kin):
            m, l, acc = carry
            ki, kc, vc = kin
            # bf16 operands, f32 accumulation (MXU-native; keeps the
            # gathered/saved tensors half-width)
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", qcs, kc,
                                preferred_element_type=jnp.float32)
            grow = qi * cq + rows                      # global q positions
            gcol = ki * ck + cols
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= grow[:, None] >= gcol[None, :]
            if window is not None:
                mask &= grow[:, None] - gcol[None, :] < window
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_cur = jnp.max(logits, -1)
            m_new = jnp.maximum(m, m_cur)
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, -1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hk, group, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hk, group, cq), jnp.float32)
        a0 = jnp.zeros((b, hk, group, cq, dh), jnp.float32)
        from ..launch.scan_registry import tagged_scan
        # checkpoint: recompute logits/mask in the backward (the
        # flash-attention backward) instead of saving (cq, ck) residuals
        # per chunk pair
        (m, l, acc), _ = tagged_scan(
            "tagscan_attn_kv", jax.checkpoint(kv_step), (m0, l0, a0),
            (jnp.arange(nk), ks, vs), length=nk)
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B,Hk,G,cq,D)
        out = jnp.moveaxis(out, 3, 1).reshape(b, cq, h, dh)
        return None, out.astype(q.dtype)

    from ..launch.scan_registry import tagged_scan
    _, outs = tagged_scan("tagscan_attn_q", jax.checkpoint(q_step), None,
                          (jnp.arange(nq), qs), length=nq)
    return jnp.swapaxes(outs, 0, 1).reshape(b, s, h, dh)


def init_attention(store: ParamStore, cfg, name="attn"):
    sub = store.subtree(name)
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    sub.add("wq", (d, h * dh), ("fsdp", "tensor"))
    sub.add("wk", (d, hk * dh), ("fsdp", "tensor"))
    sub.add("wv", (d, hk * dh), ("fsdp", "tensor"))
    sub.add("wo", (h * dh, d), ("tensor", "fsdp"))
    if cfg.qk_norm:
        sub.add("q_norm", (dh,), (None,), init="ones")
        sub.add("k_norm", (dh,), (None,), init="ones")
    return sub


def run_attention(p, cfg, x, positions, *, window=None, use_flash=False,
                  mrope_positions=None, chunked_threshold: int = 2048):
    """Full-sequence attention (training / prefill).  Sequences longer than
    ``chunked_threshold`` route through the online-softmax chunked path."""
    b, s, d = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, hk, dh)
    v = (x @ p["wv"]).reshape(b, s, hk, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.mrope_sections,
                        cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.mrope_sections,
                        cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if s > chunked_threshold:
        out = attention_chunked(q, k, v, causal=cfg.causal, window=window)
    else:
        out = attention_scores(q, k, v, causal=cfg.causal, window=window,
                               use_flash=use_flash)
    return out.reshape(b, s, h * dh) @ p["wo"]


def run_attention_decode(p, cfg, x, cache_k, cache_v, pos, *,
                         window=None, mrope_positions=None):
    """One decode step. x (B,1,d); cache_k/v (B,S,Hk,Dh) ring buffers;
    ``pos`` is either (B,) per-sequence positions (continuous batching) or
    a scalar (synchronized batch decode — enables an aliasing-friendly
    dynamic-update-slice cache write instead of a scatter).

    The sharded split-K path lives in distributed/decode.py; this is the
    reference single-shard semantics (also used under shard_map per shard).
    """
    b, _, d = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    s = cache_k.shape[1]
    uniform = jnp.ndim(pos) == 0
    pos_vec = jnp.full((b,), pos) if uniform else pos
    q = (x @ p["wq"]).reshape(b, 1, h, dh)
    k = (x @ p["wk"]).reshape(b, 1, hk, dh)
    v = (x @ p["wv"]).reshape(b, 1, hk, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.mrope_sections,
                        cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.mrope_sections,
                        cfg.rope_theta)
    else:
        q = apply_rope(q, pos_vec[:, None], cfg.rope_theta)
        k = apply_rope(k, pos_vec[:, None], cfg.rope_theta)
    if uniform:
        slot = pos % s
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))
    else:
        cache_k = jax.vmap(lambda c, i, u: c.at[i].set(u[0]))(
            cache_k, pos_vec % s, k)
        cache_v = jax.vmap(lambda c, i, u: c.at[i].set(u[0]))(
            cache_v, pos_vec % s, v)
    # Ring-buffer-aware validity: slot j holds absolute position
    # pos - ((pos - j) mod S) (negative -> never written).  For the
    # full-cache case (S > pos) this reduces to j <= pos.
    kpos = jnp.arange(s)[None, :]                           # (1,S)
    stored = pos_vec[:, None] - ((pos_vec[:, None] - kpos) % s)
    valid = stored >= 0
    if window is not None:
        valid &= stored > pos_vec[:, None] - window
    group = h // hk
    qg = q.reshape(b, hk, group, dh)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                        cache_k.astype(jnp.float32)) / (dh ** 0.5)
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, -1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w, cache_v.astype(jnp.float32))
    out = out.reshape(b, 1, h * dh).astype(x.dtype)
    return out @ p["wo"], cache_k, cache_v


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------

def init_ffn(store: ParamStore, cfg, name="ffn"):
    sub = store.subtree(name)
    d, f = cfg.d_model, cfg.d_ff
    sub.add("w_gate", (d, f), ("fsdp", "tensor"))
    sub.add("w_up", (d, f), ("fsdp", "tensor"))
    sub.add("w_down", (f, d), ("tensor", "fsdp"))
    return sub


def run_ffn(p, x):
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])


# ---------------------------------------------------------------------------
# Vocab-parallel cross entropy
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, mask):
    """logits (B,S,V) (V possibly sharded), labels (B,S) -> mean NLL."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    nll = (lse - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
