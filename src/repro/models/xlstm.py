"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential scan), alternated per config.

mLSTM parallel (training) form — exponential gating turned into a
causal-decay attention matrix, computed in log space for stability:

    F_t = Σ_{j<=t} log σ(f_j);  D_{t,j} = F_t − F_j + log i_j  (j ≤ t)
    m_t = max_j D_{t,j};  W = exp(D − m);  n_t = max(|Σ W q·k|, e^{−m})
    h_t = (W (q·kᵀ) v)_t / n_t     (Appendix-style stabilized form)

mLSTM recurrent (decode) form keeps (C (B,H,Dk,Dv), n (B,H,Dk), m (B,H))
— O(1) per token, which is what makes long_500k runnable for this family.

sLSTM: per-head scalar memory with exponential gating and a normalizer —
a genuine sequential ``lax.scan`` (noted in DESIGN.md as this family's
training bottleneck; xLSTM block pattern 1:1 here per the assignment).

d_ff = 0 per the assignment: blocks carry their own up/down projections
(mLSTM proj_factor 2.0, sLSTM conv+gates) instead of a separate FFN.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import ParamStore


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(store: ParamStore, cfg, name="mlstm"):
    sub = store.subtree(name)
    d, h = cfg.d_model, cfg.n_heads
    dk = d // h
    up = 2 * d                                # proj_factor 2.0
    sub.add("w_up", (d, up), ("fsdp", "tensor"))
    sub.add("w_skip_gate", (d, up), ("fsdp", "tensor"))
    sub.add("wq", (up, d), ("tensor", "fsdp"))
    sub.add("wk", (up, d), ("tensor", "fsdp"))
    sub.add("wv", (up, d), ("tensor", "fsdp"))
    sub.add("w_if", (up, 2 * h), ("tensor", None), scale=0.02)
    sub.add("w_o", (d, d), ("tensor", "fsdp"))
    return sub


def _mlstm_qkv(p, cfg, x):
    b = x.shape[0]
    h = cfg.n_heads
    up = x @ p["w_up"]
    q = (up @ p["wq"]).reshape(*up.shape[:-1], h, -1)
    k = (up @ p["wk"]).reshape(*up.shape[:-1], h, -1)
    v = (up @ p["wv"]).reshape(*up.shape[:-1], h, -1)
    gates = (up @ p["w_if"]).astype(jnp.float32)
    log_i, log_f = jnp.split(gates, 2, axis=-1)       # (..., H)
    log_f = jax.nn.log_sigmoid(log_f)
    return q, k, v, log_i, log_f, up


def run_mlstm(p, cfg, x, *, chunk: int = 256):
    """Chunkwise-parallel training form: O(S·chunk) memory instead of
    O(S²).  Within a chunk the stabilized quadratic decay matrix is used;
    across chunks the (C, n, m) recurrent state is carried by a scan —
    identical math to the recurrent form (tests assert this).
    """
    b, s, d = x.shape
    h = cfg.n_heads
    q, k, v, log_i, log_f, up = _mlstm_qkv(p, cfg, x)
    dk = q.shape[-1]
    ck = min(chunk, s)
    assert s % ck == 0, (s, ck)
    nc = s // ck

    def rs(t):  # (B,S,...) -> (nc, B, ck, ...)
        return jnp.swapaxes(t.reshape(b, nc, ck, *t.shape[2:]), 0, 1)

    qs, ks, vs = rs(q.astype(jnp.float32)), rs(k.astype(jnp.float32)), \
        rs(v.astype(jnp.float32))
    lis, lfs = rs(log_i), rs(log_f)
    idx = jnp.arange(ck)
    causal = idx[:, None] >= idx[None, :]

    def chunk_step(state, inp):
        qc, kc, vc, li, lf = inp                     # (B,ck,H,D)/(B,ck,H)
        c_prev, n_prev, m_prev = state
        bcum = jnp.cumsum(lf, axis=1)                # (B,ck,H) inclusive
        # intra-chunk decay D_{t,j} = b_t - b_j + log i_j (j <= t)
        dmat = (bcum[:, :, None, :] - bcum[:, None, :, :]
                + li[:, None, :, :])                 # (B,ck,ck,H)
        dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
        m_intra = jnp.max(dmat, axis=2)              # (B,ck,H)
        m_inter = m_prev[:, None, :] + bcum          # (B,ck,H)
        m_t = jnp.maximum(m_intra, m_inter)
        w = jnp.exp(dmat - m_t[:, :, None, :])       # (B,ck,ck,H)
        scores = jnp.einsum("bthd,bjhd->btjh", qc, kc) / (dk ** 0.5)
        wsc = w * scores
        num_intra = jnp.einsum("btjh,bjhd->bthd", wsc, vc)
        den_intra = jnp.sum(wsc, axis=2)             # (B,ck,H)
        inter_scale = jnp.exp(m_inter - m_t)         # (B,ck,H)
        qsc = qc / (dk ** 0.5)
        num_inter = jnp.einsum("bthk,bhkv->bthv", qsc, c_prev) \
            * inter_scale[..., None]
        den_inter = jnp.einsum("bthk,bhk->bth", qsc, n_prev) * inter_scale
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t))
        hid = (num_intra + num_inter) / den[..., None]
        # ---- state update to end of chunk ----
        b_l = bcum[:, -1, :]                         # (B,H) total decay
        m_state = jnp.maximum(
            m_prev + b_l,
            jnp.max(b_l[:, None, :] - bcum + li, axis=1))
        carry_decay = jnp.exp(m_prev + b_l - m_state)
        kv_decay = jnp.exp(b_l[:, None, :] - bcum + li - m_state[:, None, :])
        c_new = c_prev * carry_decay[..., None, None] + jnp.einsum(
            "bjh,bjhk,bjhv->bhkv", kv_decay, kc, vc)
        n_new = n_prev * carry_decay[..., None] + jnp.einsum(
            "bjh,bjhk->bhk", kv_decay, kc)
        return (c_new, n_new, m_state), hid

    c0 = jnp.zeros((b, h, dk, dk), jnp.float32)
    n0 = jnp.zeros((b, h, dk), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    from ..launch.scan_registry import tagged_scan
    _, hids = tagged_scan("tagscan_mlstm_chunks", chunk_step, (c0, n0, m0),
                          (qs, ks, vs, lis, lfs), length=nc)
    hid = jnp.swapaxes(hids, 0, 1).reshape(b, s, d).astype(x.dtype)
    out = (hid * jax.nn.silu(x @ p["w_skip_gate"])[..., :d]) @ p["w_o"]
    return out


def init_mlstm_state(cfg, batch, dtype=jnp.float32):
    h = cfg.n_heads
    dk = cfg.d_model // h
    return {
        "C": jnp.zeros((batch, h, dk, dk), dtype),
        "n": jnp.zeros((batch, h, dk), dtype),
        "m": jnp.full((batch, h), -1e30, dtype),
    }


def run_mlstm_decode(p, cfg, x, state):
    """O(1) recurrent step. x (B,1,d)."""
    b, _, d = x.shape
    h = cfg.n_heads
    q, k, v, log_i, log_f, up = _mlstm_qkv(p, cfg, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]               # (B,H,Dk)
    log_i, log_f = log_i[:, 0], log_f[:, 0]           # (B,H)
    dk = q.shape[-1]
    m_prev, c_prev, n_prev = state["m"], state["C"], state["n"]
    m_new = jnp.maximum(log_f + m_prev, log_i)
    decay = jnp.exp(log_f + m_prev - m_new)[..., None, None]
    inject = jnp.exp(log_i - m_new)[..., None, None]
    c_new = c_prev * decay + inject * (k[..., :, None] * v[..., None, :])
    n_new = n_prev * decay[..., 0] + inject[..., 0] * k
    qs = q.astype(jnp.float32) / (dk ** 0.5)
    num = jnp.einsum("bhk,bhkv->bhv", qs, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qs, n_new)),
                      jnp.exp(-m_new))
    hid = (num / den[..., None]).reshape(b, 1, d).astype(x.dtype)
    out = (hid * jax.nn.silu(x @ p["w_skip_gate"])[..., :d]) @ p["w_o"]
    return out, {"C": c_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(store: ParamStore, cfg, name="slstm"):
    sub = store.subtree(name)
    d = cfg.d_model
    sub.add("w_gates", (d, 4 * d), ("fsdp", "tensor"))   # z, i, f, o
    sub.add("r_gates", (d, 4 * d), (None, "tensor"), scale=0.02)
    sub.add("w_out", (d, d), ("tensor", "fsdp"))
    return sub


def init_slstm_state(cfg, batch, dtype=jnp.float32):
    d = cfg.d_model
    z = jnp.zeros((batch, d), dtype)
    return {"c": z, "n": z + 1e-6, "h": z, "m": z - 1e30}


def _slstm_step(p, cfg, carry, xt):
    """xt (B,4d) pre-activation (input part); carry holds h for recurrence."""
    c, n, h, m = carry["c"], carry["n"], carry["h"], carry["m"]
    pre = xt + h.astype(xt.dtype) @ p["r_gates"]
    z, i, f, o = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    log_f = jax.nn.log_sigmoid(f)
    m_new = jnp.maximum(log_f + m, i)
    ig = jnp.exp(i - m_new)
    fg = jnp.exp(log_f + m - m_new)
    c_new = fg * c + ig * z
    n_new = jnp.maximum(fg * n + ig, jnp.exp(-m_new))
    h_new = o * c_new / n_new
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def run_slstm(p, cfg, x, state=None):
    """Sequential scan over time. x (B,S,d) -> (B,S,d)."""
    b, s, d = x.shape
    pre = x @ p["w_gates"]                             # (B,S,4d)
    carry = state if state is not None else init_slstm_state(cfg, b)

    def step(carry, xt):
        new = _slstm_step(p, cfg, carry, xt)
        return new, new["h"]

    from ..launch.scan_registry import tagged_scan
    carry, hs = tagged_scan("tagscan_slstm_time", step, carry,
                            jnp.swapaxes(pre, 0, 1), length=s)
    hs = jnp.swapaxes(hs, 0, 1).astype(x.dtype)        # (B,S,d)
    return hs @ p["w_out"], carry


def run_slstm_decode(p, cfg, x, state):
    pre = (x @ p["w_gates"])[:, 0]
    new = _slstm_step(p, cfg, state, pre)
    return (new["h"][:, None].astype(x.dtype)) @ p["w_out"], new
