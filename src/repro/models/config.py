"""Model configuration for the assigned architectures.

One frozen dataclass covers all six families (dense / moe / audio / hybrid
/ vlm / ssm); family-specific fields are ignored elsewhere.  Configs for
the ten assigned architectures live in repro.configs.<id>.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "audio", "hybrid", "vlm", "ssm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                      # 0 -> d_model // n_heads

    # attention details
    causal: bool = True                  # False for encoder-only (audio)
    qk_norm: bool = False                # qwen3
    rope_theta: float = 10_000.0
    mrope: bool = False                  # qwen2-vl 3-component M-RoPE
    mrope_sections: tuple[int, ...] = (16, 24, 24)   # t/h/w splits of d_head/2

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # hybrid (recurrentgemma): per-layer pattern cycling through this tuple
    block_pattern: tuple[str, ...] = ()  # e.g. ("rglru", "rglru", "local")
    local_window: int = 2048
    rglru_c: float = 8.0                 # Griffin's gate sharpness constant
    conv1d_width: int = 4

    # ssm (xlstm): alternating block kinds
    slstm_every: int = 2                 # every k-th block is sLSTM

    # MoE execution: process tokens through experts in chunks of this many
    # tokens (0 = all at once) — bounds the (E, C, d_ff) live intermediates
    # during prefill, where there is no remat to cap them.
    moe_token_chunk: int = 0
    # "gather" (baseline: replicated tokens + combine all-reduce) or "a2a"
    # (sequence-sharded dispatch/return all-to-alls — see
    # distributed/ep_a2a.py).  "a2a" requires an active mesh context.
    moe_impl: str = "gather"
    # Megatron-style sequence parallelism: the residual stream stays
    # sequence-sharded over the tensor axis; the gather/scatter flip
    # happens only around attention (norms/FFN/MoE run seq-sharded).
    seq_parallel: bool = False
    # Model the chunked-attention scans as the Pallas flash kernel
    # (kernels/flash_attention) in the dry-run byte accounting: chunk
    # intermediates are VMEM-resident; only q/k/v tile loads and output
    # tile writes hit HBM.
    flash_model: bool = False

    # embeddings / io
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # training
    remat: bool = True                   # activation checkpoint per block

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0, \
            (self.n_heads, self.n_kv_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def params_total(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
        return _count_params(self)

    @property
    def params_active(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        return _count_params(self, active_only=True)


def _count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    d, dh = cfg.d_model, cfg.d_head
    h, hk = cfg.n_heads, cfg.n_kv_heads
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0
    kinds = _layer_kinds(cfg)
    for kind in kinds:
        if kind in ("attn", "local"):
            per_layer += d * (h * dh) + 2 * d * (hk * dh) + (h * dh) * d
        elif kind == "rglru":
            # in/gate/out projections + conv + recurrence params
            per_layer += 3 * d * d + cfg.conv1d_width * d + 2 * d
        elif kind == "mlstm":
            per_layer += 4 * d * d + 3 * d * d // 1  # qkv+o + gates
        elif kind == "slstm":
            per_layer += 8 * d * d // 4  # 4 gates, head-blocked
        # FFN part
        if kind in ("attn", "local"):
            if cfg.is_moe:
                experts = cfg.top_k if active_only else cfg.n_experts
                per_layer += experts * 3 * d * cfg.d_ff + d * cfg.n_experts
            elif cfg.d_ff > 0:
                per_layer += 3 * d * cfg.d_ff
        elif kind == "rglru" and cfg.d_ff > 0:
            per_layer += 3 * d * cfg.d_ff
    return emb + per_layer + cfg.n_layers * 2 * d  # norms


def _layer_kinds(cfg: ModelConfig) -> list[str]:
    """Per-layer block kind according to family."""
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rglru", "rglru", "local")
        return [pat[i % len(pat)] for i in range(cfg.n_layers)]
    if cfg.family == "ssm":
        return ["slstm" if (i % cfg.slstm_every == cfg.slstm_every - 1)
                else "mlstm" for i in range(cfg.n_layers)]
    return ["attn"] * cfg.n_layers


def layer_kinds(cfg: ModelConfig) -> list[str]:
    return _layer_kinds(cfg)
