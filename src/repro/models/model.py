"""Model assembly: init / forward / train loss / decode for all families.

Layers are grouped into *periods* (the repeating block pattern of the
family — length 1 for dense/moe, (rglru, rglru, local) for hybrid,
(mlstm, slstm) for ssm) and period parameters are stacked so the layer
stack compiles as ONE ``lax.scan`` body (+ an unrolled remainder).  This
keeps HLO size and compile time flat in depth — a requirement when
dry-running 40 (arch × shape) cells.

Activation-checkpointing (remat) wraps the scan body when cfg.remat.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig, layer_kinds
from . import layers as L
from . import moe as MOE
from . import rglru as RG
from . import xlstm as XL
from ..distributed.sharding import constrain


# ---------------------------------------------------------------------------
# Period structure
# ---------------------------------------------------------------------------

def period_pattern(cfg: ModelConfig) -> list[str]:
    kinds = layer_kinds(cfg)
    if cfg.family == "hybrid":
        pat = list(cfg.block_pattern or ("rglru", "rglru", "local"))
    elif cfg.family == "ssm":
        pat = ["mlstm", "slstm"] if cfg.slstm_every == 2 else \
            ["mlstm"] * (cfg.slstm_every - 1) + ["slstm"]
    else:
        pat = ["attn"]
    assert kinds[:len(pat)] == pat
    return pat


def _moe_dispatch(cfg, p, h2):
    """Route to the baseline gather MoE or the sequence-sharded a2a MoE
    (beyond-paper §Perf) depending on cfg.moe_impl + mesh context."""
    if cfg.moe_impl == "a2a":
        from ..distributed.sharding import get_active
        active = get_active()
        if active is not None:
            mesh, rules = active
            from ..distributed.ep_a2a import make_run_moe_a2a
            batch = rules.get("batch", ("pod", "data"))
            batch = batch if isinstance(batch, tuple) else (batch,)
            h2s = constrain(h2, ("batch", "tensor", None))
            moe_fn = make_run_moe_a2a(
                mesh, cfg, batch_axes=batch,
                expert_axis=rules.get("expert", "model"),
                fsdp_axis=rules.get("fsdp", "data"))
            out, aux = moe_fn(p, h2s)
            return constrain(out, ("batch", None, None)), aux
    return MOE.run_moe(p, cfg, h2)


def _init_block(cfg: ModelConfig, kind: str, key) -> tuple[dict, dict]:
    store = L.ParamStore(key, jnp.dtype(cfg.dtype))
    store.add("norm1", (cfg.d_model,), (None,), init="ones")
    if kind in ("attn", "local"):
        L.init_attention(store, cfg, "attn")
        store.add("norm2", (cfg.d_model,), (None,), init="ones")
        if cfg.is_moe:
            MOE.init_moe(store, cfg, "moe")
        elif cfg.d_ff > 0:
            L.init_ffn(store, cfg, "ffn")
    elif kind == "rglru":
        RG.init_rglru(store, cfg, "rglru")
        if cfg.d_ff > 0:
            store.add("norm2", (cfg.d_model,), (None,), init="ones")
            L.init_ffn(store, cfg, "ffn")
    elif kind == "mlstm":
        XL.init_mlstm(store, cfg, "mlstm")
    elif kind == "slstm":
        XL.init_slstm(store, cfg, "slstm")
    else:
        raise ValueError(kind)
    return store.params, store.axes


def _layout(cfg: ModelConfig) -> tuple:
    """Canonical residual-stream sharding: sequence-parallel keeps it
    seq-sharded over the tensor axis (Megatron-SP); default replicates."""
    return (("batch", "tensor", None) if cfg.seq_parallel
            else ("batch", None, None))


def _run_block(cfg: ModelConfig, kind: str, p, x, positions, *,
               mrope_positions=None, aux_acc=None):
    """Pre-norm residual block; returns (x, aux_acc)."""
    layout = _layout(cfg)
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind in ("attn", "local"):
        window = cfg.local_window if kind == "local" else None
        if cfg.seq_parallel:
            # gather the sequence only for attention; scatter right after
            h = constrain(h, ("batch", None, None))
        attn_out = L.run_attention(p["attn"], cfg, h, positions,
                                   window=window,
                                   mrope_positions=mrope_positions)
        attn_out = constrain(attn_out, layout)
        x = x + attn_out
        x = constrain(x, layout)
        h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.is_moe:
            out, aux = _moe_dispatch(cfg, p["moe"], h2)
            x = x + constrain(out, layout)
            if aux_acc is not None:
                aux_acc = {k: aux_acc[k] + aux[k] for k in aux_acc}
        elif cfg.d_ff > 0:
            x = x + L.run_ffn(p["ffn"], h2)
    elif kind == "rglru":
        out, _ = RG.run_rglru(p["rglru"], cfg, h)
        x = x + out
        if cfg.d_ff > 0:
            x = x + L.run_ffn(p["ffn"],
                              L.rms_norm(x, p["norm2"], cfg.norm_eps))
    elif kind == "mlstm":
        x = x + XL.run_mlstm(p["mlstm"], cfg, h)
    elif kind == "slstm":
        out, _ = XL.run_slstm(p["slstm"], cfg, h)
        x = x + out
    x = constrain(x, _layout(cfg))
    return x, aux_acc


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def init_model(cfg: ModelConfig, key) -> tuple[dict, dict]:
    """Returns (params, logical_axes) pytrees with period-stacked layers."""
    kd, ke, ko = jax.random.split(key, 3)
    pat = period_pattern(cfg)
    n_full = cfg.n_layers // len(pat)
    n_tail = cfg.n_layers - n_full * len(pat)

    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    estore = L.ParamStore(ke, jnp.dtype(cfg.dtype))
    estore.add("embed", (cfg.vocab, cfg.d_model), ("vocab", "fsdp"),
               scale=1.0)
    estore.add("out_norm", (cfg.d_model,), (None,), init="ones")
    if not cfg.tie_embeddings:
        estore.add("lm_head", (cfg.d_model, cfg.vocab), ("fsdp", "vocab"))
    params.update(estore.params)
    axes.update(estore.axes)

    # stacked periods: for each position in the pattern, stack n_full copies
    stacked, stacked_axes = [], []
    for pos, kind in enumerate(pat):
        plist = []
        ax = None
        for i in range(n_full):
            p, ax = _init_block(cfg, kind, jax.random.fold_in(kd, pos * 997 + i))
            plist.append(p)
        stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *plist)
                       if n_full > 0 else {})
        stacked_axes.append(jax.tree.map(lambda a: ("layers",) + tuple(a),
                                         ax, is_leaf=lambda t: isinstance(t, tuple))
                            if n_full > 0 else {})
    params["periods"] = {str(i): s for i, s in enumerate(stacked)}
    axes["periods"] = {str(i): s for i, s in enumerate(stacked_axes)}

    tail, tail_axes = [], []
    for i in range(n_tail):
        p, ax = _init_block(cfg, pat[i], jax.random.fold_in(ko, i))
        tail.append(p)
        tail_axes.append(ax)
    params["tail"] = tail
    axes["tail"] = tail_axes
    return params, axes


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens=None, *, embeddings=None,
            mrope_positions=None, collect_aux: bool = True):
    """Returns (logits (B,S,V), aux dict)."""
    if embeddings is not None:
        x = embeddings.astype(jnp.dtype(cfg.dtype))
        if tokens is not None:
            tok_emb = params["embed"][tokens]
            x = jnp.concatenate([x, tok_emb], axis=1)
    else:
        x = params["embed"][tokens]
    x = constrain(x, _layout(cfg))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    pat = period_pattern(cfg)
    aux = {"aux_loss": jnp.zeros((), jnp.float32),
           "drop_frac": jnp.zeros((), jnp.float32)} if cfg.is_moe else None

    def period_body(carry, pparams):
        x, aux = carry
        for pos, kind in enumerate(pat):
            x, aux = _run_block(cfg, kind, pparams[str(pos)], x, positions,
                                mrope_positions=mrope_positions,
                                aux_acc=aux)
        return (x, aux), None

    body = jax.checkpoint(period_body) if cfg.remat else period_body
    n_full = cfg.n_layers // len(pat)
    if n_full > 0:
        from ..launch.scan_registry import tagged_scan
        (x, aux), _ = tagged_scan("tagscan_layers_fwd", body, (x, aux),
                                  params["periods"], length=n_full)
    for i, p in enumerate(params["tail"]):
        x, aux = _run_block(cfg, pat[i], p, x, positions,
                            mrope_positions=mrope_positions, aux_acc=aux)

    x = L.rms_norm(x, params["out_norm"], cfg.norm_eps)
    x = constrain(x, ("batch", None, None))     # gather seq for the head
    w_out = (params["embed"].T if cfg.tie_embeddings
             else params["lm_head"])
    logits = x @ w_out
    logits = constrain(logits, ("batch", None, "vocab"))
    return logits, (aux or {})


def train_loss(params, cfg: ModelConfig, batch):
    """Next-token (or frame-classification, for encoder-only) loss."""
    logits, aux = forward(
        params, cfg, batch.get("tokens"),
        embeddings=batch.get("embeddings"),
        mrope_positions=batch.get("mrope_positions"))
    labels = batch["labels"]
    # align: for mixed vision+text inputs the label tensor covers the full
    # concatenated sequence (vision positions masked out by `mask`).
    loss = L.cross_entropy(logits, labels, batch["mask"])
    total = loss
    if aux:
        total = total + aux.get("aux_loss", 0.0)
    metrics = {"nll": loss}
    metrics.update(aux)
    return total, metrics


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int):
    """Per-layer cache pytree, period-stacked to mirror the param layout."""
    pat = period_pattern(cfg)
    n_full = cfg.n_layers // len(pat)
    n_tail = cfg.n_layers - n_full * len(pat)
    dt = jnp.dtype(cfg.dtype)

    def one(kind):
        if kind == "attn":
            shape = (batch, max_seq, cfg.n_kv_heads, cfg.d_head)
            return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
        if kind == "local":
            w = min(cfg.local_window, max_seq)
            shape = (batch, w, cfg.n_kv_heads, cfg.d_head)
            return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
        if kind == "rglru":
            return {"h": jnp.zeros((batch, cfg.d_model), jnp.float32),
                    "conv": jnp.zeros((batch, cfg.conv1d_width - 1,
                                       cfg.d_model), dt)}
        if kind == "mlstm":
            return XL.init_mlstm_state(cfg, batch)
        if kind == "slstm":
            return XL.init_slstm_state(cfg, batch)
        raise ValueError(kind)

    periods = {}
    for pos, kind in enumerate(pat):
        cache = one(kind)
        periods[str(pos)] = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n_full,) + l.shape)
            if n_full else l, cache)
    tail = [one(pat[i]) for i in range(n_tail)]
    return {"periods": periods, "tail": tail}


def decode_state_axes(cfg: ModelConfig):
    """Logical sharding axes mirroring init_decode_state.

    KV caches shard the *sequence* dim over "kv_seq" (→ tensor axis): the
    split-K decode layout (DESIGN.md §6) — kv-head counts (1–8) are below
    the 16-way tensor axis so head-sharding cannot scale; recurrent states
    shard channels over "tensor"."""
    pat = period_pattern(cfg)
    n_full = cfg.n_layers // len(pat)
    n_tail = cfg.n_layers - n_full * len(pat)
    lead = ("layers",)

    def one(kind, stacked: bool):
        l = lead if stacked else ()
        if kind in ("attn", "local"):
            kv = l + ("batch", "kv_seq", None, None)
            return {"k": kv, "v": kv}
        if kind == "rglru":
            return {"h": l + ("batch", "tensor"),
                    "conv": l + ("batch", None, "tensor")}
        if kind == "mlstm":
            return {"C": l + ("batch", "tensor", None, None),
                    "n": l + ("batch", "tensor", None),
                    "m": l + ("batch", "tensor")}
        if kind == "slstm":
            ax = l + ("batch", "tensor")
            return {"c": ax, "n": ax, "h": ax, "m": ax}
        raise ValueError(kind)

    periods = {str(i): one(k, n_full > 0) for i, k in enumerate(pat)}
    tail = [one(pat[i], False) for i in range(n_tail)]
    return {"periods": periods, "tail": tail}


def _decode_block(cfg, kind, p, cache, x, pos):
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind in ("attn", "local"):
        window = cfg.local_window if kind == "local" else None
        out, ck, cv = L.run_attention_decode(
            p["attn"], cfg, h, cache["k"], cache["v"], pos, window=window)
        cache = {"k": ck, "v": cv}
        x = x + out
        h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.is_moe:
            out, _ = MOE.run_moe(p["moe"], cfg, h2)
            x = x + out
        elif cfg.d_ff > 0:
            x = x + L.run_ffn(p["ffn"], h2)
    elif kind == "rglru":
        out, (hh, conv) = RG.run_rglru_decode(
            p["rglru"], cfg, h, (cache["h"], cache["conv"]))
        cache = {"h": hh, "conv": conv}
        x = x + out
        if cfg.d_ff > 0:
            x = x + L.run_ffn(p["ffn"],
                              L.rms_norm(x, p["norm2"], cfg.norm_eps))
    elif kind == "mlstm":
        out, cache = XL.run_mlstm_decode(p["mlstm"], cfg, h, cache)
        x = x + out
    elif kind == "slstm":
        out, cache = XL.run_slstm_decode(p["slstm"], cfg, h, cache)
        x = x + out
    return x, cache


def decode_step(params, cfg: ModelConfig, state, token, pos):
    """One token for the whole stack.  token (B,1) int32; pos is (B,)
    per-sequence positions or a scalar (synchronized batch decode).
    Returns (logits (B,V), new_state)."""
    x = params["embed"][token]
    pat = period_pattern(cfg)
    n_full = cfg.n_layers // len(pat)

    def body(carry, scanned):
        x = carry
        pparams, pcache = scanned
        new_caches = {}
        for p_i, kind in enumerate(pat):
            x, c = _decode_block(cfg, kind, pparams[str(p_i)],
                                 pcache[str(p_i)], x, pos)
            new_caches[str(p_i)] = c
        return x, new_caches

    if n_full > 0:
        from ..launch.scan_registry import tagged_scan
        x, new_periods = tagged_scan(
            "tagscan_layers_dec", body, x,
            (params["periods"], state["periods"]), length=n_full)
    else:
        new_periods = state["periods"]
    new_tail = []
    for i, p in enumerate(params["tail"]):
        x, c = _decode_block(cfg, pat[i], p, state["tail"][i], x, pos)
        new_tail.append(c)

    x = L.rms_norm(x, params["out_norm"], cfg.norm_eps)
    w_out = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x @ w_out)[:, 0]
    return logits, {"periods": new_periods, "tail": new_tail}
