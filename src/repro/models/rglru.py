"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The real-gated linear recurrent unit:

    r_t = sigmoid(W_a x_t),  i_t = sigmoid(W_x x_t)
    a_t = a^{c * r_t}            (a = sigmoid(Lambda), elementwise, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill runs the scan as an associative scan over the sequence
(log-depth on TPU); decode keeps O(1) state per channel — which is what
makes the 500k-token long-context cell *runnable* for this family.

Block layout (Griffin): linear in-proj to (y, gate branch), short causal
conv1d, RG-LRU, gated output projection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import ParamStore


def init_rglru(store: ParamStore, cfg, name="rglru"):
    sub = store.subtree(name)
    d = cfg.d_model
    sub.add("w_in", (d, d), ("fsdp", "tensor"))
    sub.add("w_gate_branch", (d, d), ("fsdp", "tensor"))
    sub.add("conv_w", (cfg.conv1d_width, d), (None, "tensor"))
    sub.add("conv_b", (d,), ("tensor",), init="zeros")
    sub.add("w_a", (d, d), ("fsdp", "tensor"))
    sub.add("w_i", (d, d), ("fsdp", "tensor"))
    # Lambda init so that a = sigmoid(Lambda) in [0.9, 0.999] (Griffin)
    sub.add("lam", (d,), ("tensor",), init="ones", scale=1.0)
    sub.add("w_out", (d, d), ("tensor", "fsdp"))
    return sub


def _gates(p, cfg, x):
    """x (..., d) -> (log_a (..., d), gated_input (..., d))."""
    r = jax.nn.sigmoid((x @ p["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["w_i"]).astype(jnp.float32))
    log_a_base = jax.nn.log_sigmoid(8.0 * p["lam"].astype(jnp.float32))
    log_a = cfg.rglru_c * r * log_a_base          # (..., d), <= 0
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * i \
        * x.astype(jnp.float32)
    return log_a, gated


def _causal_conv(p, cfg, x, state=None):
    """Short depthwise causal conv. x (B,S,d). state (B,W-1,d) for decode."""
    w = cfg.conv1d_width
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (w - 1,) + x.shape[2:], x.dtype)
        xp = jnp.concatenate([pad, x], 1)
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], 1)
    out = sum(xp[:, i:xp.shape[1] - (w - 1 - i)] * p["conv_w"][i]
              for i in range(w))
    return out + p["conv_b"], xp[:, -(w - 1):]


def run_rglru(p, cfg, x, *, state=None):
    """Full-sequence pass. x (B,S,d) -> (B,S,d).

    ``state``: optional (h0 (B,d) f32, conv_state (B,W-1,d)) to resume."""
    b, s, d = x.shape
    gate_branch = jax.nn.gelu(x @ p["w_gate_branch"])
    y = x @ p["w_in"]
    h0 = None
    conv_state = None
    if state is not None:
        h0, conv_state = state
    y, conv_state = _causal_conv(p, cfg, y, conv_state)
    log_a, gated = _gates(p, cfg, y)

    # associative linear recurrence: h_t = exp(log_a_t) h_{t-1} + gated_t
    def combine(c1, c2):
        la1, u1 = c1
        la2, u2 = c2
        return la1 + la2, u1 * jnp.exp(la2) + u2

    if h0 is not None:
        gated = gated.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)
    la, h = jax.lax.associative_scan(combine, (log_a, gated), axis=1)
    out = (h.astype(x.dtype) * gate_branch) @ p["w_out"]
    return out, (h[:, -1], conv_state)


def run_rglru_decode(p, cfg, x, state):
    """One token. x (B,1,d); state = (h (B,d) f32, conv (B,W-1,d))."""
    h, conv_state = state
    gate_branch = jax.nn.gelu(x @ p["w_gate_branch"])
    y = x @ p["w_in"]
    y, conv_state = _causal_conv(p, cfg, y, conv_state)
    log_a, gated = _gates(p, cfg, y)
    h_new = jnp.exp(log_a[:, 0]) * h + gated[:, 0]
    out = (h_new[:, None].astype(x.dtype) * gate_branch) @ p["w_out"]
    return out, (h_new, conv_state)
