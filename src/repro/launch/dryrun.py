import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds abstract (ShapeDtypeStruct) state/batch trees
with their NamedShardings attached, lowers the jitted step, compiles it,
and records:

  - memory_analysis()  (per-device bytes: proves it fits)
  - cost_analysis()    (HLO FLOPs / bytes for §Roofline)
  - collective bytes   (parsed from the optimized HLO: all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute)

Results go to results/dryrun/<arch>__<shape>__<mesh>.json.  ``--all``
sweeps every supported cell in subprocesses (isolation: one cell's OOM or
crash cannot take down the sweep; XLA compilation memory is returned to
the OS between cells).

NOTE: the XLA_FLAGS line above MUST precede any jax import — jax locks
the device count at first init.  This module is the only place the
512-device fiction exists.
"""

import argparse
import json
import re
import subprocess
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import (cells, get_config, input_specs, step_kind)
from ..configs.base import SHAPES, input_batch_axes
from ..distributed.sharding import (DEFAULT_RULES, activation_sharding,
                                    batch_sharding, param_sharding)
from ..models import model as M
from ..optim import adamw_init
from ..train.trainer import (TrainState, init_train_state, make_train_step,
                             shardings_for_state)
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DUMP_HLO = None

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32"
                       r"|s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape literal in ``text``."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind byte totals from optimized HLO.

    Counts the *output* shape of each collective op line (the data that
    crosses links, up to algorithm factors noted in EXPERIMENTS.md)."""
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # match '<shape> <op>(' with optional '%name = ' prefix
        m = re.search(r"=\s*(\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES)
                      + r")[\s(-]", stripped)
        if not m:
            continue
        shape_txt, kind = m.groups()
        # fusions mentioning collectives in metadata don't match '= shape op('
        out[kind] += _shape_bytes(shape_txt)
        count[kind] += 1
    return {"bytes": out, "count": count,
            "total_bytes": sum(out.values())}


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------

def _abstract_state(cfg):
    """Abstract TrainState + axes pytree, zero allocation.

    The axes tree is plain Python built during tracing — capture it as a
    side effect of eval_shape."""
    captured = {}

    def build(key):
        state, axes = init_train_state(cfg, key)
        captured["axes"] = axes
        return state

    state_shapes = jax.eval_shape(build, jax.random.PRNGKey(0))
    return state_shapes, captured["axes"]


def _abstract_params(cfg):
    captured = {}

    def build(key):
        params, axes = M.init_model(cfg, key)
        captured["axes"] = axes
        return params

    params_shapes = jax.eval_shape(build, jax.random.PRNGKey(0))
    return params_shapes, captured["axes"]


GRAD_ACCUM = 8          # microbatch fold depth for train cells
MOE_PREFILL_CHUNK = 16384   # MoE token-chunking for serve paths


def abstract_train_cell(arch: str, shape: str, mesh, overrides=None):
    """(jitted train_step fn, abstract args) — no allocation."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    train_step = make_train_step(cfg, grad_accum=GRAD_ACCUM)
    state_shapes, axes = _abstract_state(cfg)
    state_sh = shardings_for_state(state_shapes, axes, mesh)
    state_abs = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        state_shapes, state_sh)

    batch_spec = input_specs(arch, shape, cfg)
    batch_axes = input_batch_axes(arch, shape, cfg)
    batch_sh = batch_sharding(mesh, batch_spec, logical_tree=batch_axes)
    batch_abs = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        batch_spec, batch_sh)

    def wrapped(s, b):
        with activation_sharding(mesh, DEFAULT_RULES):
            return train_step(s, b)

    fn = jax.jit(wrapped, out_shardings=(state_sh, NamedSharding(mesh, P())),
                 donate_argnums=(0,))
    return fn, (state_abs, batch_abs), cfg


def abstract_serve_cell(arch: str, shape: str, mesh, *, prefill: bool,
                        overrides=None):
    """Serve cells: prefill (full forward) or decode (one token + cache)."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if cfg.is_moe:
        cfg = _dc.replace(cfg, moe_token_chunk=MOE_PREFILL_CHUNK)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    spec = SHAPES[shape]
    b, s = spec["batch"], spec["seq"]
    params_shapes, axes = _abstract_params(cfg)
    p_sh = param_sharding(axes, mesh, params_shapes)
    params_abs = jax.tree.map(
        lambda sp, sh: jax.ShapeDtypeStruct(sp.shape, sp.dtype, sharding=sh),
        params_shapes, p_sh)

    if prefill:
        batch_spec = input_specs(arch, shape, cfg)
        batch_axes = input_batch_axes(arch, shape, cfg)
        batch_sh = batch_sharding(mesh, batch_spec, logical_tree=batch_axes)
        batch_abs = jax.tree.map(
            lambda sp, sh: jax.ShapeDtypeStruct(sp.shape, sp.dtype,
                                                sharding=sh),
            batch_spec, batch_sh)

        def prefill_step(params, batch):
            with activation_sharding(mesh, DEFAULT_RULES):
                logits, _ = M.forward(
                    params, cfg, batch.get("tokens"),
                    embeddings=batch.get("embeddings"),
                    mrope_positions=batch.get("mrope_positions"))
                return logits

        fn = jax.jit(prefill_step)
        return fn, (params_abs, batch_abs), cfg

    # decode: cache + one token
    cache_shapes = jax.eval_shape(partial(M.init_decode_state, cfg, b, s))
    cache_axes = M.decode_state_axes(cfg)
    rules = dict(DEFAULT_RULES, kv_seq="model")
    cache_sh = param_sharding(cache_axes, mesh, cache_shapes, rules)
    cache_abs = jax.tree.map(
        lambda sp, sh: jax.ShapeDtypeStruct(sp.shape, sp.dtype, sharding=sh),
        cache_shapes, cache_sh)
    tok_abs = jax.ShapeDtypeStruct(
        (b, 1), jnp.int32,
        sharding=batch_sharding(mesh, {"t": jax.ShapeDtypeStruct(
            (b, 1), jnp.int32)})["t"])
    # synchronized batch decode: one shared position scalar (enables the
    # aliasing-friendly dynamic-update-slice cache write)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=NamedSharding(mesh, P()))

    def decode(params, cache, token, pos):
        with activation_sharding(mesh, rules):
            return M.decode_step(params, cfg, cache, token, pos)

    fn = jax.jit(decode, out_shardings=(NamedSharding(mesh, P()), cache_sh),
                 donate_argnums=(1,))
    return fn, (params_abs, cache_abs, tok_abs, pos_abs), cfg


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def model_flops(cfg, shape_name: str) -> float:
    """MODEL_FLOPS: 6·N·D for training (N active params, D global tokens);
    2·N·D for inference (forward only).  Attention score flops excluded
    by convention (reported separately by the HLO analysis)."""
    spec = SHAPES[shape_name]
    kind = spec["kind"]
    n_active = cfg.params_active
    if kind == "train":
        tokens = spec["batch"] * spec["seq"]
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = spec["batch"] * spec["seq"]
        return 2.0 * n_active * tokens
    tokens = spec["batch"]  # one new token per sequence
    return 2.0 * n_active * tokens


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             overrides: dict | None = None, tag: str = "") -> dict:
    from .scan_registry import clear_registry, get_registry
    from .hlo_analysis import analyze

    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = step_kind(shape)
    clear_registry()
    t0 = time.time()
    if kind == "train":
        fn, args, cfg = abstract_train_cell(arch, shape, mesh, overrides)
    elif kind == "prefill":
        fn, args, cfg = abstract_serve_cell(arch, shape, mesh, prefill=True,
                                            overrides=overrides)
    else:
        fn, args, cfg = abstract_serve_cell(arch, shape, mesh,
                                            prefill=False,
                                            overrides=overrides)

    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    from ..core.compat import cost_analysis
    cost = cost_analysis(compiled)
    hlo = compiled.as_text()
    if _DUMP_HLO:
        with open(_DUMP_HLO, "w") as f:
            f.write(hlo)
        import pickle
        with open(_DUMP_HLO + ".registry", "w") as f:
            json.dump(get_registry(), f)
    coll_naive = collective_bytes(hlo)
    corrected = analyze(hlo, get_registry(),
                        flash_model=getattr(cfg, "flash_model", False))

    n_chips = int(mesh.devices.size)
    raw_flops = float(cost.get("flops", 0.0))
    # cost_analysis is per-device but counts while bodies once; the
    # call-graph walk gives trip-count-corrected per-device dot flops.
    flops = max(corrected["dot_flops"], raw_flops)
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    bytes_accessed = max(corrected["bytes_accessed"], raw_bytes)
    wire = corrected["total_wire_bytes"]
    mflops = model_flops(cfg, shape)

    result = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "hlo_flops_raw": raw_flops,
        "hlo_flops": flops,
        "hlo_bytes_accessed_raw": raw_bytes,
        "hlo_bytes_accessed": bytes_accessed,
        "collectives_naive": coll_naive,
        "collectives": {
            "raw_bytes": corrected["collective_raw_bytes"],
            "wire_bytes": corrected["collective_wire_bytes"],
            "counts": corrected["collective_counts"],
            "total_wire_bytes": wire,
        },
        "unknown_whiles": corrected["unknown_whiles"],
        "scan_registry": corrected["registry"],
        "params_total": int(cfg.params_total),
        "params_active": int(cfg.params_active),
        "model_flops_global": mflops,
        "model_flops_per_chip": mflops / n_chips,
        "useful_flops_ratio": (mflops / n_chips) / max(flops, 1.0),
    }
    # All quantities are per-device (SPMD-partitioned HLO shard shapes):
    # wire bytes per chip / link bandwidth == the brief's
    # global_bytes / (chips × link_bw).
    result["roofline"] = {
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": wire / ICI_BW,
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: result["roofline"][k])
    result["roofline"]["dominant"] = dom
    if overrides:
        result["overrides"] = {k: str(v) for k, v in overrides.items()}
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fname = f"{arch}__{shape}__{result['mesh']}{suffix}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--dump-hlo", help="write optimized HLO text here")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (perf experiments)")
    ap.add_argument("--tag", default="", help="result filename suffix")
    args = ap.parse_args()
    if args.dump_hlo:
        global _DUMP_HLO
        _DUMP_HLO = args.dump_hlo
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                if v in ("True", "False"):
                    v = v == "True"
        overrides[k] = v

    if args.all:
        jobs = []
        for arch, shape, ok, why in cells():
            for mp in (False, True):
                jobs.append((arch, shape, mp))
        failures = []
        for arch, shape, mp in jobs:
            mesh_tag = "2x16x16" if mp else "16x16"
            fname = os.path.join(args.out,
                                 f"{arch}__{shape}__{mesh_tag}.json")
            if args.skip_existing and os.path.exists(fname):
                print(f"SKIP {arch} {shape} {mesh_tag}", flush=True)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out", args.out]
            if mp:
                cmd.append("--multi-pod")
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True)
            dt = time.time() - t0
            if r.returncode != 0:
                failures.append((arch, shape, mesh_tag))
                print(f"FAIL {arch} {shape} {mesh_tag} ({dt:.0f}s)\n"
                      f"{r.stdout[-2000:]}\n{r.stderr[-2000:]}", flush=True)
            else:
                print(f"OK   {arch} {shape} {mesh_tag} ({dt:.0f}s)",
                      flush=True)
        print(f"\n{len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    res = run_cell(args.arch, args.shape, args.multi_pod, args.out,
                   overrides or None, args.tag)
    print(json.dumps({k: res[k] for k in
                      ("arch", "shape", "mesh", "hlo_flops",
                       "useful_flops_ratio", "roofline")}, indent=1))
    print("memory_analysis:", res["memory"])
    print("collective wire bytes:", res["collectives"]["wire_bytes"])
    if res["unknown_whiles"]:
        print("WARNING unknown whiles:", res["unknown_whiles"])


if __name__ == "__main__":
    main()
