"""Training driver — the MADlib host driver (§3.1.2) at LM scale.

Composes: config -> mesh -> sharded TrainState -> jitted train_step
(donated buffers) -> data pipeline (prefetched) -> checkpoint/restart +
fault-tolerance hooks.  Only scalar metrics cross to the host per step.

Runs at any scale: ``--devices host`` uses this machine's devices (the
runnable example path); the production meshes are exercised by dryrun.py.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced_config
from ..data import TokenStream, corpus_profile, make_lm_batches
from ..distributed import checkpoint as ckpt
from ..distributed.fault_tolerance import StragglerMitigator
from ..distributed.sharding import DEFAULT_RULES, batch_sharding
from ..train.trainer import (init_train_state, jit_train_step,
                             make_train_step)
from .mesh import make_host_mesh


def train(arch: str, *, steps: int = 100, batch: int = 8, seq: int = 128,
          reduced: bool = True, ckpt_dir: str | None = None,
          ckpt_every: int = 50, resume: bool = False, base_lr: float = 3e-3,
          log_every: int = 10, profile_data: bool = True):
    cfg = reduced_config(arch) if reduced else get_config(arch)
    mesh = make_host_mesh()
    rules = dict(DEFAULT_RULES)

    state, axes = init_train_state(cfg, jax.random.PRNGKey(0))
    step_fn = make_train_step(cfg, base_lr=base_lr, warmup=10,
                              total_steps=steps)
    stream = TokenStream(vocab=cfg.vocab, seq_len=seq, batch=batch)
    if profile_data:
        prof = corpus_profile(iter(stream), vocab=cfg.vocab, n_batches=2)
        print(f"[data] distinct-token estimate: "
              f"{float(prof['distinct_estimate']):.0f}")

    sample = next(iter(stream))
    batch_spec = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                  for k, v in sample.items()}
    fn = jit_train_step(step_fn, state, axes, batch_spec, mesh, rules)
    batch_sh = batch_sharding(mesh, batch_spec, rules)

    start_step = 0
    if resume and ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        state, start_step = ckpt.restore(ckpt_dir, state)
        print(f"[ckpt] resumed from step {start_step}")

    writer = ckpt.AsyncCheckpointer()
    straggler = StragglerMitigator(["host0"])
    losses = []
    t_last = time.time()
    for i, b in enumerate(make_lm_batches(stream, mesh, batch_sh)):
        step_no = start_step + i
        if step_no >= steps:
            break
        state, metrics = fn(state, b)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.time() - t_last
        t_last = time.time()
        straggler.record("host0", dt)
        if step_no % log_every == 0:
            print(f"step {step_no:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt * 1e3:.0f} ms)",
                  flush=True)
        if ckpt_dir and step_no > 0 and step_no % ckpt_every == 0:
            writer.save(ckpt_dir, state, step_no)
    writer.wait()
    if ckpt_dir:
        ckpt.save(ckpt_dir, state, min(steps, start_step + len(losses)))
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full config (needs real accelerators)")
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()
    losses = train(args.arch, steps=args.steps, batch=args.batch,
                   seq=args.seq, reduced=not args.full,
                   ckpt_dir=args.ckpt_dir, resume=args.resume,
                   base_lr=args.lr)
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
