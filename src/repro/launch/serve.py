"""Serving driver: batched decode with a KV/recurrent cache.

Host-scale runnable (reduced configs); the production decode cells are
exercised by dryrun.py with the sequence-sharded split-K layout.

This is the LM-decode serving demo.  The *analytics* serving front-end —
concurrent analyst sessions sharing scans through an
:class:`~repro.core.AnalyticsServer` admission window — lives in
:mod:`repro.launch.analytics_serve`.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced_config
from ..models import model as M


def serve(arch: str, *, batch: int = 4, prompt_len: int = 16,
          gen_len: int = 32, reduced: bool = True, temperature: float = 0.8,
          seed: int = 0):
    cfg = reduced_config(arch) if reduced else get_config(arch)
    if cfg.family == "audio":
        raise ValueError("encoder-only arch has no decode path")
    key = jax.random.PRNGKey(seed)
    params, _ = M.init_model(cfg, key)
    max_seq = prompt_len + gen_len
    state = M.init_decode_state(cfg, batch, max_seq)
    step = jax.jit(lambda p, s, t, pos: M.decode_step(p, cfg, s, t, pos))

    toks = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)
    # prefill by teacher-forced decode (exercises the cache path end2end)
    t0 = time.time()
    logits = None
    for t in range(prompt_len):
        logits, state = step(params, state, toks[:, t:t + 1],
                             jnp.full((batch,), t, jnp.int32))
    t_prefill = time.time() - t0

    out = []
    cur = jnp.argmax(logits, -1)[:, None]
    t0 = time.time()
    for t in range(prompt_len, max_seq):
        key, sub = jax.random.split(key)
        logits, state = step(params, state, cur,
                             jnp.full((batch,), t, jnp.int32))
        cur = jax.random.categorical(sub, logits / temperature)[:, None]
        out.append(cur)
    t_gen = time.time() - t0
    gen = jnp.concatenate(out, 1)
    tok_s = batch * gen_len / max(t_gen, 1e-9)
    print(f"{arch}: prefill {prompt_len} tok in {t_prefill:.2f}s; "
          f"generated {gen_len} tok x {batch} seqs at {tok_s:.1f} tok/s")
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
          gen_len=args.gen_len, reduced=not args.full)


if __name__ == "__main__":
    main()
