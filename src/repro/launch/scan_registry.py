"""Tagged scans: trace-time trip-count registry for HLO cost accounting.

XLA's ``cost_analysis`` counts while-loop bodies ONCE, so any roofline
built on it underreports scanned layers by the trip count.  Every scan in
the model stack goes through :func:`tagged_scan`, which (a) wraps the scan
in a ``jax.named_scope`` whose tag survives into the optimized HLO's
``op_name`` metadata, and (b) records the trip count in a registry.  The
HLO analyzer (hlo_analysis.py) walks the call graph and multiplies
in-body flops/collective-bytes by the registered trip counts — including
nested scans (chunked attention inside the layer scan) and the remat'd
backward whiles (their op_name contains the same tag).
"""

from __future__ import annotations

import threading
from typing import Any, Callable

import jax

_local = threading.local()


def _reg() -> dict[str, int]:
    if not hasattr(_local, "registry"):
        _local.registry = {}
    return _local.registry


def clear_registry():
    _reg().clear()


def get_registry() -> dict[str, int]:
    return dict(_reg())


def tagged_scan(tag: str, f: Callable, init, xs=None, *, length=None,
                unroll: int = 1, reverse: bool = False):
    """jax.lax.scan wrapped in a named scope + trip-count registration.

    The scope name is length-qualified (``tag_L<n>``) so the same call
    site traced at different lengths (e.g. across tests, or train vs
    prefill in one process) registers unambiguously.  Tags must be chosen
    so no tag is a substring of another (the HLO matcher is
    substring-based over op_name paths; the innermost match wins)."""
    if length is None:
        length = jax.tree.leaves(xs)[0].shape[0]
    qualified = f"{tag}_L{int(length)}"
    _reg()[qualified] = int(length)
    with jax.named_scope(qualified):
        return jax.lax.scan(f, init, xs, length=length, unroll=unroll,
                            reverse=reverse)
