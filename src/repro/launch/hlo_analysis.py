"""Optimized-HLO call-graph analysis: trip-count-corrected flops and
collective bytes.

Parses ``compiled.as_text()`` into computations, builds the call graph
(while bodies, fusions, calls, conditionals), assigns every while a
multiplicity from the scan registry (matched by tag substring in the
op_name metadata), and walks from ENTRY accumulating:

  * dot flops: 2 · prod(output dims) · prod(contracting dims)
  * collective bytes per kind, with wire-byte convention:
      all-reduce         2 × payload   (reduce-scatter + all-gather ring)
      all-gather         output bytes
      reduce-scatter     input bytes
      all-to-all         input bytes
      collective-permute input bytes
    (recorded both raw and conventioned; EXPERIMENTS.md documents this)

Elementwise flops are not counted (≪1% of a transformer step); the raw
``cost_analysis()`` number is reported alongside as a floor.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred"
    r"|c64|c128)\[([0-9,]*)\]")

# tuple shapes contain /*index=N*/ comments (with '=' and '*'), so the
# shape group must simply run to the matching close-paren (no nesting in
# HLO shape syntax).
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*(?P<shape>\([^)]*\)|\S+)"
    r"\s+(?P<kind>[\w\-]+)\((?P<rest>.*)$")

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w\.\-]+)\s*\(.*\{\s*$")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_dims(shape_txt: str):
    m = _SHAPE_RE.search(shape_txt)
    if not m:
        return None, ()
    dt, dims = m.groups()
    if not dims:
        return dt, ()
    return dt, tuple(int(d) for d in dims.split(","))


def shape_bytes(shape_txt: str) -> int:
    """Total bytes of every typed literal in the text (tuples summed)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_txt):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    kind: str
    rest: str
    op_name: str


def parse_computations(text: str) -> dict[str, list[Op]]:
    comps: dict[str, list[Op]] = {}
    cur: list[Op] | None = None
    entry_marker = "__ENTRY__"
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                name = m.group("name")
                if line.lstrip().startswith("ENTRY"):
                    comps[entry_marker] = comps.setdefault(name, [])
                cur = comps.setdefault(name, [])
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        mm = re.search(r'op_name="([^"]*)"', line)
        cur.append(Op(m.group("name"), m.group("shape"), m.group("kind"),
                      m.group("rest"), mm.group(1) if mm else ""))
    return comps


def _dot_flops(op: Op, symtab: dict[str, str]) -> float:
    _, out_dims = shape_dims(op.shape)
    out_n = 1
    for d in out_dims:
        out_n *= d
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    if not mc:
        return 0.0
    cdims = [int(x) for x in mc.group(1).split(",")] if mc.group(1) else []
    # lhs operand = first %name in rest
    mo = re.search(r"%([\w\.\-]+)", op.rest)
    if not mo:
        return 0.0
    lhs_shape = symtab.get(mo.group(1))
    if lhs_shape is None:
        return 0.0
    _, lhs_dims = shape_dims(lhs_shape)
    k = 1
    for c in cdims:
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    return 2.0 * out_n * k


def _trip_count(op_name: str, registry: dict[str, int], unknown: list,
                body_ops: "list[Op] | None" = None) -> int:
    """Innermost matching tag wins: a nested scan's op_name path contains
    every ancestor scope's tag too (e.g. layers_fwd/attn_q/attn_kv), and
    this while's own trip count is the LAST tag on the path.

    Fallback: some JAX versions emit the transposed (backward) scan's
    while with no metadata at all, while the body instructions still
    carry the full scope path (``transpose(jvp(tag_Ln))/...``).  When the
    while itself doesn't match, attribute the OUTERMOST (leftmost) tag
    found on any body instruction — body paths of a nested scan contain
    the ancestor tag first, and the ancestor is this while."""
    best, best_pos = None, -1
    for tag, n in registry.items():
        pos = op_name.rfind(tag)
        if pos > best_pos:
            best, best_pos = n, pos
    if best is not None:
        return best
    if body_ops:
        cand, cand_pos = None, None
        for o in body_ops:
            for tag, n in registry.items():
                pos = o.op_name.find(tag)
                if pos >= 0 and (cand_pos is None or pos < cand_pos):
                    cand, cand_pos = n, pos
        if cand is not None:
            return cand
    unknown.append(op_name or "<no-metadata>")
    return 1


# scan tags whose bodies execute inside the Pallas flash-attention kernel
# on the TPU target (kernels/flash_attention, validated vs its oracle):
# their intermediates (chunk logits / probabilities / running stats) are
# VMEM-resident, so HBM-byte accounting keeps only the streamed
# dynamic-slice loads (q/k/v tiles) and dynamic-update-slice writes
# (output tiles) — the kernel's actual HBM traffic.
FLASH_TAGS = ("tagscan_attn_kv", "tagscan_attn_q")


def analyze(text: str, registry: dict[str, int], *,
            flash_model: bool = False) -> dict:
    comps = parse_computations(text)
    entry = comps.get("__ENTRY__")
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # per-computation symbol tables (name -> shape), incl. parameters
    symtabs = {name: {op.name: op.shape for op in ops}
               for name, ops in comps.items()}
    opindex = {name: {op.name: op for op in ops}
               for name, ops in comps.items()}

    def _promoted_from_bf16(op: "Op", comp_name: str) -> bool:
        """XLA:CPU promotes bf16 collectives to f32 (convert -> collective
        -> convert).  On TPU these run at bf16 width; detect the pattern
        and count payload at source width (see EXPERIMENTS.md §Method)."""
        if "f32[" not in op.shape[:8]:
            return False
        mo = re.search(r"%([\w\.\-]+)", op.rest)
        if not mo:
            return False
        prod = opindex[comp_name].get(mo.group(1))
        if prod is None:
            return False
        if prod.kind == "convert":
            src = re.search(r"%([\w\.\-]+)", prod.rest)
            srcsh = symtabs[comp_name].get(src.group(1), "") if src else ""
            return srcsh.startswith("bf16")
        if prod.kind == "fusion":
            m = re.search(r"calls=%?([\w\.\-]+)", prod.rest)
            callee = comps.get(m.group(1)) if m else None
            if callee and prod.shape.startswith("f32"):
                # promoted if the fused producer upconverts a bf16 tensor
                # of the same element count (XLA:CPU's promotion pattern)
                _, out_dims = shape_dims(prod.shape)
                n_out = 1
                for dd in out_dims:
                    n_out *= dd
                for o in callee:
                    if o.kind != "convert":
                        continue
                    src = re.search(r"%([\w\.\-]+)", o.rest)
                    srcsh = (symtabs[m.group(1)].get(src.group(1), "")
                             if src else "")
                    if srcsh.startswith("bf16"):
                        _, sdims = shape_dims(srcsh)
                        n_src = 1
                        for dd in sdims:
                            n_src *= dd
                        if n_src == n_out:
                            return True
        return False

    flops_acc = defaultdict(float)
    coll_raw = defaultdict(float)
    coll_wire = defaultdict(float)
    coll_count = defaultdict(int)
    bytes_acc = [0.0]
    unknown_whiles: list[str] = []

    # ops that are pure bookkeeping (no HBM traffic of their own).  while
    # is excluded because its carry is aliased in place (entry copies show
    # up as explicit `copy` ops, which are counted).
    _NO_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "after-all", "partition-id", "replica-id",
                 "while", "conditional", "call"}

    def callee_names(op: Op) -> list[tuple[str, float]]:
        """(computation, extra multiplicity) pairs an op invokes."""
        out = []
        if op.kind == "while":
            mb = re.search(r"body=%?([\w\.\-]+)", op.rest)
            mc = re.search(r"condition=%?([\w\.\-]+)", op.rest)
            trip = _trip_count(op.op_name, registry, unknown_whiles,
                               comps.get(mb.group(1)) if mb else None)
            if mb:
                out.append((mb.group(1), float(trip)))
            if mc:
                out.append((mc.group(1), float(trip)))
        elif op.kind in ("fusion", "call", "map", "reduce", "reduce-window",
                         "sort", "scatter", "select-and-scatter",
                         "all-reduce", "reduce-scatter"):
            for attr in ("calls", "to_apply"):
                m = re.search(attr + r"=%?([\w\.\-]+)", op.rest)
                if m:
                    out.append((m.group(1), 1.0))
        elif op.kind == "conditional":
            for m in re.finditer(r"branch_computations=\{([^}]*)\}",
                                 op.rest):
                for nm in m.group(1).split(","):
                    out.append((nm.strip().lstrip("%"), 1.0))
            for m in re.finditer(r"(?:true|false)_computation=%?([\w\.\-]+)",
                                 op.rest):
                out.append((m.group(1), 1.0))
        return out

    def _operand_names(op: Op) -> list[str]:
        head = op.rest.split("metadata=")[0]
        # operands are the leading %names before any attr=
        head = re.split(r"\b(?:calls|to_apply|body|condition|dimensions"
                        r"|sharding|channel_id)=", head)[0]
        return [m.group(1) for m in re.finditer(r"%([\w\.\-]+)", head)]

    def _operand_bytes(op: Op, symtab) -> float:
        return float(sum(shape_bytes(symtab.get(nm, ""))
                         for nm in _operand_names(op)))

    def _fusion_stream_bytes(op: Op) -> float:
        """Stream mode: charge only dynamic-slice outputs and dus updates
        inside the fused computation (the HBM tile traffic of the Pallas
        flash kernel; everything else is VMEM-resident)."""
        m = re.search(r"calls=%?([\w\.\-]+)", op.rest)
        callee = comps.get(m.group(1)) if m else None
        if callee is None:
            return 0.0
        ctab = symtabs[m.group(1)]
        total = 0.0
        for o in callee:
            if o.kind == "dynamic-slice":
                total += shape_bytes(o.shape)
            elif o.kind == "dynamic-update-slice":
                on = _operand_names(o)
                if len(on) > 1:
                    total += 2 * shape_bytes(ctab.get(on[1], ""))
        return total

    def _fusion_bytes(op: Op, symtab) -> float:
        """Slice-aware bytes for a fusion: parameters consumed by
        dynamic-slice inside the fused computation are charged at slice
        size; a dynamic-update-slice root charges its update (the full
        output is aliased in place)."""
        m = re.search(r"calls=%?([\w\.\-]+)", op.rest)
        callee = comps.get(m.group(1)) if m else None
        if callee is None:
            return shape_bytes(op.shape) + _operand_bytes(op, symtab)
        callee_tab = symtabs[m.group(1)]
        # param op name -> param index
        pidx = {}
        for o in callee:
            if o.kind == "parameter":
                mi = re.match(r"\s*(\d+)", o.rest)
                if mi:
                    pidx[o.name] = int(mi.group(1))
        sliced: dict[int, float] = {}
        aliased: set[int] = set()
        root_is_dus = False
        dus_update = 0.0
        for o in callee:
            opnds = _operand_names(o)
            if o.kind == "dynamic-slice" and opnds:
                if opnds[0] in pidx:
                    i = pidx[opnds[0]]
                    sliced[i] = sliced.get(i, 0.0) + shape_bytes(o.shape)
            elif o.kind == "dynamic-update-slice" and opnds:
                if opnds[0] in pidx:
                    aliased.add(pidx[opnds[0]])
                if len(opnds) > 1:
                    upd_sh = callee_tab.get(opnds[1], "")
                    if not upd_sh and opnds[1] in pidx:
                        # update comes in as a fusion parameter: price it
                        # from the caller's operand shape
                        outer = _operand_names(op)
                        j = pidx[opnds[1]]
                        if j < len(outer):
                            upd_sh = symtab.get(outer[j], "")
                    dus_update += shape_bytes(upd_sh)
                root_is_dus = True  # (dus is virtually always the root)
        total = 0.0
        operands = _operand_names(op)
        for i, nm in enumerate(operands):
            sh = symtab.get(nm)
            if sh is None:
                continue
            if i in sliced:
                total += sliced[i]
            elif i in aliased:
                continue  # read-modify-write accounted via the update
            else:
                total += shape_bytes(sh)
        if root_is_dus and dus_update > 0:
            total += 2 * dus_update          # read + write of the window
        else:
            total += shape_bytes(op.shape)   # output write
        return total

    def walk(comp_name: str, mult: float, count_bytes, depth=0):
        # count_bytes: False | True | "stream" (flash: slices/dus only)
        ops = comps.get(comp_name)
        if ops is None or depth > 64:
            return
        stream = count_bytes == "stream"
        symtab = symtabs[comp_name]
        for op in ops:
            if op.kind == "dot":
                flops_acc["dot"] += mult * _dot_flops(op, symtab)
                if count_bytes and not stream:
                    bytes_acc[0] += mult * (shape_bytes(op.shape)
                                            + _operand_bytes(op, symtab))
            elif op.kind in COLLECTIVES or any(
                    op.kind == c + "-start" for c in COLLECTIVES):
                kind = op.kind.replace("-start", "")
                if kind == "all-gather":
                    raw = shape_bytes(op.shape)          # output
                    wire = raw
                elif kind == "all-reduce":
                    raw = shape_bytes(op.shape)
                    wire = 2 * raw
                else:
                    # input operand bytes: first operand's shape
                    mo = re.search(r"%([\w\.\-]+)", op.rest)
                    raw = (shape_bytes(symtab.get(mo.group(1), ""))
                           if mo else shape_bytes(op.shape))
                    if raw == 0:
                        raw = shape_bytes(op.shape)
                    wire = raw
                if _promoted_from_bf16(op, comp_name):
                    raw *= 0.5   # runs at bf16 width on the target HW
                    wire *= 0.5
                    coll_count["bf16_promoted"] = \
                        coll_count.get("bf16_promoted", 0) + 1
                coll_raw[kind] += mult * raw
                coll_wire[kind] += mult * wire
                coll_count[kind] += 1
                if count_bytes and not stream:
                    bytes_acc[0] += mult * (shape_bytes(op.shape)
                                            + _operand_bytes(op, symtab))
            elif count_bytes and op.kind == "fusion":
                if stream:
                    # only the ds/dus traffic inside the fused computation
                    b = _fusion_stream_bytes(op)
                    bytes_acc[0] += mult * b
                else:
                    bytes_acc[0] += mult * _fusion_bytes(op, symtab)
            elif count_bytes and op.kind == "dynamic-slice":
                bytes_acc[0] += mult * 2 * shape_bytes(op.shape)
            elif count_bytes and op.kind == "dynamic-update-slice":
                upd = _operand_names(op)
                sz = (shape_bytes(symtab.get(upd[1], "")) if len(upd) > 1
                      else shape_bytes(op.shape))
                bytes_acc[0] += mult * 2 * sz
            elif count_bytes and not stream and op.kind not in _NO_BYTES:
                bytes_acc[0] += mult * (shape_bytes(op.shape)
                                        + _operand_bytes(op, symtab))
            for callee, extra in callee_names(op):
                # fusion-internal ops live in registers/VMEM: only while /
                # call / conditional bodies keep HBM-bytes accounting on
                if op.kind in ("while", "call", "conditional"):
                    inner = count_bytes
                    if (flash_model and op.kind == "while"
                            and any(t in op.op_name for t in FLASH_TAGS)
                            and count_bytes):
                        inner = "stream"
                else:
                    inner = False
                walk(callee, mult * extra, inner, depth + 1)

    # find the real entry computation name
    entry_name = next(n for n, ops in comps.items()
                      if n != "__ENTRY__" and ops is entry)
    walk(entry_name, 1.0, True)

    return {
        "dot_flops": flops_acc["dot"],
        "bytes_accessed": bytes_acc[0],
        "collective_raw_bytes": dict(coll_raw),
        "collective_wire_bytes": dict(coll_wire),
        "collective_counts": dict(coll_count),
        "total_wire_bytes": float(sum(coll_wire.values())),
        "unknown_whiles": sorted(set(unknown_whiles)),
        "registry": dict(registry),
    }
