"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run forces 512 host devices *before*
any jax initialization; everything else sees the real topology).
"""

from __future__ import annotations

import jax

from ..core.compat import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has — data-parallel only (used by the
    runnable examples; never 512-forced)."""
    n = len(jax.devices())
    return _compat_make_mesh((n,), ("data",))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12         # per chip
HBM_BW = 819e9                   # bytes/s per chip
ICI_BW = 50e9                    # bytes/s per link
