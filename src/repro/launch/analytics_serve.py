"""Analytics serving front-end: a multi-session demo loop over
:class:`~repro.core.AnalyticsServer`.

``python -m repro.launch.analytics_serve`` stands up one server and N
simulated analyst sessions issuing rounds of same-table statements
(profile / linregr / count-min / FM) from concurrent threads, with a
configurable append-ingest cadence racing the admission window.  It
prints per-round serving telemetry — statements, physical scans, dedup
and cache-hit counts, scans saved — straight from the server's trace
events, i.e. the in-database serving story of the paper (§3.2) made
observable: many analysts, one scan.  ``--drain=thread`` switches to
the production posture: the server's background drainer fires the
admission windows on ``--window-timeout`` and the analyst threads wait
passively on their handles instead of flushing.

This is the analytics sibling of :mod:`repro.launch.serve` (LM decode);
see :mod:`repro.core.server` for the admission-window and cache
contracts, and ``benchmarks/bench_serve.py`` for the measured version.
"""

from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from ..core import AnalyticsServer, Session, Table, trace_execution


def _make_table(rows: int, dims: int, seed: int = 0) -> Table:
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, dims), dtype=np.float32)
    b = rng.standard_normal(dims, dtype=np.float32)
    y = (x @ b + 0.1 * rng.standard_normal(rows, dtype=np.float32))
    return Table.from_columns({
        "x": x, "y": y.astype(np.float32),
        "item": rng.integers(0, 1000, rows).astype(np.int32)})


def _analyst_round(session: Session, table: Table,
                   passive: bool = False) -> list:
    hs = [session.profile(table), session.linregr(table),
          session.countmin_sketch(table), session.fm_distinct_count(table)]
    if passive:
        # drain="thread": wait for the background drainer to fire the
        # window — nothing on this thread ever demands a flush, so the
        # subsequent run() only gathers already-resolved handles
        for h in hs:
            if hasattr(h, "wait"):
                assert h.wait(60), "background drainer never fired"
    return session.run()


def serve_analytics(*, rows: int = 100_000, dims: int = 8,
                    sessions: int = 8, rounds: int = 4,
                    window_size: int = 64, drain: str = "demand",
                    window_timeout: float | None = None,
                    append_every: int = 2, seed: int = 0) -> dict:
    """Run the demo loop; returns the final server stats dict.

    ``drain="thread"`` exercises the background drainer: every analyst
    thread submits its round and then waits PASSIVELY on its handles
    (no demand flush) — the server's own drain thread fires the windows
    on ``window_timeout``, the production serving posture."""
    table = _make_table(rows, dims, seed)
    rng = np.random.default_rng(seed + 1)
    if drain == "thread" and window_timeout is None:
        window_timeout = 0.01
    server = AnalyticsServer(window_size=window_size, drain=drain,
                             window_timeout=window_timeout)
    pool = [Session(server=server) for _ in range(sessions)]
    passive = drain == "thread"

    for rnd in range(rounds):
        if append_every and rnd and rnd % append_every == 0:
            m = max(1, rows // 200)
            table.append({
                "x": rng.standard_normal((m, dims)).astype(np.float32),
                "y": rng.standard_normal(m).astype(np.float32),
                "item": rng.integers(0, 1000, m).astype(np.int32)})
            print(f"round {rnd}: ingest +{m} rows -> cache evicted "
                  f"(total {server.stats['evicted']})")
        results: list = [None] * sessions
        with trace_execution() as t:
            t0 = time.perf_counter()
            threads = [threading.Thread(
                target=lambda i=i: results.__setitem__(
                    i, _analyst_round(pool[i], table, passive)))
                for i in range(sessions)]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            dt = time.perf_counter() - t0
        summ = t.summary()
        stmts = sessions * 4
        print(f"round {rnd}: {sessions} sessions x 4 statements | "
              f"scans={summ.get('scan', 0)} "
              f"cache_hits={summ.get('cache_hit', 0)} "
              f"deduped={summ.get('deduped', 0)} "
              f"scans_saved={summ.get('scans_saved', 0)} | "
              f"{stmts / dt:.0f} stmts/s")
    stats = dict(server.stats)
    server.close()
    print(f"lifetime: {stats}")
    return stats


def main():
    ap = argparse.ArgumentParser(
        description="analytics serving demo: N sessions, one scan")
    ap.add_argument("--rows", type=int, default=100_000)
    ap.add_argument("--dims", type=int, default=8)
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--window-size", type=int, default=64)
    ap.add_argument("--drain", choices=("demand", "thread"),
                    default="demand",
                    help="'thread' = background drainer; analysts wait "
                         "passively instead of flushing")
    ap.add_argument("--window-timeout", type=float, default=None,
                    help="window age (s) that auto-drains; defaults to "
                         "0.01 with --drain=thread")
    ap.add_argument("--append-every", type=int, default=2,
                    help="ingest a delta every K rounds (0 = never)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    serve_analytics(rows=args.rows, dims=args.dims,
                    sessions=args.sessions, rounds=args.rounds,
                    window_size=args.window_size, drain=args.drain,
                    window_timeout=args.window_timeout,
                    append_every=args.append_every, seed=args.seed)


if __name__ == "__main__":
    main()
