"""Segment-fold kernel package + registry dispatch semantics.

The grouped-engine-level bit-identity matrix lives in
``test_engine_parity.py``; this file pins the layers underneath it:

* the jnp ref oracles and the (interpret-mode) Pallas kernel bodies
  agree bit-for-bit on the group-aligned layout, including the
  masked-invalid sentinel pad blocks ``sharded_blocks`` emits;
* registry resolve semantics: auto degrades to ref off-TPU or when the
  ``supports`` gate rejects; a forced ``impl="pallas"`` warns once (and
  runs interpret) off-TPU but FAILS LOUDLY on a TPU shape the compiled
  kernel cannot take;
* ``supports`` as a ranker: tuned kwargs from the active calibration
  flow into the pallas impl, explicit caller kwargs win;
* kernel dispatch records the RESOLVED impl on active traces, once per
  physical grouped execution;
* a single-member ``FusedAggregate`` (what the planner builds for a lone
  grouped statement) forwards its member's kernel hook.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Table, run_grouped, trace_execution
from repro.core.aggregates import FusedAggregate
from repro.kernels import registry
from repro.kernels.segment_fold import ops as sf_ops, ref as sf_ref
from repro.methods.linregr import LinregrAggregate
from repro.methods.sketches import CountMinAggregate, FMAggregate

G = 3
BS = 16


def _layout(n_blocks=6, bs=BS, sentinel=True, seed=0):
    """A hand-built group-aligned layout: ``n_blocks`` blocks of ``bs``
    rows, some validity padding, and (optionally) a trailing sentinel pad
    block carrying gid == G — exactly what ``sharded_blocks`` emits."""
    rng = np.random.default_rng(seed)
    gids = rng.integers(0, G, size=n_blocks).astype(np.int32)
    if sentinel:
        gids = np.concatenate([gids, np.array([G], np.int32)])
    n2 = len(gids) * bs
    valid = rng.random(n2) < 0.8
    if sentinel:  # sentinel rows are garbage; the gid guard must drop them
        valid[-bs:] = rng.random(bs) < 0.5
    x = (rng.integers(-8, 8, size=(n2, 3)) / 4.0).astype(np.float32)
    y = (rng.integers(-8, 8, size=(n2,)) / 4.0).astype(np.float32)
    items = rng.integers(0, 500, size=n2).astype(np.int32)
    return (jnp.asarray(x), jnp.asarray(y), jnp.asarray(items),
            jnp.asarray(valid), jnp.asarray(gids))


def _tree_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# -- ref oracle vs interpret-mode Pallas body ---------------------------------

@pytest.mark.parametrize("sentinel", [False, True])
def test_linregr_kernel_matches_ref(sentinel):
    x, y, _, valid, gids = _layout(sentinel=sentinel)
    want = sf_ref.segment_linregr_ref(x, y, valid, gids, num_groups=G)
    got = sf_ops.segment_linregr(x, y, valid, gids, num_groups=G)
    _tree_equal(got, want)


@pytest.mark.parametrize("sentinel", [False, True])
def test_countmin_kernel_matches_ref(sentinel):
    _, _, items, valid, gids = _layout(sentinel=sentinel, seed=1)
    want = sf_ref.segment_countmin_ref(items, valid, gids, depth=4,
                                       width=128, num_groups=G)
    got = sf_ops.segment_countmin(items, valid, gids, depth=4, width=128,
                                  num_groups=G)
    _tree_equal(got, want)


@pytest.mark.parametrize("sentinel", [False, True])
@pytest.mark.parametrize("bits", [16, 32])
def test_fm_kernel_matches_ref(sentinel, bits):
    """Covers both FM bit widths — including the bits-1 fallback when a
    hash has no set bit inside the window (the argmax-free lowbit
    formulation in the kernel must reproduce the oracle exactly)."""
    _, _, items, valid, gids = _layout(sentinel=sentinel, seed=2)
    want = sf_ref.segment_fm_ref(items, valid, gids, num_hashes=4,
                                 bits=bits, num_groups=G)
    got = sf_ops.segment_fm(items, valid, gids, num_hashes=4, bits=bits,
                            num_groups=G)
    _tree_equal(got, want)


def test_torn_layout_fails_loudly():
    x, y, _, valid, gids = _layout()
    with pytest.raises(ValueError, match="equal group-aligned blocks"):
        sf_ops.segment_linregr(x[:-1], y[:-1], valid[:-1], gids,
                               num_groups=G)
    with pytest.raises(ValueError, match="equal blocks"):
        sf_ref.segment_linregr_ref(x[:-1], y[:-1], valid[:-1], gids,
                                   num_groups=G)


# -- registry resolve semantics -----------------------------------------------

def test_auto_resolves_ref_off_tpu():
    x, y, _, valid, gids = _layout()
    entry = registry.get("segment_linregr")
    if jax.default_backend() != "tpu":
        assert entry.resolve("auto", x, y, valid, gids,
                             num_groups=G) == ("ref", {})


def test_forced_pallas_off_tpu_warns_once_and_runs_interpret():
    if jax.default_backend() == "tpu":
        pytest.skip("off-TPU interpret-mode semantics")
    x, y, _, valid, gids = _layout()
    entry = registry.get("segment_linregr")
    registry._WARNED_INTERPRET.discard("segment_linregr")
    with pytest.warns(UserWarning, match="interpret mode"):
        assert entry.resolve("pallas", x, y, valid, gids,
                             num_groups=G)[0] == "pallas"
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # second resolve: silent
        assert entry.resolve("pallas", x, y, valid, gids,
                             num_groups=G)[0] == "pallas"


def test_forced_pallas_on_tpu_unsupported_shape_raises(monkeypatch):
    """Satellite contract: on a TPU backend, forcing impl='pallas' for a
    call the supports gate rejects must fail loudly — never silently
    degrade to ref."""
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    _, _, items, valid, gids = _layout()
    entry = registry.get("segment_fm")
    # bits=16 fails the compiled kernel's lane gate (bits % 128)
    with pytest.raises(ValueError, match="supports gate rejected"):
        entry.resolve("pallas", items, valid, gids, num_hashes=4, bits=16,
                      num_groups=G)
    # auto with the same shapes degrades to ref instead
    assert entry.resolve("auto", items, valid, gids, num_hashes=4,
                         bits=16, num_groups=G) == ("ref", {})


def test_supports_runs_on_shape_structs():
    """Host-side resolution probes supports with ShapeDtypeStructs."""
    x = jax.ShapeDtypeStruct((96, 3), jnp.float32)
    y = jax.ShapeDtypeStruct((96,), jnp.float32)
    valid = jax.ShapeDtypeStruct((96,), jnp.bool_)
    gids = jax.ShapeDtypeStruct((6,), jnp.int32)
    assert sf_ops.segment_linregr_supports(x, y, valid, gids,
                                           num_groups=G) is True
    bad = jax.ShapeDtypeStruct((96, 3), jnp.float64)
    assert sf_ops.segment_linregr_supports(bad, y, valid, gids,
                                           num_groups=G) is False


def test_supports_ranker_tuned_kwargs(monkeypatch):
    """supports may return tuned kwargs (a ranker, not just a gate):
    they flow into the pallas impl only, and caller kwargs win."""
    calls = {}

    def fake_pallas(x, *, tile_n=1):
        calls["tile_n"] = tile_n
        return x

    registry.register("_test_ranker", ref=lambda x, **kw: x,
                      pallas=fake_pallas,
                      supports=lambda x, **kw: {"tile_n": 77},
                      overwrite=True)
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    x = jnp.zeros((8,))
    registry.dispatch("_test_ranker", x)
    assert calls["tile_n"] == 77                       # tuned kwarg applied
    registry.dispatch("_test_ranker", x, tile_n=5)
    assert calls["tile_n"] == 5                        # caller wins


def test_calibration_feeds_kernel_rankers(monkeypatch):
    """The built-in xtx/countmin rankers read tuned tile sizes from the
    ACTIVE calibration (no calibration -> plain True)."""
    from repro.core.calibration import Calibration, use
    entry = registry.get("xtx")
    x = jnp.zeros((64, 3), jnp.float32)
    y = jnp.zeros((64,), jnp.float32)
    assert entry.supports(x, y) is True
    cal = Calibration(backend="tpu", timestamp="t", engines={},
                      kernels={"xtx": {"tile_n": 256}}, grouped_block=[])
    with use(cal):
        assert entry.supports(x, y) == {"tile_n": 256}
        monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
        assert entry.resolve("auto", x, y) == ("pallas", {"tile_n": 256})


# -- trace recording + engine integration -------------------------------------

def _table(n=160, seed=3):
    rng = np.random.default_rng(seed)
    return Table.from_columns({
        "x": jnp.asarray((rng.integers(-8, 8, (n, 3)) / 4).astype(np.float32)),
        "y": jnp.asarray((rng.integers(-8, 8, (n,)) / 4).astype(np.float32)),
        "item": jnp.asarray(rng.integers(0, 99, n).astype(np.int32)),
        "g": jnp.asarray((np.arange(n) % G).astype(np.int32)),
    })


def test_grouped_execution_records_resolved_kernel():
    tbl = _table()
    with trace_execution() as t:
        run_grouped(LinregrAggregate(use_kernel=True), tbl, "g", G)
    assert len(t.kernels) == 1
    ev = t.kernels[0]
    assert ev.detail["name"] == "segment_linregr"
    assert ev.detail["requested"] == "auto"
    expect = "pallas" if jax.default_backend() == "tpu" else "ref"
    assert ev.engine == expect
    # no kernel requested -> no kernel event
    with trace_execution() as t:
        run_grouped(LinregrAggregate(), tbl, "g", G)
    assert t.kernels == []


def test_forced_ref_records_and_runs():
    tbl = _table()
    with trace_execution() as t:
        run_grouped(CountMinAggregate(4, 128, use_kernel="ref"),
                    tbl, "g", G)
    assert [(e.engine, e.detail["requested"]) for e in t.kernels] \
        == [("ref", "ref")]


def test_single_member_fused_forwards_kernel_hook():
    one = FusedAggregate([CountMinAggregate(4, 128, use_kernel="ref")])
    assert one.segment_kernel == "segment_countmin"
    assert one.kernel_impl == "ref"
    assert one.cost_class == "sketch"
    many = FusedAggregate([CountMinAggregate(4, 128, use_kernel="ref"),
                           FMAggregate(4, 16)])
    assert many.segment_kernel is None
    assert many.kernel_impl is None
    assert many.cost_class == "generic"


def test_planned_single_grouped_statement_uses_kernel():
    """Through the FULL plan layer (GroupedScanAgg -> single-member
    fusion -> run_grouped) the kernel hook must survive projection and
    fusion wrappers, and the result must stay bit-identical."""
    from repro.core import GroupedScanAgg, execute
    tbl = _table()
    base = execute(GroupedScanAgg(CountMinAggregate(4, 128), tbl, "g", G,
                                  columns=("item",)))
    with trace_execution() as t:
        got = execute(GroupedScanAgg(
            CountMinAggregate(4, 128, use_kernel="ref"), tbl, "g", G,
            columns=("item",)))
    assert [e.detail["name"] for e in t.kernels] == ["segment_countmin"]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))
