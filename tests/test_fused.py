"""Shared-scan (FusedAggregate / run_many) correctness.

The contract: fusing N aggregates into one data pass changes the number
of table scans and NOTHING else — every member must produce exactly what
it produces when run alone, on every engine.  Sweeps all pairings of the
four heterogeneous aggregates (mixed-merge Profile, sum-merge CountMin,
max-merge FM, pytree-state Gradient) over the local, sharded-on-mesh1 and
grouped paths, plus the profile() single-pass acceptance check.
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ConvexProgram, FusedAggregate, GradientAggregate, ProfileAggregate,
    Table, run_grouped, run_local, run_many, run_sharded,
)
from repro.methods.sketches import CountMinAggregate, FMAggregate

N, D, GROUPS = 512, 3, 4


@pytest.fixture(scope="module")
def table(key):
    kx, ky, ki = jax.random.split(key, 3)
    return Table.from_columns({
        "x": jax.random.normal(kx, (N, D)),
        "y": jax.random.normal(ky, (N,)),
        "item": jax.random.randint(ki, (N,), 0, 100),
        "g": (jnp.arange(N) % GROUPS).astype(jnp.int32),
    })


_PROGRAM = ConvexProgram(
    loss=lambda p, block, mask: jnp.sum(
        (block["x"] @ p - block["y"]) ** 2 * mask))

AGG_FACTORIES = {
    "profile": lambda: ProfileAggregate(),
    "countmin": lambda: CountMinAggregate(depth=4, width=256,
                                          item_col="item"),
    "fm": lambda: FMAggregate(num_hashes=4, bits=16, item_col="item"),
    "gradient": lambda: GradientAggregate(_PROGRAM, jnp.zeros((D,))),
}
PAIRINGS = list(itertools.combinations(AGG_FACTORIES, 2))


def _assert_trees_equal(fused, solo, rtol=1e-6, atol=1e-6):
    la, lb = jax.tree.leaves(fused), jax.tree.leaves(solo)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("pair", PAIRINGS, ids=lambda p: "+".join(p))
def test_fused_matches_solo_local(table, pair):
    fused = run_many({name: AGG_FACTORIES[name]() for name in pair}, table,
                     block_size=128)
    for name in pair:
        solo = run_local(AGG_FACTORIES[name](), table, block_size=128)
        _assert_trees_equal(fused[name], solo)


@pytest.mark.parametrize("pair", PAIRINGS, ids=lambda p: "+".join(p))
def test_fused_matches_solo_sharded(table, pair, mesh1):
    dist = table.distribute(mesh1)
    fused = run_many({name: AGG_FACTORIES[name]() for name in pair}, dist,
                     block_size=128)
    for name in pair:
        solo = run_sharded(AGG_FACTORIES[name](), dist, block_size=128)
        _assert_trees_equal(fused[name], solo)


@pytest.mark.parametrize("pair", PAIRINGS, ids=lambda p: "+".join(p))
def test_fused_matches_solo_grouped(table, pair):
    fused = run_grouped(
        FusedAggregate({name: AGG_FACTORIES[name]() for name in pair}),
        table, "g", GROUPS)
    for name in pair:
        solo = run_grouped(AGG_FACTORIES[name](), table, "g", GROUPS)
        _assert_trees_equal(fused[name], solo)


def test_fused_stream_ragged_blocks(table):
    """Fused aggregates also compose with the out-of-core engine."""
    from repro.core import run_stream
    fused = FusedAggregate({"profile": ProfileAggregate(),
                            "fm": FMAggregate(item_col="item")})
    out = run_stream(fused, (dict(b.columns) for b in table.blocks(100)))
    # looser tolerance: the stream folds blockwise, so fp32 sums
    # accumulate in a different order than the one-shot transition
    _assert_trees_equal(out["profile"],
                        run_local(ProfileAggregate(), table),
                        rtol=1e-4, atol=1e-5)
    _assert_trees_equal(out["fm"],
                        run_local(FMAggregate(item_col="item"), table),
                        rtol=1e-4, atol=1e-5)


def test_run_many_sequence_returns_tuple(table):
    out = run_many([ProfileAggregate(), FMAggregate(item_col="item")], table)
    assert isinstance(out, tuple) and len(out) == 2
    assert float(out[0]["y"]["count"]) == N


def test_fused_all_four_at_once(table):
    fused = run_many({name: f() for name, f in AGG_FACTORIES.items()}, table)
    for name, factory in AGG_FACTORIES.items():
        _assert_trees_equal(fused[name], run_local(factory(), table))


def test_fused_empty_rejected():
    with pytest.raises(ValueError):
        FusedAggregate([])


def test_run_many_mask_on_sharded_table(table, mesh1):
    """Regression: run_many used to raise on mask= for distributed tables;
    the sharded engine now applies base filters at the fold level, and the
    result matches the local masked fold."""
    mask = jnp.arange(N) % 3 == 0
    sharded = run_many([ProfileAggregate()], table.distribute(mesh1),
                       mask=mask)
    local = run_many([ProfileAggregate()], table, mask=mask)
    _assert_trees_equal(sharded, local)
    assert float(sharded[0]["y"]["count"]) == float(mask.sum())


# -- the profile() acceptance criterion ---------------------------------------

def test_profile_distinct_counts_single_pass(key):
    """profile(distinct_counts=True) = ONE fused scan (trace-verified:
    the planner fuses the per-statement ScanAggs), same numbers as the
    sequential scan-per-aggregate baseline."""
    from repro.core import trace_execution
    from repro.methods import profile as profile_mod
    from repro.methods.sketches import fm_distinct_count

    cols = {
        "a": jax.random.normal(key, (4096,)),
        "b": jax.random.randint(jax.random.fold_in(key, 1), (4096,), 0, 300),
        "c": jax.random.randint(jax.random.fold_in(key, 2), (4096,), 0, 7),
    }
    tbl = Table.from_columns(cols)

    with trace_execution() as t:
        out = profile_mod.profile(tbl, distinct_counts=True)
    assert len(t.scans) == 1, (
        f"profile executed {len(t.scans)} data passes, wanted 1")

    # sequential oracle: separate scans, pre-refactor dataflow
    stats = run_local(ProfileAggregate(), tbl)
    for name in cols:
        for k in ("count", "mean", "std", "min", "max"):
            np.testing.assert_allclose(
                np.asarray(out[name][k]), np.asarray(stats[name][k]),
                rtol=1e-6, atol=1e-6)
    for name in ("b", "c"):
        solo = fm_distinct_count(Table.from_columns({"item": cols[name]}))
        np.testing.assert_allclose(np.asarray(out[name]["approx_distinct"]),
                                   np.asarray(solo), rtol=1e-6)
    assert "approx_distinct" not in out["a"]
