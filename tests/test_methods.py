"""MADlib method library behaviour tests (Table 1 + Table 2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Table, synthetic_classification_table, \
    synthetic_regression_table


@pytest.fixture(scope="module")
def keys(key):
    return jax.random.split(key, 12)


# -- linear regression (§4.1) ------------------------------------------------

def test_linregr_matches_numpy(key):
    from repro.methods.linregr import linregr
    tbl, b = synthetic_regression_table(key, 8192, 12)
    res = linregr(tbl, block_size=1024)
    x = np.asarray(tbl["x"], np.float64)
    y = np.asarray(tbl["y"], np.float64)
    ref, *_ = np.linalg.lstsq(x, y, rcond=None)
    np.testing.assert_allclose(np.asarray(res.coef), ref, rtol=1e-3, atol=1e-4)
    assert float(res.r2) > 0.99
    assert float(res.condition_no) >= 1.0
    assert np.all(np.asarray(res.p_values) <= 1.0)
    assert float(res.num_rows) == 8192


def test_linregr_sharded_equals_local(key, mesh1):
    from repro.methods.linregr import linregr
    tbl, _ = synthetic_regression_table(key, 4096, 8)
    local = linregr(tbl)
    sharded = linregr(tbl.distribute(mesh1), block_size=512)
    np.testing.assert_allclose(np.asarray(local.coef),
                               np.asarray(sharded.coef), rtol=1e-4, atol=1e-5)


# -- logistic regression (§4.2) ----------------------------------------------

def test_logregr_irls(key):
    from repro.methods.logregr import logregr
    tbl, b = synthetic_classification_table(key, 8192, 6)
    res = logregr(tbl, max_iters=25)
    assert res.converged
    assert res.n_iters < 15
    assert float(jnp.linalg.norm(res.coef - b)) < 0.3
    # Wald z-stats should flag all 6 true nonzero coefficients
    assert np.all(np.abs(np.asarray(res.z_stats)) > 2.0)


def test_logregr_sgd_agrees_with_irls(key):
    from repro.methods.logregr import logregr, logregr_sgd
    tbl, _ = synthetic_classification_table(key, 8192, 6)
    irls = logregr(tbl)
    w = logregr_sgd(tbl, epochs=10, stepsize=0.5, batch=128, key=key)
    cos = float(jnp.vdot(w, irls.coef)
                / (jnp.linalg.norm(w) * jnp.linalg.norm(irls.coef)))
    assert cos > 0.98


# -- k-means (§4.3) ----------------------------------------------------------

@pytest.fixture(scope="module")
def blobs(keys):
    centers = jnp.array([[0., 0.], [6., 6.], [0., 6.], [6., 0.]])
    assign = jax.random.randint(keys[0], (4000,), 0, 4)
    pts = centers[assign] + 0.4 * jax.random.normal(keys[1], (4000, 2))
    return Table.from_columns({"x": pts}), centers


def test_kmeans_recovers_blobs(blobs, keys):
    from repro.methods.kmeans import kmeans_fit
    tbl, centers = blobs
    res = kmeans_fit(tbl, 4, key=keys[2], max_iters=30)
    assert res.converged
    # each true center has a learned centroid within 0.5
    d = jnp.linalg.norm(res.centroids[:, None] - centers[None], axis=-1)
    assert float(jnp.max(jnp.min(d, axis=0))) < 0.5
    # SSE non-increasing across Lloyd rounds
    assert all(a >= b - 1e-3 for a, b in
               zip(res.sse_trace, res.sse_trace[1:]))


def test_kmeans_two_pass_equals_fused(blobs, keys):
    from repro.methods.kmeans import kmeans_fit
    tbl, _ = blobs
    seed = jax.random.normal(keys[3], (4, 2)) * 3.0
    a = kmeans_fit(tbl, 4, init_centroids=seed, max_iters=15,
                   variant="fused")
    b = kmeans_fit(tbl, 4, init_centroids=seed, max_iters=15,
                   variant="two_pass")
    np.testing.assert_allclose(np.asarray(a.centroids),
                               np.asarray(b.centroids), rtol=1e-4, atol=1e-4)


# -- naive bayes / svm / decision tree ---------------------------------------

@pytest.fixture(scope="module")
def two_class(keys):
    x0 = jax.random.normal(keys[4], (2000, 4)) + 1.5
    x1 = jax.random.normal(keys[5], (2000, 4)) - 1.5
    x = jnp.concatenate([x0, x1])
    y = jnp.concatenate([jnp.zeros(2000), jnp.ones(2000)])
    return Table.from_columns({"x": x, "y": y})


def test_naive_bayes(two_class):
    from repro.methods.naive_bayes import naive_bayes_fit, naive_bayes_predict
    model = naive_bayes_fit(two_class, 2, block_size=512)
    acc = float(jnp.mean(
        naive_bayes_predict(model, two_class["x"])
        == two_class["y"].astype(jnp.int32)))
    assert acc > 0.97
    np.testing.assert_allclose(np.asarray(model.mean[0]), 1.5, atol=0.2)
    np.testing.assert_allclose(np.asarray(model.mean[1]), -1.5, atol=0.2)


def test_svm(two_class, key):
    from repro.methods.svm import svm_fit, svm_predict
    w = svm_fit(two_class, epochs=5, stepsize=0.1, key=key)
    acc = float(jnp.mean(svm_predict(w, two_class["x"])
                         == two_class["y"].astype(jnp.int32)))
    assert acc > 0.97


def test_decision_tree_xor(keys):
    from repro.methods.decision_tree import decision_tree_fit, \
        decision_tree_predict
    x = jax.random.uniform(keys[6], (4000, 3))
    y = ((x[:, 0] > 0.5) ^ (x[:, 1] > 0.3)).astype(jnp.int32)
    tbl = Table.from_columns({"x": x, "y": y})
    tree = decision_tree_fit(tbl, num_classes=2, max_depth=3)
    acc = float(jnp.mean(decision_tree_predict(tree, x) == y))
    assert acc > 0.95  # xor needs depth 2 — checks real splits happen


# -- SVD / low-rank ----------------------------------------------------------

def test_svd_power_decaying_spectrum(keys):
    from repro.methods.svd import svd_power
    u = jnp.linalg.qr(jax.random.normal(keys[7], (512, 16)))[0]
    v = jnp.linalg.qr(jax.random.normal(keys[8], (16, 16)))[0]
    s_true = jnp.array([100., 50., 25., 12.] + [1.0] * 12)
    a = (u * s_true) @ v.T
    tbl = Table.from_columns({"a": a})
    s, vecs = svd_power(tbl, 4, n_iters=30, key=keys[9])
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_true[:4]),
                               rtol=1e-2)


def test_lowrank_sgd_learns(keys):
    from repro.methods.svd import lowrank_sgd
    nr, nc, rank = 64, 48, 3
    L0 = jax.random.normal(keys[10], (nr, rank))
    R0 = jax.random.normal(keys[11], (nc, rank))
    ii = jax.random.randint(keys[0], (6000,), 0, nr)
    jj = jax.random.randint(keys[1], (6000,), 0, nc)
    vv = jnp.sum(L0[ii] * R0[jj], -1)
    tbl = Table.from_columns({"i": ii.astype(jnp.float32),
                              "j": jj.astype(jnp.float32), "v": vv})
    params = lowrank_sgd(tbl, nr, nc, rank, key=keys[2])
    pred = jnp.sum(params["L"][ii] * params["R"][jj], -1)
    rmse = float(jnp.sqrt(jnp.mean((pred - vv) ** 2)))
    assert rmse < 0.5 * float(jnp.std(vv))


# -- LDA / association rules -------------------------------------------------

def test_lda_perplexity_decreases(keys):
    from repro.methods.lda import lda_fit
    V, K = 40, 3
    topics = jax.random.dirichlet(keys[3], jnp.full((V,), 0.05), (K,))
    docs = []
    for d in range(150):
        kd = jax.random.fold_in(keys[4], d)
        th = jax.random.dirichlet(kd, jnp.full((K,), 0.3))
        from repro.core.compat import random_multinomial
        docs.append(random_multinomial(jax.random.fold_in(kd, 1), 80,
                                       th @ topics))
    tbl = Table.from_columns({"counts": jnp.stack(docs)})
    learned, trace = lda_fit(tbl, K, V, max_iters=10, key=keys[5])
    assert trace[-1] < 0.6 * trace[0]
    np.testing.assert_allclose(np.asarray(jnp.sum(learned, -1)), 1.0,
                               rtol=1e-4)


def test_apriori_finds_planted_rule():
    from repro.methods.assoc_rules import apriori
    rng = np.random.default_rng(0)
    items = (rng.random((2000, 8)) < 0.15).astype(np.float32)
    items[:, 1] = np.maximum(items[:, 0], items[:, 1])
    tbl = Table.from_columns({"items": jnp.asarray(items)})
    res = apriori(tbl, min_support=0.05, min_confidence=0.6, max_len=2)
    assert any(r[0] == (0,) and r[1] == (1,) for r in res.rules)
    # support monotonicity: subsets at least as frequent
    for s, supp in res.supports.items():
        if len(s) == 2:
            assert supp <= res.supports[(s[0],)] + 1e-9
            assert supp <= res.supports[(s[1],)] + 1e-9


# -- sketches / quantiles ----------------------------------------------------

def test_countmin_overestimates_within_bound(key):
    from repro.methods.sketches import countmin_sketch, countmin_query
    items = jax.random.randint(key, (20000,), 0, 500)
    tbl = Table.from_columns({"item": items})
    sk = countmin_sketch(tbl, depth=4, width=2048, block_size=4096)
    est = np.asarray(countmin_query(sk, jnp.arange(500)))
    true = np.bincount(np.asarray(items), minlength=500)
    assert np.all(est >= true)                    # CM never underestimates
    assert np.mean(est - true) < 2 * 20000 / 2048  # ~2n/w error bound


def test_fm_distinct_count(key):
    from repro.methods.sketches import fm_distinct_count
    for true_n in (100, 500, 2000):
        items = jax.random.randint(key, (30000,), 0, true_n)
        tbl = Table.from_columns({"item": items})
        est = float(fm_distinct_count(tbl, block_size=8192))
        assert 0.4 * true_n < est < 2.5 * true_n


def test_quantiles_gaussian(key):
    from repro.methods.quantiles import quantiles
    tbl = Table.from_columns({"v": jax.random.normal(key, (50000,))})
    qs = np.asarray(quantiles(tbl, [0.1, 0.5, 0.9], block_size=8192))
    np.testing.assert_allclose(qs, [-1.2816, 0.0, 1.2816], atol=0.05)


# -- sparse vectors / array ops ----------------------------------------------

def test_rle_roundtrip_and_dots():
    from repro.methods.sparse_vector import (rle_decode, rle_dot_dense,
                                             rle_dot_rle, rle_encode)
    dense = jnp.asarray(
        np.repeat([0., 2., 0., 5., 0.], [100, 20, 50, 10, 60])
        .astype(np.float32))
    other = jnp.asarray(
        np.repeat([1., 0., 3.], [80, 100, 60]).astype(np.float32))
    v = rle_encode(dense, 16)
    w = rle_encode(other, 16)
    assert int(v.n_runs) == 5
    np.testing.assert_array_equal(np.asarray(rle_decode(v)),
                                  np.asarray(dense))
    ref = float(dense @ other)
    assert abs(float(rle_dot_dense(v, other)) - ref) < 1e-3
    assert abs(float(rle_dot_rle(v, w)) - ref) < 1e-3


def test_closest_column():
    from repro.methods.array_ops import closest_column
    m = jnp.array([[0., 0.], [10., 10.], [5., 0.]])
    idx, dist = closest_column(m, jnp.array([4.4, 0.2]))
    assert int(idx) == 2
    np.testing.assert_allclose(float(dist), np.hypot(0.6, 0.2), rtol=1e-5)


# -- Table-2 SGD registry ----------------------------------------------------

def test_sgd_registry_all_models_run(key):
    from repro.methods.sgd_models import REGISTRY, fit_sgd_model
    tbl, b = synthetic_regression_table(key, 2048, 6)
    for name in ("least_squares", "lasso"):
        w = fit_sgd_model(name, tbl, jnp.zeros(6), epochs=3, stepsize=0.05,
                          key=key)
        assert float(jnp.linalg.norm(w - b)) < 0.8
    assert set(REGISTRY) == {"least_squares", "lasso", "logistic", "svm",
                             "recommendation", "crf"}
