"""HLO call-graph analyzer unit tests (pure text fixtures + one real
lowering on a 1x1 mesh)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as HA
from repro.launch.scan_registry import clear_registry, get_registry, \
    tagged_scan

FIXTURE = """
HloModule test

%body.1 (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128]{1,0} get-tuple-element(%p), index=1
  %w = f32[128,128]{1,0} constant({...})
  %d = f32[8,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,128]{1,0} all-reduce(%d), to_apply=%add.1, metadata={op_name="x"}
  ROOT %t = (s32[], f32[8,128]{1,0}) tuple(%i, %ar)
}

%cond.1 (p: (s32[], f32[8,128])) -> pred[] {
  %p = (s32[], f32[8,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%add.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,128]) -> f32[8,128] {
  %arg = f32[8,128]{1,0} parameter(0)
  %c = s32[] constant(0)
  %t0 = (s32[], f32[8,128]{1,0}) tuple(%c, %arg)
  %wh = (s32[], f32[8,128]{1,0}) while(%t0), condition=%cond.1, body=%body.1, metadata={op_name="jit(f)/myscan_tag/while"}
  ROOT %out = f32[8,128]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_fixture_flops_and_collectives():
    res = HA.analyze(FIXTURE, {"myscan_tag": 5})
    # dot: 2*8*128*128 flops, x5 iterations
    assert res["dot_flops"] == pytest.approx(2 * 8 * 128 * 128 * 5)
    # all-reduce: 8*128*4 bytes output, wire = 2x, x5
    assert res["collective_wire_bytes"]["all-reduce"] == \
        pytest.approx(2 * 8 * 128 * 4 * 5)
    assert res["unknown_whiles"] == []


def test_fixture_unknown_while_counts_once():
    res = HA.analyze(FIXTURE, {"not_matching": 9})
    assert res["dot_flops"] == pytest.approx(2 * 8 * 128 * 128)
    assert len(res["unknown_whiles"]) == 1


def test_shape_bytes_tuple_with_index_comments():
    txt = "(s32[], f32[32,64]{1,0}, /*index=5*/bf16[7,2]{1,0})"
    assert HA.shape_bytes(txt) == 4 + 32 * 64 * 4 + 7 * 2 * 2


def test_real_lowering_matches_hand_count(key):
    """End-to-end: tagged scan over 6 matmul layers, 1-device mesh."""
    clear_registry()

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        out, _ = tagged_scan("tagscan_layers_fwd", body, x, w, length=6)
        return out.sum()

    fn = jax.jit(jax.grad(f, argnums=1))
    xs = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    compiled = fn.lower(xs, ws).compile()
    res = HA.analyze(compiled.as_text(), get_registry())
    # fwd dot + 2 bwd dots per layer, 6 layers
    expected = 3 * 2 * 16 * 64 * 64 * 6
    assert res["dot_flops"] == pytest.approx(expected, rel=0.35)
    from repro.core.compat import cost_analysis
    assert res["dot_flops"] > cost_analysis(compiled).get("flops", 0.0)
    assert res["unknown_whiles"] == []


def test_scan_registry_length_qualified():
    """Same tag at two lengths registers two distinct qualified entries
    (no cross-trace corruption)."""
    clear_registry()

    def body(c, x):
        return c + x, None

    tagged_scan("tagscan_test_a", body, jnp.zeros(()), jnp.ones(4),
                length=4)
    tagged_scan("tagscan_test_a", body, jnp.zeros(()), jnp.ones(5),
                length=5)
    reg = get_registry()
    assert reg["tagscan_test_a_L4"] == 4
    assert reg["tagscan_test_a_L5"] == 5
    clear_registry()
