"""Device-side sort-merge join: star-schema GROUP BY as one shared-sort
segment scan.

The contract under test (``core/join.py`` + the ``JoinedGroupedScanAgg``
plan node):

* **Resolution correctness** — ``Join.resolve()`` maps every fact
  foreign key to its dimension row's attribute via searchsorted against
  the memoized dimension key sort; the gid column is bit-identical to a
  numpy dict-lookup oracle, over every generated join layout
  (``tests/strategies.py``: clean / dangling / skewed fan-out /
  duplicate attributes).
* **Never materialized** — the joined table carries exactly ONE new
  column (the int32 gid); dimension payloads are never gathered onto
  fact rows.
* **Loud edges** — duplicate dimension keys raise (an equi-join against
  a non-key column is a silent-wrong-answer bug, not a feature);
  ``on_missing="error"`` raises on dangling keys with a count;
  ``"drop"`` excludes exactly the dangling rows (gid ``-1`` falls
  outside every segment by the grouped core's contract); an empty
  dimension errors unless dropping.
* **Shared sort + one pass** — a batch of joined statements over the
  same star triple fuses into ONE physical pass whose explain shows one
  shared sort; re-running hits both the resolution memo and the
  ``group_by`` memo: zero sorts, zero joins.  Mutating either side
  (fact append, dim invalidate) forces re-resolution.
* **Caching soundness** — ``semantic_fingerprint`` returns ``None`` for
  any multi-table statement with a loud ``cache_reject`` trace event,
  so the server result cache (keyed on the FACT table's version only)
  can never serve a stale join after only the dimension mutated.
* **Sharded parity** — dimension sort products replicate; fact blocks
  stay row-sharded; results bit-identical to the local path.

Everything asserts trace counts and bitwise equality — never timing.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    AnalyticsServer, Join, JoinedGroupedScanAgg, Session, Table, execute,
    explain, run_grouped, trace_execution,
)
from repro.core.join import JOIN_GID_COL
from repro.core.plan import node_tables, semantic_fingerprint
from repro.methods.linregr import LinregrAggregate, linregr_joined
from repro.methods.sketches import CountMinAggregate

from strategies import Draw, cases, join_layout

N_FACT, N_DIM, G = 192, 12, 4


def _star(draw: Draw, pattern: str):
    """(fact, dim, fk_np, dim_keys, dim_attr) for one join layout."""
    fk, keys, attr, _ = join_layout(draw, N_FACT, N_DIM, G, pattern)
    fact = Table.from_columns({"x": draw.dyadic((N_FACT, 3)),
                               "y": draw.dyadic((N_FACT,)), "fk": fk})
    dim = Table.from_columns({"key": keys, "region": attr})
    return fact, dim, fk, keys, attr


def _oracle_gids(fk, keys, attr):
    m = {int(k): int(a) for k, a in zip(keys, attr)}
    return np.array([m.get(int(f), -1) for f in fk], np.int32)


def _join_node(fact, dim, agg=None, **kw):
    return JoinedGroupedScanAgg(
        agg or LinregrAggregate(), Join(fact, dim, "fk", "key", "region",
                                        on_missing=kw.pop("on_missing",
                                                          "error")),
        columns={"x": "x", "y": "y"}, **kw)


def _materialized_oracle(fact, gids_np, groups):
    tbl = Table.from_columns({"x": fact["x"], "y": fact["y"],
                              "g": jnp.asarray(gids_np)})
    return run_grouped(LinregrAggregate(), tbl, "g", groups)


# -- resolution correctness ---------------------------------------------------

@pytest.mark.parametrize("pattern", ("clean", "skewed", "dup_attr"))
def test_resolution_matches_numpy_oracle(pattern):
    for draw in cases(4, base_seed=11):
        fact, dim, fk, keys, attr = _star(draw, pattern)
        res = Join(fact, dim, "fk", "key", "region").resolve()
        want = _oracle_gids(fk, keys, attr)
        np.testing.assert_array_equal(
            np.asarray(res.table[JOIN_GID_COL]), want,
            err_msg=f"{pattern} {draw}")
        assert res.dangling == 0
        assert res.num_groups == int(attr.max()) + 1
        # never materialized: exactly one new column, no dim payloads
        assert set(res.table.columns) == set(fact.columns) | {JOIN_GID_COL}


@pytest.mark.parametrize("pattern", ("clean", "skewed", "dup_attr"))
def test_joined_grouped_bit_identical_to_materialized(pattern):
    for draw in cases(3, base_seed=23):
        fact, dim, fk, keys, attr = _star(draw, pattern)
        got = execute(_join_node(fact, dim))
        want = _materialized_oracle(fact, _oracle_gids(fk, keys, attr),
                                    int(attr.max()) + 1)
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                          err_msg=f"{pattern} {draw}")


# -- loud edges ---------------------------------------------------------------

def test_dangling_error_raises_with_count():
    draw = Draw(41)
    fact, dim, fk, keys, attr = _star(draw, "dangling")
    n_bad = int((_oracle_gids(fk, keys, attr) == -1).sum())
    with pytest.raises(ValueError, match=f"{n_bad} of {N_FACT}"):
        execute(_join_node(fact, dim))


def test_dangling_drop_excludes_exactly_the_dangling_rows():
    for draw in cases(3, base_seed=43):
        fact, dim, fk, keys, attr = _star(draw, "dangling")
        got = execute(_join_node(fact, dim, on_missing="drop"))
        want = _materialized_oracle(fact, _oracle_gids(fk, keys, attr),
                                    int(attr.max()) + 1)
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                          err_msg=f"{draw}")


def test_duplicate_dim_keys_rejected_loudly():
    draw = Draw(47)
    fact, dim, *_ = _star(draw, "dup_keys")
    with pytest.raises(ValueError, match="duplicate keys"):
        Join(fact, dim, "fk", "key", "region").resolve()


def test_empty_dim():
    draw = Draw(53)
    fact, dim, *_ = _star(draw, "empty_dim")
    with pytest.raises(ValueError, match="empty dimension"):
        Join(fact, dim, "fk", "key", "region").resolve()
    res = Join(fact, dim, "fk", "key", "region",
               on_missing="drop").resolve()
    assert res.num_groups == 0 and res.dangling == N_FACT


def test_bad_spec_rejected_eagerly():
    draw = Draw(59)
    fact, dim, *_ = _star(draw, "clean")
    with pytest.raises(ValueError, match="on_missing"):
        Join(fact, dim, "fk", "key", "region", on_missing="ignore")
    with pytest.raises(KeyError):
        Join(fact, dim, "nope", "key", "region")
    with pytest.raises(KeyError):
        Join(fact, dim, "fk", "key", "nope")


# -- fusion, memo sharing, explain --------------------------------------------

def test_joined_batch_one_pass_one_shared_sort():
    draw = Draw(61)
    fact, dim, fk, keys, attr = _star(draw, "clean")
    sess = Session()
    h_lr = sess.joined_grouped_scan(
        LinregrAggregate(), Join(fact, dim, "fk", "key", "region"),
        columns={"x": "x", "y": "y"})
    h_cm = sess.joined_grouped_scan(
        CountMinAggregate(4, 64, item_col="fk"),
        Join(fact, dim, "fk", "key", "region"), columns=("fk",))
    text = sess.explain()
    assert "1 pass, 1 sort" in text
    assert "JOIN" in text and "on fk=key" in text
    assert "join-grouped-scan" in text
    assert "sort-share" in text and "gather-materialize" in text
    with trace_execution() as t:
        sess.run()
    assert len(t.scans) == 1, "compatible joined statements must fuse"
    assert len(t.joins) == 1, "one key resolution for the whole batch"
    # 2 sorts total: the dim key sort + the joined table's partition
    # sort — each paid ONCE for the batch, not once per statement
    assert len(t.sorts) == 2
    groups = int(attr.max()) + 1
    want = _materialized_oracle(fact, _oracle_gids(fk, keys, attr), groups)
    for g, w in zip(jax.tree.leaves(h_lr.result()), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    assert h_cm.result().shape[0] == groups

    # re-run: resolution memo + group_by memo both hit across plans
    with trace_execution() as t:
        execute(_join_node(fact, dim))
    assert len(t.sorts) == 0 and len(t.joins) == 0

    # sorts_by_table rollup: the dim sort is attributed to the dim table
    with trace_execution() as t:
        dim.invalidate()
        execute(_join_node(fact, dim))
    by = t.summary()["sorts_by_table"]
    assert by.get(id(dim)) == 1


def test_mutation_forces_reresolution():
    draw = Draw(67)
    fact, dim, fk, keys, attr = _star(draw, "clean")
    execute(_join_node(fact, dim))
    # dim invalidate: the memoized resolution is version-stale
    dim.invalidate()
    with trace_execution() as t:
        execute(_join_node(fact, dim))
    assert len(t.joins) == 1 and len(t.sorts) == 2
    # fact append: new rows need fresh gids
    m = {int(k): int(a) for k, a in zip(keys, attr)}
    extra = Draw(68)
    fk2 = keys[extra.rng.integers(0, N_DIM, 64)].astype(np.int32)
    fact.append({"x": extra.dyadic((64, 3)), "y": extra.dyadic((64,)),
                 "fk": fk2})
    with trace_execution() as t:
        got = execute(_join_node(fact, dim))
    assert len(t.joins) == 1
    all_fk = np.asarray(fact["fk"])
    want = _materialized_oracle(
        fact, np.array([m[int(f)] for f in all_fk], np.int32),
        int(attr.max()) + 1)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_method_wrapper_and_explain_solo():
    draw = Draw(71)
    fact, dim, fk, keys, attr = _star(draw, "clean")
    got = linregr_joined(fact, dim, fact_key="fk", dim_key="key",
                         attr_col="region")
    want = _materialized_oracle(fact, _oracle_gids(fk, keys, attr),
                                int(attr.max()) + 1)
    np.testing.assert_array_equal(np.asarray(got.coef),
                                  np.asarray(want.coef))
    text = explain(_join_node(fact, dim))
    assert "JOIN" in text and "groups=" in text


# -- caching soundness --------------------------------------------------------

def test_semantic_fingerprint_rejects_multi_table():
    draw = Draw(73)
    fact, dim, *_ = _star(draw, "clean")
    node = _join_node(fact, dim)
    assert node_tables(node) == (fact, dim)
    with trace_execution() as t:
        assert semantic_fingerprint(node) is None
    (ev,) = t.cache_rejects
    assert ev.detail["reason"] == "multi-table"
    assert ev.detail["tables"] == (id(fact), id(dim))


def test_server_never_serves_stale_join_after_dim_mutation():
    """Regression for the PR-8 result cache: the cache key carries only
    the FACT table's version, so a joined statement must never be
    cached — otherwise mutating only the dimension would leave the key
    intact and replay the pre-mutation answer."""
    draw = Draw(79)
    fact, dim, fk, keys, attr = _star(draw, "clean")
    srv = AnalyticsServer(window_size=1)
    try:
        sess = Session(server=srv)
        h1 = sess.joined_grouped_scan(
            LinregrAggregate(), Join(fact, dim, "fk", "key", "region"),
            columns={"x": "x", "y": "y"})
        h1.result()
        # mutate ONLY the dimension: remap every attribute
        new_attr = ((attr + 1) % G).astype(np.int32)
        dim.columns["region"] = jnp.asarray(new_attr)
        dim.invalidate()
        with trace_execution() as t:
            h2 = sess.joined_grouped_scan(
                LinregrAggregate(), Join(fact, dim, "fk", "key", "region"),
                columns={"x": "x", "y": "y"})
            got = h2.result()
        assert len(t.cache_hits) == 0 and len(t.scans) == 1
        assert srv.stats["cache_hits"] == 0
        want = _materialized_oracle(fact, _oracle_gids(fk, keys, new_attr),
                                    int(attr.max()) + 1)
        np.testing.assert_array_equal(np.asarray(got.coef),
                                      np.asarray(want.coef))
    finally:
        srv.close()


# -- sharded path -------------------------------------------------------------

def test_sharded_join_bit_identical_to_local(mesh1):
    for draw in cases(3, base_seed=83):
        fact, dim, fk, keys, attr = _star(draw, "skewed")
        base = execute(_join_node(fact, dim))
        fact_d = fact.distribute(mesh1)
        with trace_execution() as t:
            got = execute(_join_node(fact_d, dim, mesh=mesh1))
        assert len(t.joins) == 1
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(base)):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                          err_msg=f"{draw}")
