"""Launch-layer integration: train -> checkpoint -> resume continuity,
and the serve driver, through the real drivers in repro.launch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_train_checkpoint_resume(tmp_path):
    from repro.launch.train import train
    d = str(tmp_path / "ckpt")
    # run 1: 12 steps, checkpoint every 5
    losses1 = train("stablelm-1.6b", steps=12, batch=2, seq=32,
                    ckpt_dir=d, ckpt_every=5, base_lr=1e-3,
                    profile_data=False, log_every=100)
    assert len(losses1) == 12
    # run 2: resume from the final checkpoint, 6 more steps
    losses2 = train("stablelm-1.6b", steps=18, batch=2, seq=32,
                    ckpt_dir=d, resume=True, base_lr=1e-3,
                    profile_data=False, log_every=100)
    assert 0 < len(losses2) <= 6
    assert np.isfinite(losses1 + losses2).all()
    # resumed losses continue from trained state, not from scratch
    assert losses2[0] < losses1[0]


def test_serve_driver_runs():
    from repro.launch.serve import serve
    gen = serve("xlstm-350m", batch=2, prompt_len=4, gen_len=6,
                reduced=True)
    assert gen.shape == (2, 6)
    assert bool(jnp.all((gen >= 0) & (gen < 512)))


def test_serve_rejects_encoder_only():
    from repro.launch.serve import serve
    with pytest.raises(ValueError):
        serve("hubert-xlarge", batch=1, prompt_len=2, gen_len=2)
