"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests see 1 device by
design (the 512-device forcing lives only at the top of launch/dryrun.py)."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.compat import make_mesh


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def mesh1():
    """A 1-device data mesh (the sharded code paths, minus real parallelism)."""
    return make_mesh((1,), ("data",))
