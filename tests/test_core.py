"""Core UDA / driver / convex behaviour tests (single device)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Aggregate, ConvexProgram, ProfileAggregate, Table,
    conjugate_gradient, counted_driver, device_driver, gradient_descent,
    host_driver, newton, relative_change, run_grouped, run_local,
    run_sharded, run_stream, sgd, synthetic_classification_table,
    synthetic_regression_table,
)


class LinregrAgg(Aggregate):
    def init(self, block):
        d = block["x"].shape[-1]
        return {"xtx": jnp.zeros((d, d)), "xty": jnp.zeros((d,)),
                "n": jnp.zeros(())}

    def transition(self, state, block, mask):
        x = block["x"] * mask[:, None]
        y = block["y"] * mask
        return {"xtx": state["xtx"] + x.T @ x,
                "xty": state["xty"] + x.T @ y,
                "n": state["n"] + mask.sum()}

    def final(self, s):
        return jnp.linalg.solve(
            s["xtx"] + 1e-6 * jnp.eye(s["xtx"].shape[0]), s["xty"])


@pytest.fixture(scope="module")
def regr(key):
    return synthetic_regression_table(key, 4096, 8)


def test_table_basic(regr):
    tbl, _ = regr
    assert tbl.n_rows == 4096
    assert tbl.column_names == ("x", "y")
    t2, mask = tbl.pad_to(5000)
    assert t2.n_rows == 5000 and int(mask.sum()) == 4096
    assert tbl.select("x").column_names == ("x",)


def test_table_ragged_rejected():
    with pytest.raises(ValueError):
        Table.from_columns({"a": jnp.zeros((4,)), "b": jnp.zeros((5,))})


def test_uda_local_matches_closed_form(regr):
    tbl, b = regr
    coef = run_local(LinregrAgg(), tbl, block_size=256)
    x, y = tbl["x"], tbl["y"]
    ref = jnp.linalg.solve(x.T @ x + 1e-6 * jnp.eye(8), x.T @ y)
    np.testing.assert_allclose(np.asarray(coef), np.asarray(ref), rtol=1e-4)
    assert float(jnp.linalg.norm(coef - b)) < 0.05


def test_uda_blocking_invariance(regr):
    """Associativity contract: result independent of block partitioning."""
    tbl, _ = regr
    outs = [run_local(LinregrAgg(), tbl, block_size=bs)
            for bs in (None, 64, 100, 1000, 4096)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-4, atol=1e-5)


def test_uda_stream_matches_local(regr):
    tbl, _ = regr
    local = run_local(LinregrAgg(), tbl)
    stream = run_stream(
        LinregrAgg(),
        ({k: v[s:s + 512] for k, v in tbl.columns.items()}
         for s in range(0, 4096, 512)))
    np.testing.assert_allclose(np.asarray(local), np.asarray(stream),
                               rtol=2e-4, atol=1e-5)


def test_uda_sharded_1dev(regr, mesh1):
    tbl, _ = regr
    local = run_local(LinregrAgg(), tbl)
    sharded = run_sharded(LinregrAgg(), tbl.distribute(mesh1), block_size=512)
    np.testing.assert_allclose(np.asarray(local), np.asarray(sharded),
                               rtol=2e-4, atol=1e-5)


def test_uda_grouped(regr):
    tbl, b = regr
    g = (jnp.arange(4096) % 4).astype(jnp.int32)
    tg = tbl.with_column("g", g)
    coefs = run_grouped(LinregrAgg(), tg, "g", 4)
    assert coefs.shape == (4, 8)
    # every group estimates the same b
    for i in range(4):
        assert float(jnp.linalg.norm(coefs[i] - b)) < 0.12


def test_profile_mixed_merges(regr, mesh1):
    tbl, _ = regr
    local = run_local(ProfileAggregate(), tbl)
    sharded = run_sharded(ProfileAggregate(), tbl.distribute(mesh1),
                          block_size=512)
    for col in ("x", "y"):
        for k in ("count", "mean", "std", "min", "max"):
            np.testing.assert_allclose(
                np.asarray(local[col][k]), np.asarray(sharded[col][k]),
                rtol=1e-4, atol=1e-5)
    assert float(local["y"]["count"]) == 4096.0


def test_newton_logistic(key):
    tbl, b = synthetic_classification_table(key, 8192, 6)

    def logloss(params, block, mask):
        z = block["x"] @ params
        ll = jnp.where(block["y"] > 0.5, jax.nn.softplus(-z),
                       jax.nn.softplus(z))
        return jnp.sum(ll * mask)

    prog = ConvexProgram(loss=logloss)
    params, trace, conv = newton(prog, tbl, jnp.zeros(6), max_iters=30,
                                 tol=1e-6)
    assert conv
    assert float(jnp.linalg.norm(params - b)) < 0.3
    # loss monotone decreasing (convexity + Newton)
    losses = [t[0] for t in trace]
    assert losses == sorted(losses, reverse=True)


def test_sgd_decreases_loss(key):
    tbl, b = synthetic_classification_table(key, 4096, 6)

    def logloss(params, block, mask):
        z = block["x"] @ params
        ll = jnp.where(block["y"] > 0.5, jax.nn.softplus(-z),
                       jax.nn.softplus(z))
        return jnp.sum(ll * mask)

    prog = ConvexProgram(loss=logloss)
    mask = jnp.ones((4096,), jnp.bool_)
    l0 = float(logloss(jnp.zeros(6), tbl.columns, mask))
    p = sgd(prog, tbl, jnp.zeros(6), stepsize=0.5, epochs=3, batch=128,
            key=key)
    l1 = float(logloss(p, tbl.columns, mask))
    # judge against the attainable optimum, not a fixed fraction of l0
    # (the dataset's Bayes loss depends on the RNG draw): SGD must close
    # >= 95% of the gap between the zero-params loss and Newton's optimum.
    popt, _, _ = newton(prog, tbl, jnp.zeros(6), max_iters=30, tol=1e-8)
    lopt = float(logloss(popt, tbl.columns, mask))
    assert (l0 - l1) > 0.95 * (l0 - lopt), (l0, l1, lopt)


def test_conjugate_gradient(key):
    a = jax.random.normal(key, (32, 32))
    a = a @ a.T + 32 * jnp.eye(32)
    b = jax.random.normal(key, (32,))
    x, res, iters = conjugate_gradient(lambda v: a @ v, b, tol=1e-10)
    np.testing.assert_allclose(np.asarray(a @ x), np.asarray(b), atol=1e-4)
    assert int(iters) <= 64


def test_host_and_device_driver_agree():
    def step(s):
        return {"x": 0.5 * s["x"] + 1.0}  # fixpoint x = 2

    init = {"x": jnp.zeros(3)}
    r_host = host_driver(step, init, metric=relative_change, tol=1e-6,
                         max_iters=100)
    r_dev = device_driver(step, init, metric=relative_change, tol=1e-6,
                          max_iters=100)
    assert r_host.converged and r_dev.converged
    np.testing.assert_allclose(np.asarray(r_host.state["x"]), 2.0, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(r_dev.state["x"]), 2.0, rtol=1e-4)
    assert abs(r_host.n_iters - r_dev.n_iters) <= 1


def test_counted_driver():
    out = counted_driver(lambda s: s + 1.0, jnp.zeros(()), 17)
    assert float(out) == 17.0


# -- engine edge cases --------------------------------------------------------

def test_grouped_empty_group(regr):
    """A group id with no rows must yield an empty-state result, not NaNs."""
    tbl, _ = regr
    g = (jnp.arange(4096) % 4).astype(jnp.int32)
    g = jnp.where(g == 2, 3, g)          # group 2 has zero rows
    out = run_grouped(ProfileAggregate(), tbl.with_column("g", g), "g", 4)
    counts = np.asarray(out["y"]["count"])
    np.testing.assert_array_equal(counts, [1024.0, 1024.0, 0.0, 2048.0])
    assert np.all(np.isfinite(np.asarray(out["y"]["mean"])))
    assert np.all(np.isfinite(np.asarray(out["y"]["std"])))


def test_grouped_non_contiguous_ids(regr):
    """Sparse/non-contiguous group ids: untouched slots stay empty."""
    tbl, _ = regr
    ids = jnp.asarray([0, 3, 7], jnp.int32)
    g = ids[jnp.arange(4096) % 3]
    out = run_grouped(ProfileAggregate(), tbl.with_column("g", g), "g", 8)
    counts = np.asarray(out["y"]["count"])
    expect = np.zeros(8)
    expect[[0, 3, 7]] = np.bincount(np.arange(4096) % 3)
    np.testing.assert_array_equal(counts, expect)
    # per-group sums add up to the ungrouped total
    total = run_local(ProfileAggregate(), tbl)["y"]["sum"]
    np.testing.assert_allclose(np.asarray(out["y"]["sum"]).sum(),
                               np.asarray(total), rtol=1e-5)


def test_stream_single_block(regr):
    tbl, _ = regr
    local = run_local(LinregrAgg(), tbl)
    stream = run_stream(LinregrAgg(), iter([dict(tbl.columns)]))
    np.testing.assert_allclose(np.asarray(local), np.asarray(stream),
                               rtol=2e-4, atol=1e-5)


def test_stream_non_divisible_blocks(regr):
    """4096 rows in 600-row blocks: the ragged 496-row tail must fold in."""
    tbl, _ = regr
    local = run_local(LinregrAgg(), tbl)
    stream = run_stream(LinregrAgg(),
                        (dict(b.columns) for b in tbl.blocks(600)))
    np.testing.assert_allclose(np.asarray(local), np.asarray(stream),
                               rtol=2e-4, atol=1e-5)


def test_local_all_false_mask(regr):
    """An all-masked input is an empty table: zero counts, finite stats."""
    tbl, _ = regr
    mask = jnp.zeros((4096,), jnp.bool_)
    out = run_local(ProfileAggregate(), tbl, mask=mask)
    for col in ("x", "y"):
        assert float(out[col]["count"]) == 0.0
        assert np.all(np.asarray(out[col]["sum"]) == 0.0)
        assert np.all(np.isfinite(np.asarray(out[col]["mean"])))
        assert np.all(np.isfinite(np.asarray(out[col]["std"])))
    lin = run_local(LinregrAgg(), tbl, mask=mask)
    assert np.all(np.isfinite(np.asarray(lin)))
