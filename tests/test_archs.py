"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, cells, get_config, input_specs, \
    reduced_config
from repro.models import model as M


def _smoke_batch(cfg, key, b=2, s=16):
    batch = {}
    if cfg.family == "audio":
        batch["embeddings"] = jax.random.normal(key, (b, s, cfg.d_model))
        batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
        batch["mask"] = jnp.ones((b, s), jnp.float32)
    elif cfg.family == "vlm":
        s_vis, s_txt = 4, s - 4
        batch["tokens"] = jax.random.randint(key, (b, s_txt), 0, cfg.vocab)
        batch["embeddings"] = jax.random.normal(key, (b, s_vis, cfg.d_model))
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, None], (3, b, s)).astype(jnp.int32)
        batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
        batch["mask"] = jnp.concatenate(
            [jnp.zeros((b, s_vis)), jnp.ones((b, s_txt))], 1)
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
        batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
        batch["mask"] = jnp.ones((b, s), jnp.float32)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch, key):
    cfg = reduced_config(arch)
    params, axes = M.init_model(cfg, key)
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda t: isinstance(t, tuple))
    batch = _smoke_batch(cfg, key)

    logits, aux = M.forward(params, cfg, batch.get("tokens"),
                            embeddings=batch.get("embeddings"),
                            mrope_positions=batch.get("mrope_positions"))
    b, s = batch["labels"].shape
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    # one SGD step must produce finite params and reduce loss on the batch
    loss0, _ = M.train_loss(params, cfg, batch)
    g = jax.grad(lambda p: M.train_loss(p, cfg, batch)[0])(params)
    params2 = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
    loss1, _ = M.train_loss(params2, cfg, batch)
    assert np.isfinite(float(loss0)) and np.isfinite(float(loss1))
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if a != "hubert-xlarge"])
def test_arch_smoke_decode_step(arch, key):
    cfg = reduced_config(arch)
    params, _ = M.init_model(cfg, key)
    b = 2
    state = M.init_decode_state(cfg, b, 32)
    tok = jax.random.randint(key, (b, 1), 0, cfg.vocab)
    logits, new_state = M.decode_step(params, cfg, state, tok,
                                      jnp.zeros((b,), jnp.int32))
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    assert jax.tree.structure(state) == jax.tree.structure(new_state)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3-8b", "recurrentgemma-2b",
                                  "xlstm-350m", "stablelm-1.6b"])
def test_decode_matches_forward(arch, key):
    """Teacher-forced decode must reproduce the forward logits."""
    cfg = reduced_config(arch)
    params, _ = M.init_model(cfg, key)
    b, s = 2, 12
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    fwd, _ = M.forward(params, cfg, toks)
    state = M.init_decode_state(cfg, b, 16)
    outs = []
    for t in range(s):
        lg, state = M.decode_step(params, cfg, state, toks[:, t:t + 1],
                                  jnp.full((b,), t, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(fwd), np.asarray(dec), rtol=2e-3,
                               atol=2e-4)


def test_full_configs_match_assignment():
    """Pin the exact assigned hyperparameters."""
    expect = {
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840, 64, 6),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352, 16, 4),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936, 0, 0),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064, 0, 0),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936, 0, 0),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352, 0, 0),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504, 0, 0),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000, 0, 0),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936, 0, 0),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304, 0, 0),
    }
    for arch, (L, d, h, kv, ff, v, e, k) in expect.items():
        cfg = get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab, cfg.n_experts, cfg.top_k)
        assert got == (L, d, h, kv, ff, v, e, k), (arch, got)
    assert get_config("qwen3-8b").qk_norm
    assert get_config("qwen2-vl-2b").mrope
    assert not get_config("hubert-xlarge").causal
    assert get_config("recurrentgemma-2b").block_pattern == \
        ("rglru", "rglru", "local")


def test_cell_accounting():
    """31 runnable cells + 9 documented skips = 40."""
    all_cells = list(cells(include_skipped=True))
    assert len(all_cells) == 40
    runnable = [c for c in all_cells if c[2]]
    skipped = [c for c in all_cells if not c[2]]
    assert len(runnable) == 31
    assert len(skipped) == 9
    for arch, shape, ok, why in skipped:
        assert why != ""


def test_input_specs_no_allocation():
    for arch, shape, ok, _ in cells():
        spec = input_specs(arch, shape)
        for leaf in jax.tree.leaves(spec):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
