"""Unified iterative executor: oracle equivalence + grouped/stream engines.

The refactor contract: porting a method onto ``repro.core.iterative``
changes HOW the loop executes (compiled while_loop, engines), never WHAT
it computes — so every test here compares against either the hand-rolled
pre-refactor dataflow or a solo per-group fit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ConvexProgram, Table, fit, fit_grouped, fit_stream,
    synthetic_classification_table, synthetic_regression_table,
)
from repro.core.aggregates import run_local, run_sharded
from repro.methods.logregr import (
    IRLSAggregate, IRLSTask, logregr, logregr_grouped, logregr_stream,
)


@pytest.fixture(scope="module")
def cls_table(key):
    return synthetic_classification_table(key, 4096, 5)


def _oracle_irls(t, max_iters=30, tol=1e-6):
    """The pre-refactor hand-rolled IRLS loop, verbatim."""
    d = t["x"].shape[-1]
    beta = jnp.zeros((d,))
    converged = False
    state = None
    it = 0
    for it in range(1, max_iters + 1):
        state = run_local(IRLSAggregate(beta), t)
        new_beta = jnp.linalg.solve(state["xdx"] + 1e-8 * jnp.eye(d),
                                    state["xdz"])
        delta = float(jnp.linalg.norm(new_beta - beta)
                      / (jnp.linalg.norm(beta) + 1e-12))
        beta = new_beta
        if delta < tol:
            converged = True
            break
    return beta, state["ll"], it, converged


# -- oracle equivalence -------------------------------------------------------

def test_irls_matches_prerefactor_loop(cls_table):
    tbl, _ = cls_table
    res = logregr(tbl, max_iters=30, tol=1e-6)
    beta, ll, it, conv = _oracle_irls(tbl)
    np.testing.assert_allclose(np.asarray(res.coef), np.asarray(beta),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(res.log_likelihood), float(ll),
                               rtol=1e-5)
    assert res.n_iters == it
    assert res.converged == conv


def test_host_mode_matches_compiled(cls_table):
    tbl, _ = cls_table
    a = logregr(tbl, max_iters=30)
    b = logregr(tbl, max_iters=30, mode="host")
    np.testing.assert_allclose(np.asarray(a.coef), np.asarray(b.coef),
                               rtol=1e-5, atol=1e-6)
    assert a.n_iters == b.n_iters and a.converged == b.converged


def test_sharded_engine_matches_local(cls_table, mesh1):
    tbl, _ = cls_table
    local = logregr(tbl, max_iters=30)
    sharded = logregr(tbl.distribute(mesh1), max_iters=30, block_size=512)
    np.testing.assert_allclose(np.asarray(local.coef),
                               np.asarray(sharded.coef),
                               rtol=1e-4, atol=1e-5)
    assert sharded.converged


def test_sharded_engine_mask_matches_local(cls_table, mesh1):
    """fit's sharded engine now honors mask= (fold-level base filter):
    fitting the even rows sharded == fitting them locally."""
    tbl, _ = cls_table
    mask = jnp.arange(tbl.n_rows) % 2 == 0
    local = fit(IRLSTask(), tbl, max_iters=30, mask=mask)
    sharded = fit(IRLSTask(), tbl.distribute(mesh1), max_iters=30,
                  mask=mask, block_size=512)
    np.testing.assert_allclose(np.asarray(local.state["beta"]),
                               np.asarray(sharded.state["beta"]),
                               rtol=1e-4, atol=1e-5)
    assert local.n_iters == sharded.n_iters


def test_fit_grouped_sharded_segment_matches_local(key, mesh1):
    """fit_grouped(mesh=) — the whole frozen-group loop in one shard_map
    program — reproduces the local segment layout's per-group models,
    iteration counts and active-row trace."""
    n, d, G = 1536, 3, 4
    kx, kg, ku = jax.random.split(key, 3)
    x = jnp.round(jax.random.normal(kx, (n, d)) * 8) / 8
    g = jax.random.randint(kg, (n,), 0, G)
    p = jax.nn.sigmoid(x @ jnp.ones((d,)))
    y = (jax.random.uniform(ku, (n,)) < p).astype(jnp.float32)
    tbl = Table.from_columns({"x": x, "y": y, "g": g})
    loc = fit_grouped(IRLSTask(), tbl, "g", G, max_iters=25, tol=1e-6,
                      block_size=128)
    sh = fit_grouped(IRLSTask(), tbl, "g", G, max_iters=25, tol=1e-6,
                     block_size=128, mesh=mesh1)
    assert sh.stats["layout"] == "segment" and sh.stats["sharded"]
    np.testing.assert_array_equal(loc.n_iters, sh.n_iters)
    np.testing.assert_array_equal(np.asarray(loc.stats["active_rows"]),
                                  np.asarray(sh.stats["active_rows"]))
    np.testing.assert_allclose(np.asarray(loc.state["beta"]),
                               np.asarray(sh.state["beta"]),
                               rtol=1e-5, atol=1e-6)


def test_warm_start_skips_iterations(cls_table):
    tbl, _ = cls_table
    cold = logregr(tbl, max_iters=30)
    warm = logregr(tbl, max_iters=30, warm_start=cold.coef)
    assert warm.converged and warm.n_iters <= 2
    np.testing.assert_allclose(np.asarray(warm.coef), np.asarray(cold.coef),
                               rtol=1e-4, atol=1e-5)


def test_stream_engine_matches_local(cls_table):
    tbl, _ = cls_table
    local = logregr(tbl, max_iters=30)
    stream = logregr_stream(
        lambda: (dict(b.columns) for b in tbl.blocks(600)), max_iters=30)
    np.testing.assert_allclose(np.asarray(local.coef),
                               np.asarray(stream.coef),
                               rtol=1e-4, atol=1e-5)
    assert stream.converged and stream.n_iters == local.n_iters


def test_sgd_epochs_match_prerefactor(cls_table, key):
    """Counted (tol=None) executor mode: the SGD epoch task reproduces the
    pre-refactor host epoch loop bit-for-bit (same key sequence)."""
    from repro.core import sgd
    tbl, _ = cls_table

    def logloss(params, block, mask):
        z = block["x"] @ params
        return jnp.sum(jnp.where(block["y"] > 0.5, jax.nn.softplus(-z),
                                 jax.nn.softplus(z)) * mask)

    prog = ConvexProgram(loss=logloss)
    new = sgd(prog, tbl, jnp.zeros(5), stepsize=0.5, epochs=3, batch=128,
              key=key)

    # pre-refactor reference: host loop, split-per-epoch, shuffled batches
    params = jnp.zeros(5)
    k = key
    n = tbl.n_rows
    nb = n // 128
    for e in range(3):
        k, sub = jax.random.split(k)
        perm = jax.random.permutation(sub, n)[: nb * 128].reshape(nb, 128)
        alpha = 0.5 / (1.0 + e)

        def body(p, idx):
            block = {c: v[idx] for c, v in tbl.columns.items()}
            g = jax.grad(prog.total_loss)(p, block,
                                          jnp.ones((128,), jnp.bool_))
            return jax.tree.map(lambda pp, gg: pp - alpha * gg / 128, p, g), \
                None

        params, _ = jax.lax.scan(body, params, perm)
    np.testing.assert_allclose(np.asarray(new), np.asarray(params),
                               rtol=1e-6, atol=1e-7)


# -- GROUP BY model fitting ---------------------------------------------------

def _concat_groups(key, sizes, d=4):
    """Per-group synthetic logistic data with DIFFERENT true coefficients,
    concatenated into one table with a group column."""
    xs, ys, gs, betas = [], [], [], []
    for g, n in enumerate(sizes):
        tbl, b = synthetic_classification_table(
            jax.random.fold_in(key, g), n, d)
        xs.append(tbl["x"])
        ys.append(tbl["y"])
        gs.append(jnp.full((n,), g, jnp.int32))
        betas.append(b)
    return Table.from_columns({
        "x": jnp.concatenate(xs), "y": jnp.concatenate(ys),
        "g": jnp.concatenate(gs)}), betas


def test_grouped_logregr_matches_solo(key):
    tbl, _ = _concat_groups(key, [1024, 2048, 512])
    grouped = logregr_grouped(tbl, "g")
    assert grouped.coef.shape == (3, 4)
    for g, n in enumerate([1024, 2048, 512]):
        sel = np.asarray(tbl["g"]) == g
        solo = logregr(Table.from_columns(
            {"x": tbl["x"][sel], "y": tbl["y"][sel]}))
        np.testing.assert_allclose(np.asarray(grouped.coef[g]),
                                   np.asarray(solo.coef),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(grouped.log_likelihood[g]),
                                   float(solo.log_likelihood), rtol=1e-4)
        assert int(grouped.n_iters[g]) == solo.n_iters
        assert bool(grouped.converged[g]) == solo.converged


def test_grouped_single_group_matches_plain(key):
    tbl, _ = synthetic_classification_table(key, 2048, 4)
    tg = tbl.with_column("g", jnp.zeros((2048,), jnp.int32))
    grouped = logregr_grouped(tg, "g")
    plain = logregr(tbl)
    assert grouped.coef.shape == (1, 4)
    np.testing.assert_allclose(np.asarray(grouped.coef[0]),
                               np.asarray(plain.coef), rtol=1e-4, atol=1e-5)
    assert int(grouped.n_iters[0]) == plain.n_iters


def test_grouped_empty_group_is_finite(key):
    """A group id with zero rows must produce a finite degenerate model
    (zero coefficients), converge immediately, and not poison the others."""
    tbl, _ = synthetic_classification_table(key, 2048, 4)
    g = jnp.where(jnp.arange(2048) % 2 == 0, 0, 2).astype(jnp.int32)
    grouped = logregr_grouped(tbl.with_column("g", g), "g", num_groups=3)
    assert np.all(np.isfinite(np.asarray(grouped.coef)))
    np.testing.assert_allclose(np.asarray(grouped.coef[1]), 0.0)
    assert bool(grouped.converged[1])
    sel = np.asarray(g) == 0
    solo = logregr(Table.from_columns(
        {"x": tbl["x"][sel], "y": tbl["y"][sel]}))
    np.testing.assert_allclose(np.asarray(grouped.coef[0]),
                               np.asarray(solo.coef), rtol=1e-4, atol=1e-5)


def test_grouped_kmeans_matches_solo(key):
    from repro.methods.kmeans import kmeans_fit, kmeans_grouped
    centers = jnp.array([[0., 0.], [5., 5.], [0., 5.]])
    kk = jax.random.split(key, 4)
    pts = centers[jax.random.randint(kk[0], (1800,), 0, 3)] \
        + 0.3 * jax.random.normal(kk[1], (1800, 2))
    g = (jnp.arange(1800) % 2).astype(jnp.int32)
    seed = jax.random.normal(kk[2], (3, 2)) * 2.0
    grouped = kmeans_grouped(Table.from_columns({"x": pts, "g": g}), "g", 3,
                             init_centroids=seed, max_iters=30)
    for i in range(2):
        sel = np.asarray(g) == i
        solo = kmeans_fit(Table.from_columns({"x": pts[sel]}), 3,
                          init_centroids=seed, max_iters=30)
        np.testing.assert_allclose(np.asarray(grouped.centroids[i]),
                                   np.asarray(solo.centroids),
                                   rtol=1e-3, atol=1e-3)
        assert int(grouped.n_iters[i]) == solo.n_iters
        assert bool(grouped.converged[i]) == solo.converged


def test_grouped_linregr_matches_lstsq(key):
    from repro.methods.linregr import linregr_grouped
    tbl, _ = synthetic_regression_table(key, 3000, 6)
    g = (jnp.arange(3000) % 3).astype(jnp.int32)
    grouped = linregr_grouped(tbl.with_column("g", g), "g")
    x = np.asarray(tbl["x"], np.float64)
    y = np.asarray(tbl["y"], np.float64)
    for i in range(3):
        sel = np.asarray(g) == i
        ref, *_ = np.linalg.lstsq(x[sel], y[sel], rcond=None)
        np.testing.assert_allclose(np.asarray(grouped.coef[i]), ref,
                                   rtol=1e-3, atol=1e-3)
        assert float(grouped.num_rows[i]) == sel.sum()


# -- pass-count accounting ----------------------------------------------------

class _CountingIRLS(IRLSAggregate):
    passes = 0

    def transition(self, state, block, mask):
        _CountingIRLS.passes += 1
        return super().transition(state, block, mask)


def test_host_mode_runs_one_pass_per_iteration(cls_table):
    """The §3.1.2 contract: each driver round = exactly ONE data pass."""
    tbl, _ = cls_table

    class Task(IRLSTask):
        def make_aggregate(self, state):
            return _CountingIRLS(state["beta"])

    _CountingIRLS.passes = 0
    res = fit(Task(), tbl, max_iters=30, tol=1e-6, mode="host")
    assert _CountingIRLS.passes == res.n_iters


def test_two_pass_kmeans_runs_two_passes_per_iteration(key):
    from repro.methods import kmeans as km

    counts = {"bary": 0, "reassign": 0}

    class CountBary(km.KMeansStoredAssignAggregate):
        def transition(self, state, block, mask):
            counts["bary"] += 1
            return super().transition(state, block, mask)

    class CountReassign(km.KMeansReassignAggregate):
        def transition(self, state, block, mask):
            counts["reassign"] += 1
            return super().transition(state, block, mask)

    pts = jax.random.normal(key, (512, 2))
    tbl = Table.from_columns({"x": pts})
    seed = jax.random.normal(jax.random.fold_in(key, 1), (4, 2))
    task_cls = km.KMeansTwoPassTask

    class Task(task_cls):
        def iteration(self, state, run_pass):
            out = run_pass(CountBary(state["cents"], state["assign"]))
            upd = run_pass(CountReassign(out["centroids"], state["assign"]))
            new = {"cents": out["centroids"], "assign": upd["assign"],
                   "it": state["it"] + 1}
            n = jnp.maximum(jnp.sum(out["counts"]), 1.0)
            m = jnp.where(new["it"] <= 1, jnp.inf, upd["moved"] / n)
            return new, {"sse": out["sse"], "counts": out["counts"]}, m

    t = tbl.with_column("__row__", jnp.arange(512, dtype=jnp.int32))
    res = fit(Task(seed), t, max_iters=5, tol=0.5 / 512, mode="host")
    assert counts["bary"] == res.n_iters
    assert counts["reassign"] == res.n_iters


# -- streaming fused profile (ROADMAP workload) -------------------------------

def test_profile_stream_matches_local(key):
    from repro.methods.profile import profile, profile_stream
    cols = {
        "v": jax.random.normal(key, (5000,)),
        "item": jax.random.randint(jax.random.fold_in(key, 1), (5000,),
                                   0, 400),
    }
    tbl = Table.from_columns(cols)
    streamed = profile_stream(
        (dict(b.columns) for b in tbl.blocks(700)), distinct_counts=True)
    local = profile(tbl, distinct_counts=True)
    for col in cols:
        for k, v in local[col].items():
            np.testing.assert_allclose(
                np.asarray(streamed[col][k]), np.asarray(v),
                rtol=1e-4, atol=1e-4, err_msg=f"{col}.{k}")
    assert "approx_distinct" in streamed["item"]
