"""Multi-device integration tests.

These run in SUBPROCESSES with ``--xla_force_host_platform_device_count=8``
so the main test session keeps seeing 1 device (per the dry-run-only
device-forcing rule).  They verify real cross-device semantics: sharded
UDA == local UDA, split-K decode across a real model axis, compressed
psum, and a sharded train step.
"""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # subprocess-per-test, 8 forced host devices

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src")


def run_py(code: str, timeout=420):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=ENV, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_sharded_uda_8dev():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import run_local, run_sharded, \\
            synthetic_regression_table
        from repro.methods.linregr import LinregrAggregate
        tbl, _ = synthetic_regression_table(jax.random.PRNGKey(0), 8192, 16)
        from repro.core.compat import make_mesh
        mesh = make_mesh((8,), ("data",))
        local = run_local(LinregrAggregate(), tbl)
        sharded = run_sharded(LinregrAggregate(), tbl.distribute(mesh),
                              block_size=256)
        np.testing.assert_allclose(np.asarray(local.coef),
                                   np.asarray(sharded.coef),
                                   rtol=1e-4, atol=1e-5)
        print("OK", len(jax.devices()))
    """)
    assert "OK 8" in out


def test_splitk_decode_seq_sharded_8dev():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.decode import make_splitk_decode_attention
        from repro.core.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        b, h, hk, s, dh = 4, 8, 1, 64, 32     # MQA: kv=1 (the hard case)
        k = jax.random.PRNGKey(0)
        q = jax.random.normal(k, (b, 1, h, dh))
        ck = jax.random.normal(jax.random.fold_in(k, 1), (b, s, hk, dh))
        cv = jax.random.normal(jax.random.fold_in(k, 2), (b, s, hk, dh))
        pos = jnp.array([5, 20, 40, 63], jnp.int32)
        attn = make_splitk_decode_attention(mesh, batch_axes=("data",))
        ck_sh = jax.device_put(ck, NamedSharding(
            mesh, P("data", "model", None, None)))
        cv_sh = jax.device_put(cv, NamedSharding(
            mesh, P("data", "model", None, None)))
        out = attn(q, ck_sh, cv_sh, pos)
        qg = q.reshape(b, hk, h // hk, dh)
        logits = jnp.einsum("bhgd,bkhd->bhgk", qg, ck) / (dh ** 0.5)
        valid = jnp.arange(s)[None, :] <= pos[:, None]
        logits = jnp.where(valid[:, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, -1)
        ref = jnp.einsum("bhgk,bkhd->bhgd", w, cv).reshape(b, 1, h, dh)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)
        print("SPLITK-OK")
    """)
    assert "SPLITK-OK" in out


def test_compressed_psum_8dev():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum, \\
            init_error_feedback
        from repro.core.compat import make_mesh
        mesh = make_mesh((8,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 1024))

        def body(g_shard, key):
            grads = {"w": g_shard[0]}
            err = init_error_feedback(grads)
            out, new_e = compressed_psum(grads, err, key, "pod")
            return out["w"]

        from repro.core.compat import shard_map
        fn = jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P("pod"), P()), out_specs=P("pod"),
            check_vma=False))
        keys = jax.random.PRNGKey(1)
        out = fn(g[:, None], keys)           # (8, 1024): per-shard results
        mean_true = jnp.mean(g, axis=0)
        # every shard's dequantized mean approximates the true mean
        err = float(jnp.max(jnp.abs(out[0] - mean_true)))
        scale = float(jnp.max(jnp.abs(g))) / 127.0
        assert err < 3 * scale, (err, scale)
        print("COMPRESS-OK")
    """)
    assert "COMPRESS-OK" in out


def test_sharded_train_step_8dev():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import reduced_config
        from repro.data import synthetic_batch
        from repro.train.trainer import (init_train_state, jit_train_step,
                                         make_train_step)
        from repro.distributed.sharding import DEFAULT_RULES
        cfg = reduced_config("qwen3-8b")
        from repro.core.compat import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        state, axes = init_train_state(cfg, jax.random.PRNGKey(0))
        step = make_train_step(cfg, base_lr=1e-2, warmup=1, total_steps=50)
        batch = synthetic_batch(cfg, 8, 16, jax.random.PRNGKey(1))
        spec = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                for k, v in batch.items()}
        fn = jit_train_step(step, state, axes, spec, mesh, DEFAULT_RULES)
        losses = []
        for _ in range(4):
            state, m = fn(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        assert np.isfinite(losses).all()
        print("TRAIN-OK", [round(l, 3) for l in losses])
    """)
    assert "TRAIN-OK" in out
