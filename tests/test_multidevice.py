"""Multi-device integration tests.

These run in SUBPROCESSES with ``--xla_force_host_platform_device_count=8``
so the main test session keeps seeing 1 device (per the dry-run-only
device-forcing rule).  They verify real cross-device semantics: sharded
UDA == local UDA, split-K decode across a real model axis, compressed
psum, and a sharded train step.
"""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # subprocess-per-test, 8 forced host devices

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src")


def run_py(code: str, timeout=420):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=ENV, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_sharded_uda_8dev():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import fit, run_local, run_sharded, \\
            synthetic_regression_table
        from repro.methods.linregr import LinregrAggregate
        from repro.methods.logregr import IRLSTask
        tbl, _ = synthetic_regression_table(jax.random.PRNGKey(0), 8192, 16)
        from repro.core.compat import make_mesh
        mesh = make_mesh((8,), ("data",))
        local = run_local(LinregrAggregate(), tbl)
        dist = tbl.distribute(mesh)
        sharded = run_sharded(LinregrAggregate(), dist, block_size=256)
        np.testing.assert_allclose(np.asarray(local.coef),
                                   np.asarray(sharded.coef),
                                   rtol=1e-4, atol=1e-5)
        # mask= chunks alongside the rows: fold-level base filter parity
        mask = jnp.arange(tbl.n_rows) % 3 == 0
        lm = run_local(LinregrAggregate(), tbl, mask=mask)
        sm = run_sharded(LinregrAggregate(), dist, mask=mask,
                         block_size=256)
        np.testing.assert_allclose(np.asarray(lm.coef),
                                   np.asarray(sm.coef),
                                   rtol=1e-4, atol=1e-5)
        assert float(sm.num_rows) == float(mask.sum())
        y = (tbl["y"] > 0).astype(jnp.float32)
        ctbl = tbl.with_column("y", y)
        fl = fit(IRLSTask(), ctbl, max_iters=20, mask=mask)
        fs = fit(IRLSTask(), ctbl.distribute(mesh), max_iters=20,
                 mask=mask, block_size=256)
        assert fl.n_iters == fs.n_iters
        np.testing.assert_allclose(np.asarray(fl.state["beta"]),
                                   np.asarray(fs.state["beta"]),
                                   rtol=1e-4, atol=1e-5)
        print("OK", len(jax.devices()))
    """)
    assert "OK 8" in out


def test_splitk_decode_seq_sharded_8dev():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.decode import make_splitk_decode_attention
        from repro.core.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        b, h, hk, s, dh = 4, 8, 1, 64, 32     # MQA: kv=1 (the hard case)
        k = jax.random.PRNGKey(0)
        q = jax.random.normal(k, (b, 1, h, dh))
        ck = jax.random.normal(jax.random.fold_in(k, 1), (b, s, hk, dh))
        cv = jax.random.normal(jax.random.fold_in(k, 2), (b, s, hk, dh))
        pos = jnp.array([5, 20, 40, 63], jnp.int32)
        attn = make_splitk_decode_attention(mesh, batch_axes=("data",))
        ck_sh = jax.device_put(ck, NamedSharding(
            mesh, P("data", "model", None, None)))
        cv_sh = jax.device_put(cv, NamedSharding(
            mesh, P("data", "model", None, None)))
        out = attn(q, ck_sh, cv_sh, pos)
        qg = q.reshape(b, hk, h // hk, dh)
        logits = jnp.einsum("bhgd,bkhd->bhgk", qg, ck) / (dh ** 0.5)
        valid = jnp.arange(s)[None, :] <= pos[:, None]
        logits = jnp.where(valid[:, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, -1)
        ref = jnp.einsum("bhgk,bkhd->bhgd", w, cv).reshape(b, 1, h, dh)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)
        print("SPLITK-OK")
    """)
    assert "SPLITK-OK" in out


def test_sharded_grouped_uda_8dev():
    """run_grouped(mesh=) across 8 devices is BIT-IDENTICAL to the local
    segment fold for exact-state aggregates (dyadic linregr, integer
    Count-Min), and the sharded masked fallback serves generic merges."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import Table, run_grouped
        from repro.core.compat import make_mesh
        from repro.methods.linregr import LinregrAggregate
        from repro.methods.sketches import CountMinAggregate
        mesh = make_mesh((8,), ("data",))
        k = jax.random.PRNGKey(0)
        n, d, G = 4001, 4, 7
        kx, ky, kg, ki = jax.random.split(k, 4)
        x = jnp.round(jax.random.normal(kx, (n, d)) * 8) / 8
        y = jnp.round(jax.random.normal(ky, (n,)) * 8) / 8
        g = jax.random.randint(kg, (n,), 0, G - 2)   # two groups empty
        item = jax.random.randint(ki, (n,), 0, 500)
        tbl = Table.from_columns({"x": x, "y": y, "g": g, "item": item})
        for agg in (LinregrAggregate(), CountMinAggregate(4, 256)):
            loc = run_grouped(agg, tbl, "g", G, method="segment",
                              block_size=128)
            sh = run_grouped(agg, tbl, "g", G, method="segment",
                             block_size=128, mesh=mesh)
            for a, b in zip(jax.tree.leaves(loc), jax.tree.leaves(sh)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # generic-merge fallback takes the sharded masked path
        from repro.methods.kmeans import GumbelPickAggregate
        t2 = Table.from_columns({
            "x": x, "d2": jnp.ones((n,)),
            "__row__": jnp.arange(n, dtype=jnp.int32), "g": g})
        agg = GumbelPickAggregate(jax.random.PRNGKey(1), d)
        o_sh = run_grouped(agg, t2, "g", G, mesh=mesh)
        o_lo = run_grouped(agg, t2, "g", G, mesh=None)
        for a, b in zip(jax.tree.leaves(o_sh), jax.tree.leaves(o_lo)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        print("GROUPED-OK", len(jax.devices()))
    """)
    assert "GROUPED-OK 8" in out


def test_sharded_fit_grouped_8dev():
    """fit_grouped(mesh=) runs the whole frozen-group loop in one
    shard_map program with per-group n_iters parity vs local: exact on a
    deterministic countdown task, and matching IRLS models/iteration
    counts on a real grouped logistic fit."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import IterativeTask, Table, fit_grouped
        from repro.core.aggregates import Aggregate, MERGE_SUM
        from repro.core.compat import make_mesh
        from repro.methods.logregr import IRLSTask
        mesh = make_mesh((8,), ("data",))

        class MeanAgg(Aggregate):
            merge_ops = MERGE_SUM
            def init(self, block):
                return {"s": jnp.zeros(()), "n": jnp.zeros(())}
            def transition(self, state, block, mask):
                m = mask.astype(jnp.float32)
                return {"s": state["s"] + jnp.sum(block["k"] * m),
                        "n": state["n"] + jnp.sum(m)}
            def final(self, s):
                return s["s"] / jnp.maximum(s["n"], 1.0)

        class Countdown(IterativeTask):
            def init_state(self, columns):
                return {"it": jnp.zeros(())}
            def make_aggregate(self, state):
                return MeanAgg()
            def update(self, state, out):
                return {"it": state["it"] + 1.0}
            def metric(self, prev, new, out):
                return out - new["it"]

        # group i's mean(k) == i + 1 exactly -> converges after i+1 rounds
        G, per = 6, 600
        g = jnp.repeat(jnp.arange(G, dtype=jnp.int32), per)
        tbl = Table.from_columns({"k": (g + 1).astype(jnp.float32),
                                  "g": g})
        loc = fit_grouped(Countdown(), tbl, "g", G, max_iters=20, tol=0.5,
                          block_size=64)
        sh = fit_grouped(Countdown(), tbl, "g", G, max_iters=20, tol=0.5,
                         block_size=64, mesh=mesh)
        np.testing.assert_array_equal(loc.n_iters, np.arange(1, G + 1))
        np.testing.assert_array_equal(sh.n_iters, loc.n_iters)
        np.testing.assert_array_equal(sh.stats["active_rows"],
                                      loc.stats["active_rows"])
        assert sh.stats["sharded"] and not loc.stats["sharded"]

        # real model: grouped IRLS, per-group n_iters + coefficient parity
        k = jax.random.PRNGKey(0)
        n, d, G2 = 4096, 4, 5
        kx, kg, ku = jax.random.split(k, 3)
        x = jnp.round(jax.random.normal(kx, (n, d)) * 8) / 8
        gid = jax.random.randint(kg, (n,), 0, G2)
        b = 1.0 + jnp.arange(G2, dtype=jnp.float32)[:, None] \\
            * jnp.ones((G2, d)) * 0.3
        p = jax.nn.sigmoid(jnp.sum(x * b[gid], -1))
        y = (jax.random.uniform(ku, (n,)) < p).astype(jnp.float32)
        ftbl = Table.from_columns({"x": x, "y": y, "g": gid})
        rl = fit_grouped(IRLSTask(), ftbl, "g", G2, max_iters=30,
                         tol=1e-6, block_size=128)
        rs = fit_grouped(IRLSTask(), ftbl, "g", G2, max_iters=30,
                         tol=1e-6, block_size=128, mesh=mesh)
        np.testing.assert_array_equal(rl.n_iters, rs.n_iters)
        np.testing.assert_allclose(np.asarray(rl.state["beta"]),
                                   np.asarray(rs.state["beta"]),
                                   rtol=1e-4, atol=1e-6)
        print("FITGROUPED-OK", loc.n_iters.tolist(), rl.n_iters.tolist())
    """)
    assert "FITGROUPED-OK" in out


def test_ivm_empty_view_and_sharding_8dev():
    """PR-6 regressions at real device counts: (a) a sharded grouped
    pass over an all-empty view consumes the sentinel-padded block
    layout (every segment owns whole blocks even with 0 real rows);
    (b) derived columns on a distributed table are actually row-sharded
    over the 8 devices, and append re-places the grown table."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.core import Table, run_grouped
        from repro.core.compat import make_mesh
        from repro.methods.linregr import LinregrAggregate
        mesh = make_mesh((8,), ("data",))
        # (a) all ids out of range -> every group empty
        t = Table.from_columns({
            "g": jnp.full((64,), -1, jnp.int32),
            "x": jnp.ones((64, 2)), "y": jnp.ones((64,))})
        view = t.group_by("g", 5)
        cols, valid, bgids = view.sharded_blocks(mesh, block_size=4)
        assert bgids.shape[0] == 8 and bgids.shape[0] % 8 == 0
        assert not bool(valid.any())
        out = run_grouped(LinregrAggregate(), view, mesh=mesh,
                          block_size=4)
        np.testing.assert_array_equal(np.asarray(out.num_rows),
                                      np.zeros(5))
        # (b) sharding invariants across with_column / append
        t2 = Table.from_columns({"a": jnp.arange(64.0)}).distribute(mesh)
        t3 = t2.with_column("b", jnp.arange(64.0) * 2)
        assert isinstance(t3["b"].sharding, NamedSharding)
        assert len(t3["b"].sharding.device_set) == 8
        t3.append({"a": jnp.arange(16.0), "b": jnp.arange(16.0)})
        assert t3.n_rows == 80 and t3.version == 1
        assert len(t3["a"].sharding.device_set) == 8
        print("IVM-OK", len(jax.devices()))
    """)
    assert "IVM-OK 8" in out


def test_compressed_psum_8dev():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum, \\
            init_error_feedback
        from repro.core.compat import make_mesh
        mesh = make_mesh((8,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 1024))

        def body(g_shard, key):
            grads = {"w": g_shard[0]}
            err = init_error_feedback(grads)
            out, new_e = compressed_psum(grads, err, key, "pod")
            return out["w"]

        from repro.core.compat import shard_map
        fn = jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P("pod"), P()), out_specs=P("pod"),
            check_vma=False))
        keys = jax.random.PRNGKey(1)
        out = fn(g[:, None], keys)           # (8, 1024): per-shard results
        mean_true = jnp.mean(g, axis=0)
        # every shard's dequantized mean approximates the true mean
        err = float(jnp.max(jnp.abs(out[0] - mean_true)))
        scale = float(jnp.max(jnp.abs(g))) / 127.0
        assert err < 3 * scale, (err, scale)
        print("COMPRESS-OK")
    """)
    assert "COMPRESS-OK" in out


def test_sharded_train_step_8dev():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import reduced_config
        from repro.data import synthetic_batch
        from repro.train.trainer import (init_train_state, jit_train_step,
                                         make_train_step)
        from repro.distributed.sharding import DEFAULT_RULES
        cfg = reduced_config("qwen3-8b")
        from repro.core.compat import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        state, axes = init_train_state(cfg, jax.random.PRNGKey(0))
        step = make_train_step(cfg, base_lr=1e-2, warmup=1, total_steps=50)
        batch = synthetic_batch(cfg, 8, 16, jax.random.PRNGKey(1))
        spec = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                for k, v in batch.items()}
        fn = jit_train_step(step, state, axes, spec, mesh, DEFAULT_RULES)
        losses = []
        for _ in range(4):
            state, m = fn(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        assert np.isfinite(losses).all()
        print("TRAIN-OK", [round(l, 3) for l in losses])
    """)
    assert "TRAIN-OK" in out
