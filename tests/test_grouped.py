"""Partitioned grouped-scan core: GroupedView layout, segment-vs-masked
equivalence, grouped one-pass oracle tests, and skewed-convergence
compaction.

The refactor contract mirrors PR 2's: changing HOW GROUP BY executes
(partitioned segments vs per-group masks) changes cost, never results.
Integer-state aggregates (sketches, histograms) and exactly-representable
(dyadic) float data make the grouped-vs-solo oracle checks bit-identical;
everything else is held to f32-ulp-level tolerances.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    IterativeTask, ProfileAggregate, Table, fit_grouped, fit_stream,
    run_grouped, run_stream,
)
from repro.core.aggregates import Aggregate, MERGE_SUM
from repro.methods.linregr import linregr, linregr_grouped
from repro.methods.naive_bayes import naive_bayes_fit, naive_bayes_grouped
from repro.methods.quantiles import quantiles, quantiles_grouped
from repro.methods.sketches import (
    countmin_sketch, countmin_sketch_grouped, fm_distinct_count,
    fm_distinct_count_grouped,
)


def _dyadic(key, shape):
    """Small multiples of 1/8: f32 sums of their pairwise products are
    exact, so any fold order gives bit-identical aggregate states."""
    return jnp.round(jax.random.normal(key, shape) * 8.0) / 8.0


@pytest.fixture(scope="module")
def grouped_table(key):
    n, d, G = 1200, 4, 4
    kx, ky, kg, ki, kv = jax.random.split(key, 5)
    return Table.from_columns({
        "x": _dyadic(kx, (n, d)),
        "y": jax.random.randint(ky, (n,), 0, 3).astype(jnp.float32),
        "g": jax.random.randint(kg, (n,), 0, G),
        "item": jax.random.randint(ki, (n,), 0, 300),
        "v": jax.random.normal(kv, (n,)),
    }), G


# -- GroupedView layout -------------------------------------------------------

def test_grouped_view_layout(key):
    g = jax.random.randint(key, (500,), 0, 7)
    tbl = Table.from_columns({"v": jnp.arange(500.0), "g": g})
    view = tbl.group_by("g")
    gn = np.asarray(g)
    assert view.num_groups == 7
    np.testing.assert_array_equal(np.asarray(view.gids), np.sort(gn))
    np.testing.assert_array_equal(np.asarray(view.counts),
                                  np.bincount(gn, minlength=7))
    offs = np.asarray(view.offsets)
    for i in range(7):
        seg = np.asarray(view.table["v"])[offs[i]:offs[i + 1]]
        np.testing.assert_array_equal(np.sort(seg),
                                      np.sort(np.arange(500.0)[gn == i]))
    # stable sort: within a group, original row order is preserved
    np.testing.assert_array_equal(
        np.asarray(view.perm), np.argsort(gn, kind="stable"))


def test_grouped_view_aligned_blocks(key):
    g = jax.random.randint(key, (300,), 0, 5)
    tbl = Table.from_columns({"v": jnp.arange(300.0), "g": g})
    view = tbl.group_by("g", 6)  # group 5 empty
    cols, valid, bgids = view.aligned_blocks(64)
    counts = np.asarray(view.counts)
    assert bgids.shape[0] == int((-(-counts // 64)).sum())
    # every block holds rows of exactly one group, padding masked out
    vg = np.asarray(view.gids)
    offs = np.asarray(view.offsets)
    vals = np.asarray(cols["v"]).reshape(-1, 64)
    vm = np.asarray(valid).reshape(-1, 64)
    for j, gid in enumerate(np.asarray(bgids)):
        rows = vals[j][vm[j]]
        src = np.asarray(view.table["v"])[offs[gid]:offs[gid + 1]]
        assert np.all(np.isin(rows, src))
    assert int(np.asarray(valid).sum()) == 300


# -- segment vs masked equivalence on random layouts --------------------------

@pytest.mark.parametrize("seed,G,bs", [(0, 3, None), (1, 8, 64), (2, 16, 17)])
def test_run_grouped_segment_matches_masked(seed, G, bs):
    """The two grouped strategies agree on random group layouts (empty
    groups, non-contiguous ids, ragged sizes included)."""
    k = jax.random.PRNGKey(seed)
    kx, kg = jax.random.split(k)
    n = 700
    # leave some ids unused so empty groups are exercised
    g = jax.random.randint(kg, (n,), 0, max(1, G - 2))
    tbl = Table.from_columns({
        "x": jax.random.normal(kx, (n, 3)),
        "v": jax.random.normal(jax.random.fold_in(k, 3), (n,)),
        "g": g,
    })
    seg = run_grouped(ProfileAggregate(), tbl, "g", G, method="segment",
                      block_size=bs)
    msk = run_grouped(ProfileAggregate(), tbl, "g", G, method="masked",
                      block_size=bs)
    for col in ("x", "v"):
        for stat in ("count", "sum", "sumsq", "min", "max", "mean", "std"):
            np.testing.assert_allclose(
                np.asarray(seg[col][stat]), np.asarray(msk[col][stat]),
                rtol=1e-5, atol=1e-5, err_msg=f"{col}.{stat}")


def test_run_grouped_mask_filters_rows(key):
    """run_grouped accepts a base mask like run_local, on both paths."""
    n = 400
    g = jax.random.randint(key, (n,), 0, 4)
    tbl = Table.from_columns({"v": jnp.arange(n, dtype=jnp.float32),
                              "g": g})
    mask = jnp.arange(n) % 2 == 0
    for method in ("segment", "masked"):
        out = run_grouped(ProfileAggregate(), tbl, "g", 4, mask=mask,
                          method=method)
        counts = np.asarray(out["v"]["count"])
        expect = np.bincount(np.asarray(g)[np.asarray(mask)], minlength=4)
        np.testing.assert_array_equal(counts, expect, err_msg=method)


def test_run_grouped_generic_merge_falls_back():
    """A generic-merge aggregate cannot take the segment path: auto falls
    back to masked, and forcing segment raises."""
    from repro.methods.kmeans import GumbelPickAggregate
    n = 128
    tbl = Table.from_columns({
        "x": jnp.ones((n, 2)), "d2": jnp.ones((n,)),
        "__row__": jnp.arange(n, dtype=jnp.int32),
        "g": (jnp.arange(n) % 2).astype(jnp.int32),
    })
    agg = GumbelPickAggregate(jax.random.PRNGKey(0), 2)
    out = run_grouped(agg, tbl, "g", 2)  # auto -> masked, must not raise
    assert np.asarray(out["score"]).shape == (2,)
    with pytest.raises(ValueError, match="segment"):
        run_grouped(agg, tbl, "g", 2, method="segment")


def test_run_grouped_accepts_prebuilt_view(key):
    """A GroupedView pays the sort once and is accepted in place of a
    Table by both strategies, with identical results."""
    n = 600
    g = jax.random.randint(key, (n,), 0, 5)
    tbl = Table.from_columns({
        "v": jax.random.normal(jax.random.fold_in(key, 1), (n,)), "g": g})
    vw = tbl.group_by("g", 5)
    for method in ("segment", "masked"):
        from_view = run_grouped(ProfileAggregate(), vw, method=method)
        from_tbl = run_grouped(ProfileAggregate(), tbl, "g", 5,
                               method=method)
        for stat in ("count", "sum", "min", "max"):
            np.testing.assert_allclose(
                np.asarray(from_view["v"][stat]),
                np.asarray(from_tbl["v"][stat]), rtol=1e-6, atol=1e-6,
                err_msg=f"{method}.{stat}")
    with pytest.raises(ValueError, match="group_col"):
        run_grouped(ProfileAggregate(), tbl)  # Table without a key column
    with pytest.raises(ValueError, match="disagrees"):
        run_grouped(ProfileAggregate(), vw, num_groups=9)


def test_run_grouped_blocked_fold_used(key):
    """The masked path now honors block_size (regression: it used to fold
    the whole table in one unblocked transition)."""
    calls = []

    class Counting(ProfileAggregate):
        def transition(self, state, block, mask):
            calls.append(block["v"].shape[0])
            return super().transition(state, block, mask)

    n = 256
    tbl = Table.from_columns({"v": jnp.arange(n, dtype=jnp.float32),
                              "g": jnp.zeros((n,), jnp.int32)})
    run_grouped(Counting(), tbl, "g", 1, method="masked", block_size=64)
    assert calls and all(b == 64 for b in calls)


# -- grouped one-pass oracle tests (bit-identical) ----------------------------

def test_naive_bayes_grouped_matches_solo(grouped_table):
    """Dyadic features make every sufficient-statistic sum exact in f32,
    so the grouped model is BIT-IDENTICAL to fitting each group alone."""
    tbl, G = grouped_table
    nb = naive_bayes_grouped(tbl, "g", 3)
    assert nb.mean.shape == (G, 3, 4)
    gv = np.asarray(tbl["g"])
    for i in range(G):
        sel = gv == i
        solo = naive_bayes_fit(Table.from_columns(
            {"x": tbl["x"][sel], "y": tbl["y"][sel]}), 3)
        np.testing.assert_array_equal(np.asarray(nb.log_prior[i]),
                                      np.asarray(solo.log_prior))
        np.testing.assert_array_equal(np.asarray(nb.mean[i]),
                                      np.asarray(solo.mean))
        np.testing.assert_array_equal(np.asarray(nb.var[i]),
                                      np.asarray(solo.var))


def test_quantiles_grouped_matches_solo(grouped_table):
    """Histogram counts are integers and each group's range comes from its
    own (exact) min/max, so per-group quantiles are BIT-IDENTICAL to the
    solo two-pass sketch on that group's rows."""
    tbl, G = grouped_table
    qs = [0.1, 0.25, 0.5, 0.9]
    qg = quantiles_grouped(tbl, "g", qs, bins=512)
    assert qg.shape == (G, len(qs))
    gv = np.asarray(tbl["g"])
    for i in range(G):
        solo = quantiles(Table.from_columns({"v": tbl["v"][gv == i]}), qs,
                         bins=512)
        np.testing.assert_array_equal(np.asarray(qg[i]), np.asarray(solo))


def test_sketches_grouped_match_solo(grouped_table):
    """Integer sketch states are order-independent: grouped Count-Min and
    FM are BIT-IDENTICAL to sketching each group alone."""
    tbl, G = grouped_table
    cm = countmin_sketch_grouped(tbl, "g", depth=4, width=256)
    fm = fm_distinct_count_grouped(tbl, "g", num_hashes=4, bits=16)
    assert cm.shape == (G, 4, 256) and fm.shape == (G,)
    gv = np.asarray(tbl["g"])
    for i in range(G):
        st = Table.from_columns({"item": tbl["item"][gv == i]})
        np.testing.assert_array_equal(
            np.asarray(cm[i]),
            np.asarray(countmin_sketch(st, depth=4, width=256)))
        np.testing.assert_array_equal(
            np.asarray(fm[i]),
            np.asarray(fm_distinct_count(st, num_hashes=4, bits=16)))


def test_linregr_grouped_bit_identical_on_dyadic_data(key):
    """With exactly-representable data the partitioned fold's X^T X equals
    the solo matmul bitwise, so the whole OLS result is bit-identical."""
    n, d, G = 1024, 4, 4
    kx, kb, kg, ke = jax.random.split(key, 4)
    x = _dyadic(kx, (n, d))
    b = _dyadic(kb, (d,))
    y = jnp.round((x @ b + 0.1 * jax.random.normal(ke, (n,))) * 8) / 8
    g = jax.random.randint(kg, (n,), 0, G)
    tbl = Table.from_columns({"x": x, "y": y, "g": g})
    lr = linregr_grouped(tbl, "g")
    gv = np.asarray(g)
    for i in range(G):
        sel = gv == i
        solo = linregr(Table.from_columns({"x": x[sel], "y": y[sel]}))
        np.testing.assert_array_equal(np.asarray(lr.coef[i]),
                                      np.asarray(solo.coef))
        np.testing.assert_array_equal(np.asarray(lr.r2[i]),
                                      np.asarray(solo.r2))
        np.testing.assert_array_equal(np.asarray(lr.num_rows[i]),
                                      np.asarray(solo.num_rows))
        # Wald statistics go through a BATCHED eigh under the grouped
        # vmap, whose pseudo-inverse differs from the solo one by ~1 ulp.
        np.testing.assert_allclose(np.asarray(lr.std_err[i]),
                                   np.asarray(solo.std_err), rtol=1e-5)


# -- fit_grouped: layouts, compaction, skewed convergence ---------------------

class _MeanAggregate(Aggregate):
    merge_ops = MERGE_SUM

    def init(self, block):
        return {"s": jnp.zeros(()), "n": jnp.zeros(())}

    def transition(self, state, block, mask):
        m = mask.astype(jnp.float32)
        return {"s": state["s"] + jnp.sum(block["k"] * m),
                "n": state["n"] + jnp.sum(m)}

    def final(self, s):
        return s["s"] / jnp.maximum(s["n"], 1.0)


class _CountdownTask(IterativeTask):
    """Deterministic convergence schedule: group g's metric is
    ``mean(k) - rounds_done``, so it converges after ceil(mean(k)) rounds
    — the controlled skewed-convergence workload."""

    def init_state(self, columns):
        return {"it": jnp.zeros(())}

    def make_aggregate(self, state):
        return _MeanAggregate()

    def update(self, state, out):
        return {"it": state["it"] + 1.0}

    def metric(self, prev, new, out):
        return out - new["it"]


def _skewed_table(n=6000, G=6):
    sizes = [(i + 1) * n // ((G * (G + 1)) // 2) for i in range(G)]
    sizes[-1] += n - sum(sizes)
    g = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                         for i, s in enumerate(sizes)])
    k = (g + 1).astype(jnp.float32)  # group i converges after i+1 rounds
    return Table.from_columns({"k": k, "g": g}), sizes


def test_fit_grouped_skewed_convergence_compacts():
    """As groups freeze, the segment layout's per-round pass shrinks: the
    active-row trace decreases monotonically and the total blocks scanned
    stay below rounds x full-table blocks."""
    tbl, sizes = _skewed_table()
    res = fit_grouped(_CountdownTask(), tbl, "g", max_iters=20, tol=0.5,
                      block_size=128)
    G = len(sizes)
    np.testing.assert_array_equal(res.n_iters, np.arange(1, G + 1))
    assert res.stats["layout"] == "segment"
    ar = res.stats["active_rows"]
    assert len(ar) == G
    assert all(ar[i] > ar[i + 1] for i in range(G - 1)), ar
    assert res.stats["blocks"] < res.stats["blocks_full_scan"]
    # round r scans exactly the rows of groups that still iterate
    expect = [sum(sizes[r:]) for r in range(G)]
    np.testing.assert_array_equal(ar, expect)


def test_fit_grouped_layouts_agree():
    """layout='segment' and layout='masked' produce the same models and
    per-group iteration counts."""
    tbl, _ = _skewed_table(n=2000, G=4)
    seg = fit_grouped(_CountdownTask(), tbl, "g", max_iters=10, tol=0.5,
                      layout="segment")
    msk = fit_grouped(_CountdownTask(), tbl, "g", max_iters=10, tol=0.5,
                      layout="masked")
    assert msk.stats["layout"] == "masked"
    np.testing.assert_array_equal(seg.n_iters, msk.n_iters)
    np.testing.assert_array_equal(np.asarray(seg.converged),
                                  np.asarray(msk.converged))
    np.testing.assert_allclose(np.asarray(seg.state["it"]),
                               np.asarray(msk.state["it"]))


def test_fit_grouped_multi_statement_task_falls_back(key):
    """Tasks overriding iteration() (two-pass k-means style) cannot use the
    segment layout; auto routes them to masked."""

    class TwoScan(_CountdownTask):
        def iteration(self, state, run_pass):
            out = run_pass(self.make_aggregate(state))
            out = 0.5 * (out + run_pass(self.make_aggregate(state)))
            new = self.update(state, out)
            return new, out, self.metric(state, new, out)

    tbl, _ = _skewed_table(n=1000, G=3)
    res = fit_grouped(TwoScan(), tbl, "g", max_iters=10, tol=0.5)
    assert res.stats["layout"] == "masked"
    np.testing.assert_array_equal(res.n_iters, [1, 2, 3])
    # forcing the segment layout must refuse, not silently skip the
    # override's second scan
    with pytest.raises(ValueError, match="single-scan"):
        fit_grouped(TwoScan(), tbl, "g", max_iters=10, tol=0.5,
                    layout="segment")


# -- streaming guards (regression: bare StopIteration) ------------------------

def test_run_stream_empty_raises():
    with pytest.raises(ValueError, match="empty block stream"):
        run_stream(ProfileAggregate(), iter([]))


def test_fit_stream_empty_factory_raises():
    with pytest.raises(ValueError, match="no blocks"):
        fit_stream(_CountdownTask(), lambda: iter([]), max_iters=3)
