"""Pipeline-parallel (GPipe / collective_permute) tests — subprocess with
8 forced host devices, like test_multidevice."""

import os
import subprocess
import sys
import textwrap

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src")


def run_py(code: str, timeout=420):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=ENV, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_gpipe_matches_sequential():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import make_pipeline
        from repro.core.compat import make_mesh
        mesh = make_mesh((4, 2), ("pod", "model"))
        S, M, mb, d = 4, 8, 2, 16
        key = jax.random.PRNGKey(0)
        Ws = jax.random.normal(key, (S, d, d)) * 0.3

        def stage_fn(w, x):
            return x + jnp.tanh(x @ w)

        pipe = make_pipeline(mesh, stage_fn, stage_axis="pod")
        x = jax.random.normal(jax.random.fold_in(key, 1), (M, mb, d))
        out = jax.jit(pipe)(Ws, x)
        ref = x
        for s in range(S):
            ref = stage_fn(Ws[s], ref)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)
        print("PIPELINE-OK")
    """)
    assert "PIPELINE-OK" in out


def test_bubble_fraction():
    from repro.distributed.pipeline import bubble_fraction
    assert bubble_fraction(1, 8) == 0.0
    assert abs(bubble_fraction(4, 8) - 3 / 11) < 1e-12
    # sizing rule: M >= 4*S keeps the bubble under ~20%
    assert bubble_fraction(4, 16) < 0.2
