"""Distributed runtime tests: trainer, checkpointing, elasticity, fault
tolerance, gradient compression, split-K decode."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data import TokenStream, corpus_profile, synthetic_batch
from repro.distributed import checkpoint as ckpt
from repro.distributed.compression import (compress_grads, dequantize_int8,
                                           init_error_feedback,
                                           quantize_int8)
from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               StragglerMitigator,
                                               plan_elastic_mesh)
from repro.train import init_train_state, make_train_step


@pytest.fixture(scope="module")
def tiny_state(key):
    cfg = reduced_config("stablelm-1.6b")
    state, axes = init_train_state(cfg, key)
    return cfg, state, axes


def test_train_step_reduces_loss(tiny_state, key):
    cfg, state, _ = tiny_state
    step = make_train_step(cfg, base_lr=1e-2, warmup=1, total_steps=100)
    batch = synthetic_batch(cfg, 4, 16, key)
    losses = []
    for i in range(8):
        state, metrics = jax.jit(step)(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
    assert int(state.step) == 8
    assert np.isfinite(losses).all()


def test_grad_accum_matches_full_batch(tiny_state, key):
    cfg, state, _ = tiny_state
    batch = synthetic_batch(cfg, 8, 16, key)
    s1 = make_train_step(cfg, base_lr=1e-3, warmup=1, total_steps=10,
                         grad_accum=1)
    s4 = make_train_step(cfg, base_lr=1e-3, warmup=1, total_steps=10,
                         grad_accum=4)
    out1, m1 = jax.jit(s1)(state, batch)
    out4, m4 = jax.jit(s4)(state, batch)
    # UDA blocking invariance: same grads whether folded in 1 or 4 blocks
    for a, b in zip(jax.tree.leaves(out1.params),
                    jax.tree.leaves(out4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                                   atol=2e-4)


def test_checkpoint_roundtrip(tmp_path, tiny_state, key):
    cfg, state, _ = tiny_state
    d = str(tmp_path / "ckpt")
    ckpt.save(d, state, 7)
    restored, step = ckpt.restore(d, state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path, tiny_state):
    cfg, state, _ = tiny_state
    d = str(tmp_path / "ckpt2")
    writer = ckpt.AsyncCheckpointer()
    for s in (1, 2, 3, 4, 5):
        writer.save(d, state, s, keep=2)
    writer.wait()
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert kept == ["step_0000000004", "step_0000000005"]
    assert ckpt.latest_step(d) == 5


def test_checkpoint_elastic_reshard(tmp_path, tiny_state, mesh1):
    """Restore against explicit shardings (the elastic-restart path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    cfg, state, _ = tiny_state
    d = str(tmp_path / "ckpt3")
    ckpt.save(d, state.params, 1)
    sh = jax.tree.map(lambda _: NamedSharding(mesh1, P()), state.params)
    restored, _ = ckpt.restore(d, state.params, shardings=sh)
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_heartbeat_monitor():
    t = [0.0]
    mon = HeartbeatMonitor(["h0", "h1", "h2"], interval=10, max_missed=3,
                           clock=lambda: t[0])
    t[0] = 25.0
    mon.beat("h0")
    mon.beat("h1")
    assert mon.sweep() == []
    t[0] = 35.0          # h2 has missed 3 intervals
    assert mon.sweep() == ["h2"]
    assert sorted(mon.alive_hosts) == ["h0", "h1"]


def test_plan_elastic_mesh():
    assert plan_elastic_mesh(512, model_parallel=16, pods=2) == (2, 16, 16)
    # lose a pod's worth: shrink data axis
    assert plan_elastic_mesh(384, model_parallel=16, pods=2) == (2, 12, 16)
    assert plan_elastic_mesh(256, model_parallel=16, pods=2) == (2, 8, 16)
    assert plan_elastic_mesh(8, model_parallel=16, pods=2) is None


def test_straggler_mitigator():
    sm = StragglerMitigator(["a", "b", "c", "d"], threshold=1.5, patience=3)
    for step in range(6):
        for h in "abcd":
            sm.record(h, 1.0 if h != "d" else 2.5)
        flagged = sm.stragglers()
    assert flagged == ["d"]


def test_quantize_int8_unbiased(key):
    x = jax.random.normal(key, (4096,))
    errs = []
    for i in range(16):
        q, s = quantize_int8(x, jax.random.fold_in(key, i))
        errs.append(np.asarray(dequantize_int8(q, s) - x))
    bias = np.abs(np.mean(errs))
    assert bias < 2e-3                       # stochastic rounding ~unbiased
    assert np.max(np.abs(errs[0])) <= float(s) + 1e-6


def test_error_feedback_accumulates(key):
    g = {"w": jax.random.normal(key, (256,))}
    e = init_error_feedback(g)
    q, s, e2 = compress_grads(g, e, key)
    # dequant + error == original exactly (by construction)
    np.testing.assert_allclose(
        np.asarray(dequantize_int8(q["w"], s["w"]) + e2["w"]),
        np.asarray(g["w"]), rtol=1e-5, atol=1e-6)


def test_splitk_decode_matches_reference(key, mesh1):
    from repro.distributed.decode import make_splitk_decode_attention
    b, h, hk, s, dh = 2, 4, 2, 32, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, 1, h, dh))
    ck = jax.random.normal(kk, (b, s, hk, dh))
    cv = jax.random.normal(kv, (b, s, hk, dh))
    pos = jnp.array([7, 20], jnp.int32)
    from repro.core.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    attn = make_splitk_decode_attention(mesh, batch_axes=("data",))
    out = attn(q, ck, cv, pos)
    # reference: masked softmax attention
    qg = q.reshape(b, hk, h // hk, dh)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg, ck) / (dh ** 0.5)
    valid = jnp.arange(s)[None, :] <= pos[:, None]
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, -1)
    ref = jnp.einsum("bhgk,bkhd->bhgd", w, cv).reshape(b, 1, h, dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-5)


def test_data_pipeline_profile():
    stream = TokenStream(vocab=1000, seq_len=64, batch=8, seed=0)
    prof = corpus_profile(iter(stream), vocab=1000, n_batches=3)
    assert prof["heavy_hitters"].shape == (64,)
    assert float(prof["distinct_estimate"]) > 50
    # Zipf: token 0 region should dominate the tail
    hh = np.asarray(prof["heavy_hitters"], np.float64)
    assert hh[:8].mean() > hh[32:].mean()


def test_data_pipeline_determinism():
    a = next(iter(TokenStream(vocab=100, seq_len=16, batch=2, seed=42)))
    b = next(iter(TokenStream(vocab=100, seq_len=16, batch=2, seed=42)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
