"""Incremental view maintenance: versioning, delta folds, cache staleness.

The contract under test (``core/materialize.py`` + ``Table`` versioning):

* ``Table.append`` bumps ``version`` but not ``epoch``; ``invalidate``
  bumps both.  Every cache stamped with a version observes staleness.
* A retained :class:`MaterializedHandle` refreshed after an append
  folds ONLY the new rows (``kind="delta"`` in the trace, no scan) and
  the merged state is **bit-identical** to a full rescan for
  exact-state aggregates — integer sketches, dyadic-f32 sums.
* The ``group_by`` memo is version-aware: grouped refreshes re-sort
  only the delta (trace sort sizes prove it), and plan-time group
  resolution never reads an outdated view.

Plus regression tests for the two confirmed Table-layer bugs this PR
fixes (empty-view sentinel blocks; sharding of derived columns).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding

from repro.core import (
    GroupedScanAgg, ScanAgg, Session, Table, execute, materialize,
    run_grouped, trace_execution,
)
from repro.methods.linregr import LinregrAggregate
from repro.methods.sketches import CountMinAggregate, FMAggregate
from repro.core.templates import ProfileAggregate

from strategies import Draw, cases, group_layout


def _dyadic_table(draw: Draw, n: int, d: int = 3, groups: int = 4,
                  pattern=None):
    gids, _ = group_layout(draw, n, groups, pattern)
    return Table.from_columns({
        "x": draw.dyadic((n, d)),
        "y": draw.dyadic((n,)),
        "item": draw.ints((n,), 0, 40),
        "g": gids,
    })


def _delta_cols(draw: Draw, m: int, d: int = 3, groups: int = 4):
    return {
        "x": draw.dyadic((m, d)),
        "y": draw.dyadic((m,)),
        "item": draw.ints((m,), 0, 40),
        "g": draw.ints((m,), 0, groups - 1),
    }


def _bitwise_equal(a, b) -> bool:
    fa = [np.asarray(x) for x in jax.tree.leaves(a)]
    fb = [np.asarray(x) for x in jax.tree.leaves(b)]
    return len(fa) == len(fb) and all(
        x.shape == y.shape and (x == y).all() for x, y in zip(fa, fb))


def _allclose(a, b) -> bool:
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(fa) == len(fb) and all(
        np.allclose(np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-6,
                    equal_nan=True)
        for x, y in zip(fa, fb))


# ---------------------------------------------------------------------------
# Table versioning + append
# ---------------------------------------------------------------------------

class TestVersioning:
    def test_append_bumps_version_not_epoch(self):
        t = Table.from_columns({"a": np.arange(4.0)})
        assert (t.version, t.epoch) == (0, 0)
        t.append({"a": np.arange(2.0)})
        assert (t.version, t.epoch) == (1, 0)
        assert t.n_rows == 6
        np.testing.assert_array_equal(np.asarray(t["a"]),
                                      [0, 1, 2, 3, 0, 1])

    def test_invalidate_bumps_version_and_epoch(self):
        t = Table.from_columns({"a": np.arange(4.0)})
        t.invalidate()
        assert (t.version, t.epoch) == (1, 1)

    def test_append_schema_errors(self):
        t = Table.from_columns({"a": np.arange(4.0),
                                "b": np.zeros((4, 2), np.float32)})
        with pytest.raises(ValueError, match="columns"):
            t.append({"a": np.arange(2.0)})
        with pytest.raises(ValueError, match="dtype"):
            t.append({"a": np.arange(2), "b": np.zeros((2, 2), np.float32)})
        with pytest.raises(ValueError, match="trailing shape"):
            t.append({"a": np.arange(2.0),
                      "b": np.zeros((2, 3), np.float32)})
        assert t.version == 0  # failed appends leave the table untouched

    def test_append_distributed_replaces_rows(self, mesh1):
        t = Table.from_columns({"a": np.arange(8.0)}).distribute(mesh1)
        t.append({"a": np.arange(4.0)})
        assert t.n_rows == 12
        assert isinstance(t["a"].sharding, NamedSharding)

    def test_group_by_memo_is_version_aware(self):
        t = Table.from_columns({"g": np.array([0, 1, 0, 1], np.int32),
                                "v": np.arange(4.0)})
        with trace_execution() as tr:
            v1 = t.group_by("g", 2)
            assert t.group_by("g", 2) is v1          # memo hit
            assert t.cached_group_by("g", 2) is v1
        assert len(tr.sorts) == 1
        t.append({"g": np.array([1], np.int32), "v": np.array([9.0])})
        assert t.cached_group_by("g", 2) is None     # stale, not served
        with trace_execution() as tr:
            v2 = t.group_by("g", 2)
        assert v2 is not v1 and len(tr.sorts) == 1
        assert v2.n_rows == 5

    def test_invalidate_clears_memo(self):
        t = Table.from_columns({"g": np.array([0, 1], np.int32)})
        t.group_by("g", 2)
        t.invalidate()
        assert t.cached_group_by("g", 2) is None
        assert not t._gb_cache


# ---------------------------------------------------------------------------
# Confirmed bug 1: empty-view sentinel blocks
# ---------------------------------------------------------------------------

class TestEmptyViewBlocks:
    def test_aligned_blocks_empty_view_pads_sentinels(self):
        t = Table.from_columns({"g": np.full(8, -1, np.int32),
                                "v": np.arange(8.0)})
        view = t.group_by("g", 3)
        cols, valid, bgids = view.aligned_blocks(4, pad_blocks_to=2)
        assert bgids.shape == (2,)                 # was (0,) before the fix
        np.testing.assert_array_equal(np.asarray(bgids), [3, 3])  # sentinel
        assert valid.shape == (8,) and not bool(valid.any())
        assert cols["v"].shape == (8,)

    def test_aligned_blocks_empty_view_no_pad_keeps_zero_blocks(self):
        t = Table.from_columns({"g": np.full(4, 9, np.int32),
                                "v": np.arange(4.0)})
        view = t.group_by("g", 2)
        cols, valid, bgids = view.aligned_blocks(4)
        assert bgids.shape == (0,) and valid.shape == (0,)

    def test_run_grouped_sharded_empty_view(self, mesh1):
        """The regression the sentinel layout protects: a sharded grouped
        pass over an all-out-of-range view must return init-state
        results for every group."""
        t = Table.from_columns({
            "g": np.full(8, -1, np.int32),
            "x": np.ones((8, 2), np.float32),
            "y": np.ones(8, np.float32),
        })
        view = t.group_by("g", 3)
        out = run_grouped(LinregrAggregate(), view, mesh=mesh1,
                          block_size=4)
        assert np.asarray(out.num_rows).shape == (3,)
        np.testing.assert_array_equal(np.asarray(out.num_rows), np.zeros(3))


# ---------------------------------------------------------------------------
# Confirmed bug 2: derived columns keep the table's sharding
# ---------------------------------------------------------------------------

class TestShardingInvariants:
    def _assert_row_sharded(self, arr):
        assert isinstance(arr.sharding, NamedSharding)
        assert arr.sharding.spec[0] == ("data",)

    def test_with_column_distributes_new_column(self, mesh1):
        t = Table.from_columns({"a": np.arange(8.0)}).distribute(mesh1)
        t2 = t.with_column("b", jnp.arange(8.0))
        self._assert_row_sharded(t2["b"])          # was SingleDeviceSharding

    def test_map_rows_distributes_outputs(self, mesh1):
        t = Table.from_columns({"a": np.arange(8.0)}).distribute(mesh1)
        t2 = t.map_rows(lambda c: {"b": c["a"] * 2.0})
        self._assert_row_sharded(t2["b"])

    def test_pad_to_distributes_padded_columns_and_mask(self, mesh1):
        t = Table.from_columns({"a": np.arange(7.0)})
        t8, _ = t.pad_to(8)
        td = t8.distribute(mesh1)
        padded, mask = td.pad_to(16)
        self._assert_row_sharded(padded["a"])
        self._assert_row_sharded(mask)


# ---------------------------------------------------------------------------
# Materialized handles: delta folds bit-identical to rescans
# ---------------------------------------------------------------------------

class TestMaterializedScan:
    def test_delta_merge_bit_identical_seeded(self):
        for draw in cases(4, base_seed=61):
            n = draw.integers(300, 900)
            m = draw.integers(20, 150)
            t = _dyadic_table(draw, n)
            cm = CountMinAggregate(4, 64, item_col="item")
            fm = FMAggregate(4, 16, item_col="item")
            lr = LinregrAggregate()
            prof = ProfileAggregate()
            h = materialize([
                ScanAgg(cm, t, columns=("item",)),
                ScanAgg(fm, t, columns=("item",)),
                ScanAgg(lr, t, columns={"x": "x", "y": "y"}),
                ScanAgg(prof, t, columns=("x", "y")),
            ])
            h.result()
            t.append(_delta_cols(draw, m))
            with trace_execution() as tr:
                got = h.result()
            assert len(tr.deltas) == 1 and len(tr.scans) == 0, draw
            assert tr.deltas[0].detail["rows"] == m, draw
            # The IVM exactness contract: the delta-MERGED STATE is
            # bit-identical to a full rescan's state (fresh handle over
            # the grown table = pure rescan).  Finalized outputs from
            # identical states may differ by 1 ulp only because final
            # runs in a different jit program than execute's fold+final
            # — so states get bitwise asserts, results get allclose
            # (bitwise for the integer sketch, whose final is identity).
            rescan = materialize([
                ScanAgg(CountMinAggregate(4, 64, item_col="item"), t,
                        columns=("item",)),
                ScanAgg(FMAggregate(4, 16, item_col="item"), t,
                        columns=("item",)),
                ScanAgg(LinregrAggregate(), t,
                        columns={"x": "x", "y": "y"}),
                ScanAgg(ProfileAggregate(), t, columns=("x", "y")),
            ])
            assert _bitwise_equal(h._state, rescan._state), draw
            want = rescan.result()
            assert _bitwise_equal(got[0], want[0]), draw  # int counters
            for g, w in zip(got[1:], want[1:]):
                assert _allclose(g, w), draw

    def test_refresh_is_noop_at_pinned_version(self):
        draw = Draw(7)
        t = _dyadic_table(draw, 200)
        h = materialize(ScanAgg(LinregrAggregate(), t,
                                columns={"x": "x", "y": "y"}))
        h.result()
        with trace_execution() as tr:
            h.result()
        assert not tr.scans and not tr.deltas and not h.stale()

    def test_multiple_appends_chain(self):
        draw = Draw(11)
        t = _dyadic_table(draw, 256)
        h = materialize(ScanAgg(CountMinAggregate(4, 32, item_col="item"),
                                t, columns=("item",)))
        h.result()
        for _ in range(3):
            t.append(_delta_cols(draw, 64))
            h.result()
        rescan = materialize(ScanAgg(
            CountMinAggregate(4, 32, item_col="item"), t,
            columns=("item",)))
        assert _bitwise_equal(h._state, rescan._state)
        assert _bitwise_equal(h.result(), rescan.result())

    def test_invalidate_forces_rescan_and_reflects_mutation(self):
        """After an in-place mutation + invalidate(), the handle must
        not serve the retained (now wrong) state — the
        prepared-statement staleness contract."""
        t = Table.from_columns({"x": np.ones((64, 2), np.float32),
                                "y": np.ones(64, np.float32)})
        h = materialize(ScanAgg(LinregrAggregate(), t,
                                columns={"x": "x", "y": "y"}))
        before = h.result()
        t.columns["y"] = jnp.asarray(np.full(64, 2.0, np.float32))
        t.invalidate()
        with trace_execution() as tr:
            after = h.result()
        assert len(tr.scans) == 1 and len(tr.deltas) == 0
        assert not _allclose(before, after)
        want = execute(ScanAgg(LinregrAggregate(), t,
                               columns={"x": "x", "y": "y"}))
        assert _allclose(after, want)

    def test_masked_statement_rejected(self):
        t = Table.from_columns({"y": np.arange(8.0)})
        mask = jnp.ones(8, bool)
        with pytest.raises(ValueError, match="mask"):
            materialize(ScanAgg(ProfileAggregate(), t, mask=mask))

    def test_mixed_tables_rejected(self):
        t1 = Table.from_columns({"y": np.arange(8.0)})
        t2 = Table.from_columns({"y": np.arange(8.0)})
        with pytest.raises(ValueError, match="different tables"):
            materialize([ScanAgg(ProfileAggregate(), t1),
                         ScanAgg(ProfileAggregate(), t2)])


class TestMaterializedGrouped:
    def test_grouped_delta_bit_identical_and_sorts_only_delta(self):
        for draw in cases(3, base_seed=71):
            n = draw.integers(300, 800)
            m = draw.integers(16, 120)
            G = 5
            t = _dyadic_table(draw, n, groups=G)
            h = materialize(GroupedScanAgg(
                LinregrAggregate(), t, "g", num_groups=G,
                columns={"x": "x", "y": "y"}))
            h.result()
            t.append(_delta_cols(draw, m, groups=G))
            with trace_execution() as tr:
                got = h.result()
            assert len(tr.deltas) == 1 and len(tr.scans) == 0, draw
            # fresh sort only over the delta, never the full table
            assert [e.detail["n_rows"] for e in tr.sorts] == [m], draw
            rescan = materialize(GroupedScanAgg(
                LinregrAggregate(), t, "g", num_groups=G,
                columns={"x": "x", "y": "y"}))
            assert _bitwise_equal(h._state, rescan._state), draw
            assert _allclose(got, rescan.result()), draw

    def test_new_group_id_forces_rescan(self):
        draw = Draw(5)
        t = _dyadic_table(draw, 200, groups=3)
        t.columns["g"] = jnp.asarray(
            np.minimum(np.asarray(t["g"]), 2).astype(np.int32))
        h = materialize(GroupedScanAgg(
            LinregrAggregate(), t, "g", columns={"x": "x", "y": "y"}))
        assert np.asarray(h.result().num_rows).shape == (3,)
        delta = _delta_cols(draw, 32, groups=3)
        delta["g"] = np.full(32, 7, np.int32)  # a key outside pinned G
        t.append(delta)
        with trace_execution() as tr:
            got = h.result()
        assert len(tr.scans) == 1 and len(tr.deltas) == 0
        assert np.asarray(got.num_rows).shape == (8,)  # G regrew like a full run
        want = execute(GroupedScanAgg(
            LinregrAggregate(), t, "g", columns={"x": "x", "y": "y"}))
        assert _allclose(got, want)

    def test_fixed_group_count_drops_out_of_range_delta_keys(self):
        draw = Draw(13)
        t = _dyadic_table(draw, 200, groups=4)
        h = materialize(GroupedScanAgg(
            LinregrAggregate(), t, "g", num_groups=4,
            columns={"x": "x", "y": "y"}))
        h.result()
        delta = _delta_cols(draw, 24, groups=4)
        delta["g"][:8] = 9  # out of range under num_groups=4: dropped
        t.append(delta)
        with trace_execution() as tr:
            got = h.result()
        assert len(tr.deltas) == 1
        rescan = materialize(GroupedScanAgg(
            LinregrAggregate(), t, "g", num_groups=4,
            columns={"x": "x", "y": "y"}))
        assert _bitwise_equal(h._state, rescan._state)
        assert _allclose(got, rescan.result())

    def test_prebuilt_view_rejected(self):
        t = Table.from_columns({"g": np.zeros(8, np.int32),
                                "y": np.arange(8.0)})
        view = t.group_by("g", 1)
        with pytest.raises(TypeError, match="GroupedView"):
            materialize(GroupedScanAgg(ProfileAggregate(), view))


# ---------------------------------------------------------------------------
# Plan-layer staleness: cost-model group resolution
# ---------------------------------------------------------------------------

class TestPlanStaleness:
    def test_resolve_groups_never_reads_outdated_view(self):
        t = Table.from_columns({"g": np.array([0, 1, 2, 3], np.int32),
                                "y": np.arange(4.0)})
        out = execute(GroupedScanAgg(ProfileAggregate(), t, "g",
                                     columns=("y",)))
        assert np.asarray(out["y"]["count"]).shape[0] == 4  # memoized G=4
        t.append({"g": np.array([9], np.int32), "y": np.array([9.0])})
        out = execute(GroupedScanAgg(ProfileAggregate(), t, "g",
                                     columns=("y",)))
        # before the accessor fix this reused the stale view's G=4
        assert np.asarray(out["y"]["count"]).shape[0] == 10


# ---------------------------------------------------------------------------
# Session front-end
# ---------------------------------------------------------------------------

class TestSessionLivingViews:
    def test_session_materialize_and_refresh(self):
        draw = Draw(3)
        t = _dyadic_table(draw, 300)
        sess = Session()
        h = sess.materialize(
            ScanAgg(CountMinAggregate(4, 32, item_col="item"), t,
                    columns=("item",)),
            ScanAgg(LinregrAggregate(), t, columns={"x": "x", "y": "y"}))
        t.append(_delta_cols(draw, 50))
        with trace_execution() as tr:
            (res,) = sess.refresh()
        assert len(tr.deltas) == 1 and len(tr.scans) == 0
        want = execute(ScanAgg(CountMinAggregate(4, 32, item_col="item"),
                               t, columns=("item",)))
        assert _bitwise_equal(res[0], want)  # identity final: exact
        assert h in sess._materialized
