"""Prepared-statement cache behavior.

The engines memoize compiled programs static on the aggregate INSTANCE
(``_LOCAL_JIT_CACHE``, ``_SEGMENT_JIT_CACHE``, ``_STREAM_JIT_CACHE``)
and the plan layer memoizes fused/projected wrappers.  This file pins
the lifecycle contracts those docstrings promise:

* bounded FIFO — filling a cache past its max evicts the oldest entry,
  and eviction actually DROPS the compiled program (weakref dies after
  gc), so one-shot aggregates cannot accumulate executables;
* a live entry pins its aggregate, so ``id()`` keys cannot be reused by
  new objects while the entry lives;
* a cache hit after ``Table.append`` (same epoch — rows grew, existing
  rows untouched) stays CORRECT: the jit object retraces on the new
  shapes, the cached entry is reused, and results match a fresh
  aggregate's.
"""

import gc
import weakref

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Table, run_grouped, run_local
from repro.core import aggregates as agg_mod
from repro.methods.sketches import CountMinAggregate

G = 3


def _table(n=96, seed=0):
    rng = np.random.default_rng(seed)
    return Table.from_columns({
        "item": jnp.asarray(rng.integers(0, 50, n).astype(np.int32)),
        "g": jnp.asarray((np.arange(n) % G).astype(np.int32)),
    })


def _fresh(depth=4, width=128, **kw):
    return CountMinAggregate(depth, width, **kw)


@pytest.fixture(autouse=True)
def _clean_caches():
    agg_mod._SEGMENT_JIT_CACHE.clear()
    agg_mod._LOCAL_JIT_CACHE.clear()
    yield
    agg_mod._SEGMENT_JIT_CACHE.clear()
    agg_mod._LOCAL_JIT_CACHE.clear()


def test_segment_jit_cache_hit_and_fifo_eviction(monkeypatch):
    monkeypatch.setattr(agg_mod, "_SEGMENT_JIT_MAX", 2)
    tbl = _table()
    a0 = _fresh()
    run_grouped(a0, tbl, "g", G)
    assert len(agg_mod._SEGMENT_JIT_CACHE) == 1
    key0, (pinned, fn0) = next(iter(agg_mod._SEGMENT_JIT_CACHE.items()))
    assert pinned is a0                     # live entry pins its aggregate
    run_grouped(a0, tbl, "g", G)
    assert agg_mod._SEGMENT_JIT_CACHE[key0][1] is fn0   # hit, not rebuild

    dead = weakref.ref(fn0)
    dead_agg = weakref.ref(a0)
    # two more distinct aggregates evict the oldest entry (FIFO, max=2)
    for seed in (1, 2):
        run_grouped(_fresh(), tbl, "g", G)
    assert len(agg_mod._SEGMENT_JIT_CACHE) == 2
    assert key0 not in agg_mod._SEGMENT_JIT_CACHE
    del a0, fn0, pinned
    gc.collect()
    # eviction dropped the compiled program AND released the aggregate
    assert dead() is None
    assert dead_agg() is None


def test_segment_jit_key_includes_kernel_impl(recwarn):
    """The same aggregate instance resolved to different kernel impls
    must compile different programs (the kernel branch changes the
    traced graph) — seg_impl is part of the cache key."""
    tbl = _table()
    a_ref = _fresh(use_kernel="ref")
    a_none = _fresh()
    run_grouped(a_ref, tbl, "g", G)
    run_grouped(a_none, tbl, "g", G)
    impls = {k[-1] for k in agg_mod._SEGMENT_JIT_CACHE}
    assert impls == {"ref", None}


def test_segment_cache_hit_after_append_same_epoch_stays_correct():
    tbl = _table()
    agg = _fresh()
    before = run_grouped(agg, tbl, "g", G)
    assert np.asarray(before).shape == (G, 4, 128)
    (key, _), = agg_mod._SEGMENT_JIT_CACHE.items()
    epoch = tbl.epoch

    rng = np.random.default_rng(7)
    tbl.append({"item": jnp.asarray(rng.integers(0, 50, 33).astype(np.int32)),
                "g": jnp.asarray(rng.integers(0, G, 33).astype(np.int32))})
    assert tbl.epoch == epoch               # append-only: same epoch

    after = run_grouped(agg, tbl, "g", G)   # same instance -> cache hit
    assert key in agg_mod._SEGMENT_JIT_CACHE
    fresh = run_grouped(_fresh(), tbl, "g", G)
    np.testing.assert_array_equal(np.asarray(after), np.asarray(fresh))
    assert int(np.asarray(after).sum()) > int(np.asarray(before).sum())


def test_local_jit_cache_fifo_and_weakref(monkeypatch):
    monkeypatch.setattr(agg_mod, "_LOCAL_JIT_MAX", 2)
    tbl = _table()
    a0 = _fresh()
    run_local(a0, tbl, block_size=32)
    (key0, (pinned, fn0)), = agg_mod._LOCAL_JIT_CACHE.items()
    assert pinned is a0
    dead = weakref.ref(fn0)
    for _ in range(2):
        run_local(_fresh(), tbl, block_size=32)
    assert key0 not in agg_mod._LOCAL_JIT_CACHE
    assert len(agg_mod._LOCAL_JIT_CACHE) == 2
    del a0, fn0, pinned
    gc.collect()
    assert dead() is None


def test_local_cache_hit_after_append_same_epoch_stays_correct():
    tbl = _table(seed=5)
    agg = _fresh()
    run_local(agg, tbl)
    tbl.append({"item": jnp.asarray(np.arange(17, dtype=np.int32)),
                "g": jnp.asarray((np.arange(17) % G).astype(np.int32))})
    after = run_local(agg, tbl)             # cached program, new shapes
    fresh = run_local(_fresh(), tbl)
    np.testing.assert_array_equal(np.asarray(after), np.asarray(fresh))
