"""Engine-parity matrix: every one-pass method × every execution engine.

The framework's correctness argument is that HOW an aggregate executes —
local blocked fold, host-side stream, sharded two-phase fold, partitioned
grouped segments, masked per-group vmap, or the sharded grouped engine —
never changes WHAT it computes.  This suite pins that down as a matrix:
for each one-pass method and each generated group layout
(``tests/strategies.py``: empty / singleton / non-contiguous / skewed
groups), all six engines must produce the per-group solo fold's state —
BIT-IDENTICAL for exact-state cases (integer sketches, dyadic-exact
features, min/max extremes), allclose for ordinary f32 data.

States are compared rather than finals (``_RawState`` makes ``final``
the identity) so the check isolates the fold/merge contract — the part
each engine implements differently — from the shared ``final`` math
(whose batched-vs-solo ulp wiggle, e.g. vmapped ``eigh``, is covered by
the grouped oracle tests).
"""

import warnings
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Table, run_grouped, run_local, run_sharded, \
    run_stream
from repro.core.aggregates import Aggregate
from repro.core.templates import ProfileAggregate
from repro.methods.linregr import LinregrAggregate
from repro.methods.naive_bayes import NaiveBayesAggregate
from repro.methods.sketches import CountMinAggregate, FMAggregate

from strategies import Draw, group_layout, join_layout

N, G = 160, 4
STREAM_BS = 48

ENGINES = ("local", "stream", "sharded", "grouped-segment",
           "grouped-masked", "sharded-grouped")


class _RawState(Aggregate):
    """final = identity wrapper: engines return raw fold states, so the
    matrix compares exactly the engine-specific part of the pipeline."""

    def __init__(self, inner: Aggregate):
        self.inner = inner

    @property
    def merge_ops(self):
        return self.inner.merge_ops

    def init(self, block):
        return self.inner.init(block)

    def transition(self, state, block, mask):
        return self.inner.transition(state, block, mask)

    def merge(self, a, b):
        return self.inner.merge(a, b)

    def segment_ops(self, state):
        return self.inner.segment_ops(state)

    def mesh_merge(self, state, axes):
        return self.inner.mesh_merge(state, axes)

    def final(self, state):
        return state


# name -> (columns builder, aggregate factory, exact-state?)
def _linregr_cols(draw):
    return {"x": draw.dyadic((N, 3)), "y": draw.dyadic((N,))}


def _profile_cols(draw):
    return {"v": draw.dyadic((N,)), "w": draw.dyadic((N, 2))}


def _profile_f32_cols(draw):
    return {"v": draw.normal((N,))}


def _nb_cols(draw):
    return {"x": draw.dyadic((N, 3)),
            "y": draw.ints((N,), 0, 2).astype(np.float32)}


def _item_cols(draw):
    return {"item": draw.ints((N,), 0, 40)}


CASES = {
    "linregr": (_linregr_cols, LinregrAggregate, True),
    "profile": (_profile_cols, ProfileAggregate, True),
    "profile_f32": (_profile_f32_cols, ProfileAggregate, False),
    "naive_bayes": (_nb_cols, lambda: NaiveBayesAggregate(3), True),
    "countmin": (_item_cols, lambda: CountMinAggregate(4, 128), True),
    "fm": (_item_cols, lambda: FMAggregate(4, 16), True),
}

PATTERNS = ("empty", "singleton", "non_contiguous", "skewed")


def _assert_leaves(got, want, exact, msg):
    gl, wl = jax.tree.leaves(got), jax.tree.leaves(want)
    assert len(gl) == len(wl), msg
    for a, b in zip(gl, wl):
        if exact:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=msg)
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6, err_msg=msg)


def _stack(trees):
    return jax.tree.map(
        lambda *xs: np.stack([np.asarray(x) for x in xs]), *trees)


@pytest.mark.parametrize("pattern", PATTERNS)
@pytest.mark.parametrize("name", sorted(CASES))
def test_engine_parity_matrix(name, pattern, mesh1):
    build, make_agg, exact = CASES[name]
    draw = Draw(zlib.crc32(f"{name}/{pattern}".encode()))
    gids_np, _ = group_layout(draw, N, G, pattern)
    cols = {k: jnp.asarray(v) for k, v in build(draw).items()}
    gids = jnp.asarray(gids_np)
    tbl = Table.from_columns(dict(cols, g=gids))
    data_tbl = Table.from_columns(cols)
    dist_tbl = data_tbl.distribute(mesh1)

    # the per-group solo oracle == the "local" engine (masked fold)
    ref = _stack([run_local(_RawState(make_agg()), data_tbl,
                            mask=gids == g) for g in range(G)])

    # stream: per group, the group's own rows in host-side blocks
    got_stream, stream_groups = [], []
    for g in range(G):
        rows = np.where(gids_np == g)[0]
        if not len(rows):
            continue  # run_stream rejects empty streams by contract
        sub = {k: np.asarray(v)[rows] for k, v in cols.items()}
        blocks = [{k: v[i:i + STREAM_BS] for k, v in sub.items()}
                  for i in range(0, len(rows), STREAM_BS)]
        got_stream.append(run_stream(_RawState(make_agg()), iter(blocks)))
        stream_groups.append(g)
    ref_stream = jax.tree.map(lambda x: x[np.asarray(stream_groups)], ref)
    _assert_leaves(_stack(got_stream), ref_stream, exact,
                   f"stream {name}/{pattern} {draw}")

    # sharded: two-phase fold with the new fold-level base mask
    got_sharded = _stack([
        run_sharded(_RawState(make_agg()), dist_tbl, mask=gids == g)
        for g in range(G)])
    _assert_leaves(got_sharded, ref, exact,
                   f"sharded {name}/{pattern} {draw}")

    # grouped engines: segment core, masked fallback, sharded grouped
    grouped_runs = {
        "grouped-segment": dict(method="segment"),
        "grouped-masked": dict(method="masked"),
        "sharded-grouped": dict(method="segment", mesh=mesh1),
    }
    for engine, kw in grouped_runs.items():
        got = run_grouped(_RawState(make_agg()), tbl, "g", G, **kw)
        _assert_leaves(got, ref, exact, f"{engine} {name}/{pattern} {draw}")


# -- segment-fold kernel parity -----------------------------------------------
#
# The registered Pallas segment-fold kernels (kernels/segment_fold) must
# be BIT-identical to the generic jnp segment fold on every grouped
# engine.  Off-TPU (CI) the forced "pallas" impl runs the kernel BODY in
# interpret mode — same arithmetic, same guarantee.

KERNEL_CASES = {
    "linregr": (_linregr_cols,
                lambda uk: LinregrAggregate(use_kernel=uk)),
    "countmin": (_item_cols,
                 lambda uk: CountMinAggregate(4, 128, use_kernel=uk)),
    "fm": (_item_cols,
           lambda uk: FMAggregate(4, 16, use_kernel=uk)),
}


@pytest.mark.parametrize("pattern", ("empty", "skewed"))
@pytest.mark.parametrize("name", sorted(KERNEL_CASES))
@pytest.mark.parametrize("impl", ("ref", "pallas"))
def test_segment_kernel_grouped_parity(name, pattern, impl, mesh1):
    build, make = KERNEL_CASES[name]
    draw = Draw(zlib.crc32(f"kern/{name}/{pattern}".encode()))
    gids_np, _ = group_layout(draw, N, G, pattern)
    cols = {k: jnp.asarray(v) for k, v in build(draw).items()}
    tbl = Table.from_columns(dict(cols, g=jnp.asarray(gids_np)))
    for kw in (dict(), dict(mesh=mesh1)):
        base = run_grouped(make(False), tbl, "g", G, method="segment",
                           finalize=False, **kw)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")  # forced-pallas interpret note
            got = run_grouped(make(impl), tbl, "g", G, method="segment",
                              finalize=False, **kw)
        _assert_leaves(got, base, True,
                       f"kernel {name}/{pattern}/{impl} {kw} {draw}")


@pytest.mark.parametrize("impl", ("ref", "pallas"))
def test_fit_grouped_kernel_parity(impl, mesh1):
    """The iterative grouped executor with kernel-routed transitions is
    bit-identical to the inline jnp transitions, locally and sharded."""
    from repro.core import fit_grouped
    from repro.methods.linregr import LinregrTask
    draw = Draw(7)
    gids_np, _ = group_layout(draw, N, G, "skewed")
    tbl = Table.from_columns({"x": jnp.asarray(draw.dyadic((N, 3))),
                              "y": jnp.asarray(draw.dyadic((N,))),
                              "g": jnp.asarray(gids_np)})
    for kw in (dict(), dict(mesh=mesh1)):
        base = fit_grouped(LinregrTask(), tbl, "g", G, max_iters=1,
                           tol=None, **kw)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            got = fit_grouped(LinregrTask(use_kernel=impl), tbl, "g", G,
                              max_iters=1, tol=None, **kw)
        _assert_leaves(got.result.coef, base.result.coef, True,
                       f"fit_grouped kernel {impl} {kw}")


@pytest.mark.parametrize("pattern", ("clean", "skewed", "dup_attr"))
@pytest.mark.parametrize("name", ("linregr", "countmin"))
def test_joined_grouped_parity(name, pattern, mesh1):
    """The joined-grouped row of the matrix: ``fact JOIN dim GROUP BY
    dim.attr`` through the device-side sort-merge join must equal a
    materialize-then-group oracle (numpy key lookup, same grouped
    engine) BIT-identically — locally and on the sharded grouped
    engine."""
    from repro.core import JoinedGroupedScanAgg, execute
    from repro.core.join import Join

    build, make_agg, _ = CASES[name]
    draw = Draw(zlib.crc32(f"join/{name}/{pattern}".encode()))
    fk, keys, attr, _ = join_layout(draw, N, 3 * G, G, pattern)
    cols = {k: jnp.asarray(v) for k, v in build(draw).items()}
    fact = Table.from_columns(dict(cols, fk=jnp.asarray(fk)))
    dim = Table.from_columns({"key": jnp.asarray(keys),
                              "region": jnp.asarray(attr)})
    lookup = {int(k): int(a) for k, a in zip(keys, attr)}
    gids = np.array([lookup[int(f)] for f in fk], np.int32)
    groups = int(attr.max()) + 1

    agg_cols = ({"x": "x", "y": "y"} if name == "linregr" else ("item",))
    ref = run_grouped(_RawState(make_agg()),
                      Table.from_columns(dict(cols, g=jnp.asarray(gids))),
                      "g", groups, method="segment")
    for kw in (dict(), dict(mesh=mesh1)):
        f = fact.distribute(mesh1) if kw else fact
        got = execute(JoinedGroupedScanAgg(
            _RawState(make_agg()), Join(f, dim, "fk", "key", "region"),
            groups, columns=agg_cols, method="segment", **kw))
        _assert_leaves(got, ref, True,
                       f"joined {name}/{pattern} {kw} {draw}")


def test_final_results_ride_the_states(mesh1):
    """End-to-end spot check that engine-level state parity carries to the
    user-facing results: grouped profile finals equal the vmapped final
    of the solo states on every engine that stacks per-group output."""
    draw = Draw(99)
    gids_np, _ = group_layout(draw, N, G, "skewed")
    tbl = Table.from_columns({"v": jnp.asarray(draw.dyadic((N,))),
                              "g": jnp.asarray(gids_np)})
    data = Table.from_columns({"v": tbl["v"]})
    agg = ProfileAggregate()
    states = _stack([run_local(_RawState(ProfileAggregate()), data,
                               mask=tbl["g"] == g) for g in range(G)])
    want = jax.vmap(agg.final)(jax.tree.map(jnp.asarray, states))
    for kw in (dict(method="segment"), dict(method="masked"),
               dict(method="segment", mesh=mesh1)):
        got = run_grouped(ProfileAggregate(), tbl, "g", G, **kw)
        for stat in ("count", "sum", "mean", "std", "min", "max"):
            np.testing.assert_allclose(
                np.asarray(got["v"][stat]), np.asarray(want["v"][stat]),
                rtol=1e-6, atol=1e-6, err_msg=f"{kw} {stat}")
