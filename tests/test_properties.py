"""Property-based tests on the system's invariants — hypothesis-free.

The parallelization contract of the whole framework is UDA merge
associativity/commutativity + partitioning invariance — these properties
ARE the paper's correctness argument for Figure 4, so they get the
heaviest property coverage.  Cases come from the seeded generator
library in ``tests/strategies.py`` (no hypothesis dependency: the suite
runs everywhere); every assertion message embeds the case seed, so a
failure replays with ``strategies.Draw(seed)``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Table, run_grouped, run_local
from repro.core.templates import ProfileAggregate
from repro.methods.linregr import LinregrAggregate

from strategies import Draw, cases, group_layout

jax.config.update("jax_platform_name", "cpu")

N_CASES = 8  # per-property seeded cases; keeps tier-1 under the 10-min gate


def _table(draw, n, d):
    return Table.from_columns({
        "x": jnp.asarray(draw.normal((n, d))),
        "y": jnp.asarray(draw.normal((n,))),
    })


def test_merge_consistency_arbitrary_split():
    """state(A ∪ B) == merge(state(A), state(B)) for any row split."""
    for draw in cases(N_CASES, base_seed=1):
        n, d = draw.integers(16, 300), draw.integers(1, 8)
        cut = draw.floats(0.1, 0.9)
        tbl = _table(draw, n, d)
        agg = LinregrAggregate()
        k = max(1, int(n * cut))
        full_mask = jnp.ones((n,), jnp.bool_)

        def fold(cols, m):
            return agg.transition(agg.init(cols), cols, m)

        whole = fold(dict(tbl.columns), full_mask)
        a = fold({c: v[:k] for c, v in tbl.columns.items()},
                 jnp.ones((k,), jnp.bool_))
        b = fold({c: v[k:] for c, v in tbl.columns.items()},
                 jnp.ones((n - k,), jnp.bool_))
        merged = agg.merge(a, b)
        for leaf_w, leaf_m in zip(jax.tree.leaves(whole),
                                  jax.tree.leaves(merged)):
            np.testing.assert_allclose(
                np.asarray(leaf_w), np.asarray(leaf_m), rtol=2e-4,
                atol=1e-4, err_msg=f"{draw}")


def test_merge_commutativity():
    for draw in cases(N_CASES, base_seed=2):
        n, d = draw.integers(16, 300), draw.integers(1, 6)
        tbl = _table(draw, n, d)
        agg = ProfileAggregate()
        k = n // 2

        def fold(cols, nn):
            return agg.transition(agg.init(cols), cols,
                                  jnp.ones((nn,), jnp.bool_))

        a = fold({c: v[:k] for c, v in tbl.columns.items()}, k)
        # merge_ops synthesized per init call; reuse same agg for both folds
        b = fold({c: v[k:] for c, v in tbl.columns.items()}, n - k)
        ab = agg.merge(a, b)
        ba = agg.merge(b, a)
        for la, lb in zip(jax.tree.leaves(ab), jax.tree.leaves(ba)):
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       rtol=1e-5, atol=1e-6,
                                       err_msg=f"{draw}")


def test_block_size_invariance():
    """Blocked fold (incl. ragged tail padding) == single transition."""
    for draw in cases(N_CASES, base_seed=3):
        n, d = draw.integers(32, 400), draw.integers(1, 6)
        bs = draw.sample([None, 16, 33, 64, 128])
        tbl = _table(draw, n, d)
        base = run_local(LinregrAggregate(), tbl, block_size=None)
        blocked = run_local(LinregrAggregate(), tbl, block_size=bs)
        np.testing.assert_allclose(
            np.asarray(base.coef), np.asarray(blocked.coef), rtol=5e-3,
            atol=1e-3, err_msg=f"{draw} bs={bs}")


def test_grouped_strategies_match_on_generated_layouts():
    """segment and masked GROUP BY strategies agree on every layout class
    the generator produces (empty/singleton/non-contiguous/skewed...)."""
    for draw in cases(6, base_seed=4):
        n = draw.integers(40, 250)
        G = draw.integers(2, 8)
        gids, pattern = group_layout(draw, n, G)
        tbl = Table.from_columns({
            "v": jnp.asarray(draw.normal((n,))),
            "g": jnp.asarray(gids),
        })
        seg = run_grouped(ProfileAggregate(), tbl, "g", G, method="segment")
        msk = run_grouped(ProfileAggregate(), tbl, "g", G, method="masked")
        for stat in ("count", "sum", "min", "max"):
            np.testing.assert_allclose(
                np.asarray(seg["v"][stat]), np.asarray(msk["v"][stat]),
                rtol=1e-5, atol=1e-5,
                err_msg=f"{draw} pattern={pattern} stat={stat}")


def test_countmin_never_underestimates():
    from repro.methods.sketches import countmin_query, countmin_sketch
    for draw in cases(N_CASES, base_seed=5):
        n = draw.integers(64, 512)
        n_items = draw.integers(2, 50)
        items = draw.ints((n,), 0, n_items - 1)
        tbl = Table.from_columns({"item": jnp.asarray(items)})
        sk = countmin_sketch(tbl, depth=4, width=256)
        est = np.asarray(countmin_query(sk, jnp.arange(n_items)))
        true = np.bincount(items, minlength=n_items)
        assert np.all(est >= true), f"{draw}"


def test_rle_roundtrip():
    from repro.methods.sparse_vector import rle_decode, rle_encode
    for draw in cases(N_CASES, base_seed=6):
        n_runs = draw.integers(1, 12)
        runs = [(round(draw.floats(-5, 5), 2), draw.integers(1, 20))
                for _ in range(n_runs)]
        dense = np.repeat([v for v, _ in runs],
                          [r for _, r in runs]).astype(np.float32)
        v = rle_encode(jnp.asarray(dense), capacity=32)
        np.testing.assert_array_equal(np.asarray(rle_decode(v)), dense,
                                      err_msg=f"{draw}")


def test_profile_bounds():
    """min <= mean <= max and std >= 0 for arbitrary data/ranges."""
    for draw in cases(N_CASES, base_seed=7):
        n = draw.integers(10, 200)
        lo = draw.floats(-100, 0)
        hi = draw.floats(1, 100)
        v = jnp.asarray(draw.uniform((n,), lo, hi))
        out = run_local(ProfileAggregate(), Table.from_columns({"v": v}))["v"]
        assert float(out["min"]) - 1e-5 <= float(out["mean"]) <= \
            float(out["max"]) + 1e-5, f"{draw}"
        assert float(out["std"]) >= 0.0, f"{draw}"
        assert float(out["count"]) == n, f"{draw}"


def test_viterbi_is_argmax_over_samples():
    """Viterbi path log-prob >= log-prob of random labelings (optimality)."""
    from repro.methods.crf import (crf_init_params, crf_log_likelihood,
                                   extract_features, viterbi_decode)
    for draw in cases(5, base_seed=8):
        k = jax.random.PRNGKey(draw.integers(0, 2 ** 16))
        k1, k2, k3 = jax.random.split(k, 3)
        toks = jax.random.randint(k1, (2, 7), 0, 20)
        feats = extract_features(toks, 32)
        mask = jnp.ones((2, 7), jnp.float32)
        params = crf_init_params(32, 3, k2, scale=0.5)
        vit = viterbi_decode(params, feats, mask)
        ll_vit = float(crf_log_likelihood(params, feats, vit, mask))
        for i in range(5):
            rnd = jax.random.randint(jax.random.fold_in(k3, i), (2, 7), 0, 3)
            ll_rnd = float(crf_log_likelihood(params, feats, rnd, mask))
            assert ll_vit >= ll_rnd - 1e-4, f"{draw}"
