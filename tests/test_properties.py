"""Property-based tests (hypothesis) on the system's invariants.

The parallelization contract of the whole framework is UDA merge
associativity/commutativity + partitioning invariance — these properties
ARE the paper's correctness argument for Figure 4, so they get the
heaviest property coverage.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import Table, run_local
from repro.core.aggregates import Aggregate
from repro.methods.linregr import LinregrAggregate
from repro.core.templates import ProfileAggregate

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=20, deadline=None)


def _table(n, d, seed):
    k = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(k)
    return Table.from_columns({
        "x": jax.random.normal(kx, (n, d)),
        "y": jax.random.normal(ky, (n,)),
    })


@given(n=st.integers(16, 300), d=st.integers(1, 8),
       seed=st.integers(0, 2 ** 16),
       cut=st.floats(0.1, 0.9))
@settings(**SETTINGS)
def test_merge_consistency_arbitrary_split(n, d, seed, cut):
    """state(A ∪ B) == merge(state(A), state(B)) for any row split."""
    tbl = _table(n, d, seed)
    agg = LinregrAggregate()
    k = max(1, int(n * cut))
    full_mask = jnp.ones((n,), jnp.bool_)

    def fold(cols, m):
        return agg.transition(agg.init(cols), cols, m)

    whole = fold(dict(tbl.columns), full_mask)
    a = fold({c: v[:k] for c, v in tbl.columns.items()},
             jnp.ones((k,), jnp.bool_))
    b = fold({c: v[k:] for c, v in tbl.columns.items()},
             jnp.ones((n - k,), jnp.bool_))
    merged = agg.merge(a, b)
    for leaf_w, leaf_m in zip(jax.tree.leaves(whole),
                              jax.tree.leaves(merged)):
        np.testing.assert_allclose(np.asarray(leaf_w), np.asarray(leaf_m),
                                   rtol=2e-4, atol=1e-4)


@given(n=st.integers(16, 300), d=st.integers(1, 6),
       seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_merge_commutativity(n, d, seed):
    tbl = _table(n, d, seed)
    agg = ProfileAggregate()
    k = n // 2

    def fold(cols, nn):
        return agg.transition(agg.init(cols), cols,
                              jnp.ones((nn,), jnp.bool_))

    a = fold({c: v[:k] for c, v in tbl.columns.items()}, k)
    # merge_ops synthesized per init call; reuse same agg for both folds
    b = fold({c: v[k:] for c, v in tbl.columns.items()}, n - k)
    ab = agg.merge(a, b)
    ba = agg.merge(b, a)
    for la, lb in zip(jax.tree.leaves(ab), jax.tree.leaves(ba)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-5, atol=1e-6)


@given(n=st.integers(32, 400), d=st.integers(1, 6),
       seed=st.integers(0, 2 ** 16),
       bs=st.sampled_from([None, 16, 33, 64, 128]))
@settings(**SETTINGS)
def test_block_size_invariance(n, d, seed, bs):
    """Blocked fold (incl. ragged tail padding) == single transition."""
    tbl = _table(n, d, seed)
    base = run_local(LinregrAggregate(), tbl, block_size=None)
    blocked = run_local(LinregrAggregate(), tbl, block_size=bs)
    np.testing.assert_allclose(np.asarray(base.coef),
                               np.asarray(blocked.coef), rtol=5e-3,
                               atol=1e-3)


@given(n=st.integers(64, 512), seed=st.integers(0, 2 ** 16),
       n_items=st.integers(2, 50))
@settings(**SETTINGS)
def test_countmin_never_underestimates(n, seed, n_items):
    from repro.methods.sketches import countmin_query, countmin_sketch
    k = jax.random.PRNGKey(seed)
    items = jax.random.randint(k, (n,), 0, n_items)
    tbl = Table.from_columns({"item": items})
    sk = countmin_sketch(tbl, depth=4, width=256)
    est = np.asarray(countmin_query(sk, jnp.arange(n_items)))
    true = np.bincount(np.asarray(items), minlength=n_items)
    assert np.all(est >= true)


@given(runs=st.lists(
    st.tuples(st.floats(-5, 5).map(lambda v: round(v, 2)),
              st.integers(1, 20)),
    min_size=1, max_size=12))
@settings(**SETTINGS)
def test_rle_roundtrip(runs):
    from repro.methods.sparse_vector import rle_decode, rle_encode
    dense = np.repeat([v for v, _ in runs],
                      [r for _, r in runs]).astype(np.float32)
    v = rle_encode(jnp.asarray(dense), capacity=32)
    np.testing.assert_array_equal(np.asarray(rle_decode(v)), dense)


@given(seed=st.integers(0, 2 ** 16), n=st.integers(10, 200),
       lo=st.floats(-100, 0), hi=st.floats(1, 100))
@settings(**SETTINGS)
def test_profile_bounds(seed, n, lo, hi):
    """min <= mean <= max and std >= 0 for arbitrary data/ranges."""
    k = jax.random.PRNGKey(seed)
    v = jax.random.uniform(k, (n,), minval=lo, maxval=hi)
    out = run_local(ProfileAggregate(), Table.from_columns({"v": v}))["v"]
    assert float(out["min"]) - 1e-5 <= float(out["mean"]) <= \
        float(out["max"]) + 1e-5
    assert float(out["std"]) >= 0.0
    assert float(out["count"]) == n


@given(seed=st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_viterbi_is_argmax_over_samples(seed):
    """Viterbi path log-prob >= log-prob of random labelings (optimality)."""
    from repro.methods.crf import (crf_init_params, crf_log_likelihood,
                                   extract_features, viterbi_decode)
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    toks = jax.random.randint(k1, (2, 7), 0, 20)
    feats = extract_features(toks, 32)
    mask = jnp.ones((2, 7), jnp.float32)
    params = crf_init_params(32, 3, k2, scale=0.5)
    vit = viterbi_decode(params, feats, mask)
    ll_vit = float(crf_log_likelihood(params, feats, vit, mask))
    for i in range(5):
        rnd = jax.random.randint(jax.random.fold_in(k3, i), (2, 7), 0, 3)
        ll_rnd = float(crf_log_likelihood(params, feats, rnd, mask))
        assert ll_vit >= ll_rnd - 1e-4
