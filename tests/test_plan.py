"""Logical-plan layer: scan sharing, sort dedup, cost-based engine
selection, loud mixed-mask rejection, golden EXPLAIN plans, and planned
vs per-statement-direct parity (bit-identical for exact-state
aggregates).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ENGINE_CAPS, GroupedScanAgg, ProfileAggregate, ScanAgg, Session,
    StreamAgg, Table, execute, plan, run_grouped, run_local,
    trace_execution,
)
from repro.core.plan import (
    fused_scan_pass, select_grouped_method, select_scan_engine,
)
from repro.methods.linregr import LinregrAggregate
from repro.methods.quantiles import HistogramAggregate
from repro.methods.sketches import CountMinAggregate, FMAggregate

N, GROUPS = 512, 4


@pytest.fixture(scope="module")
def table(key):
    kx, ky, ki = jax.random.split(key, 3)
    return Table.from_columns({
        "x": jax.random.normal(kx, (N, 3)),
        "y": jax.random.normal(ky, (N,)),
        "item": jax.random.randint(ki, (N,), 0, 100),
        "g": (jnp.arange(N) % GROUPS).astype(jnp.int32),
    })


def _cm():
    return CountMinAggregate(depth=4, width=256, item_col="item")


def _fm():
    return FMAggregate(num_hashes=4, bits=16, item_col="item")


def _hist():
    return HistogramAggregate(-4.0, 4.0, bins=64, value_col="y")


# -- capability matrix & cost model -------------------------------------------

def test_capability_matrix_shape():
    assert set(ENGINE_CAPS) == {
        "local", "sharded", "stream", "grouped-segment", "grouped-masked",
        "sharded-grouped"}
    for caps in ENGINE_CAPS.values():
        assert set(caps) == {"mask", "group_by", "fit", "stream"}
    assert not ENGINE_CAPS["stream"]["mask"]
    assert ENGINE_CAPS["sharded-grouped"]["group_by"]


class _FakeMesh:
    def __init__(self, segs):
        self.shape = {"data": segs}


def test_select_scan_engine_cost_based():
    eng, costs, src = select_scan_engine(100_000, mesh=None)
    assert eng == "local" and set(costs) == {"local"}
    assert src == {"kind": "heuristic"}  # no calibration active
    # >1 segment: the two-phase sharded plan is strictly cheaper
    eng, costs, _ = select_scan_engine(100_000, mesh=_FakeMesh(4))
    assert eng == "sharded"
    assert costs["sharded"] < costs["local"]
    # degenerate 1-segment mesh: tie breaks to the local fold
    eng, _, _ = select_scan_engine(100_000, mesh=_FakeMesh(1))
    assert eng == "local"
    # forced engine is honored, not re-derived
    eng, _, _ = select_scan_engine(100_000, mesh=_FakeMesh(4),
                                   forced="local")
    assert eng == "local"


def test_select_grouped_method_cost_based():
    m, costs, src = select_grouped_method(100_000, 64, segment_ok=True)
    assert m == "segment" and costs["segment"] < costs["masked"]
    assert src == {"kind": "heuristic"}
    m, costs, _ = select_grouped_method(100_000, 64, segment_ok=False)
    assert m == "masked" and "segment" not in costs
    with pytest.raises(ValueError, match="segment"):
        select_grouped_method(100_000, 64, segment_ok=False,
                              forced="segment")


# -- scan sharing across statements -------------------------------------------

def test_batch_three_statements_one_pass(table):
    """The acceptance criterion: >=3 independent one-pass statements over
    one table -> exactly ONE data pass, bit-identical to per-statement
    direct engine calls on exact-state aggregates."""
    sess = Session()
    h_cm = sess.scan(_cm(), table)
    h_fm = sess.scan(_fm(), table)
    h_hist = sess.scan(_hist(), table)
    with trace_execution() as t:
        sess.run()
    assert len(t.scans) == 1, [e.engine for e in t.scans]

    # per-statement direct engine execution (the pre-plan dataflow)
    solo_cm = run_local(_cm(), table)
    solo_fm = run_local(_fm(), table)
    solo_hist = run_local(_hist(), table)
    # integer sketch counters / bitmap states and histogram counts are
    # exact: planned fusion must be BIT-identical, not just close
    assert np.array_equal(np.asarray(h_cm.result()), np.asarray(solo_cm))
    assert float(h_fm.result()) == float(solo_fm)
    assert np.array_equal(np.asarray(h_hist.result()),
                          np.asarray(solo_hist))


def test_planned_profile_and_linregr_share_scan(table):
    sess = Session()
    h_prof = sess.profile(table.select("x", "y"))
    h_ols = sess.linregr(table)
    with trace_execution() as t:
        sess.run()
    # profile scans its own (projected) table; linregr scans `table` —
    # two tables, two passes, but profile's members still fuse
    assert len(t.scans) == 2
    prof = h_prof.result()
    solo = run_local(ProfileAggregate(), table.select("x", "y"))
    np.testing.assert_allclose(np.asarray(prof["y"]["mean"]),
                               np.asarray(solo["y"]["mean"]), rtol=1e-6)
    res = h_ols.result()
    from repro.methods.linregr import linregr
    solo_ols = linregr(table)
    np.testing.assert_allclose(np.asarray(res.coef),
                               np.asarray(solo_ols.coef), rtol=1e-6)


def test_projection_isolates_templated_members(table):
    """A fused ProfileAggregate member must profile exactly ITS
    statement's columns even when the fused block carries more."""
    sess = Session()
    h_prof = sess.scan(ProfileAggregate(), table, columns=("y",))
    h_cm = sess.scan(_cm(), table)
    with trace_execution() as t:
        sess.run()
    assert len(t.scans) == 1
    assert set(h_prof.result()) == {"y"}


# -- the mixed-mask correctness trap ------------------------------------------

def test_mixed_masks_plan_as_separate_passes(table):
    m1 = np.arange(N) % 2 == 0
    m2 = np.arange(N) % 3 == 0
    sess = Session()
    h1 = sess.scan(_hist(), table, mask=m1)
    h2 = sess.scan(_hist(), table, mask=m2)
    h3 = sess.scan(_hist(), table)  # no mask: its own pass too
    pl = plan(sess._nodes)
    assert len(pl.passes) == 3
    sess.run()
    for h, m in ((h1, m1), (h2, m2), (h3, None)):
        solo = run_local(_hist(), table, mask=None if m is None
                         else jnp.asarray(m))
        assert np.array_equal(np.asarray(h.result()), np.asarray(solo))


def test_mixed_mask_fusion_rejected_loudly(table):
    m1 = jnp.asarray(np.arange(N) % 2 == 0)
    m2 = jnp.asarray(np.arange(N) % 3 == 0)
    members = [(0, ScanAgg(_hist(), table, mask=m1)),
               (1, ScanAgg(_hist(), table, mask=m2))]
    with pytest.raises(ValueError, match="mixed-mask"):
        fused_scan_pass(members)


def test_cross_table_and_block_size_fusion_rejected(table, key):
    other = Table.from_columns({"y": jax.random.normal(key, (N,))})
    with pytest.raises(ValueError, match="different tables"):
        fused_scan_pass([(0, ScanAgg(_hist(), table)),
                         (1, ScanAgg(_hist(), other))])
    with pytest.raises(ValueError, match="block_size"):
        fused_scan_pass([(0, ScanAgg(_hist(), table, block_size=64)),
                         (1, ScanAgg(_hist(), table, block_size=128))])


# -- sort dedup ---------------------------------------------------------------

def test_grouped_statements_share_one_sort_and_scan(table):
    sess = Session()
    h_cm = sess.grouped_scan(_cm(), table, "g", columns=("item",))
    h_fm = sess.grouped_scan(_fm(), table, "g", columns=("item",))
    h_lr = sess.grouped_scan(LinregrAggregate(), table, "g",
                             columns=("x", "y"))
    with trace_execution() as t:
        sess.run()
    assert len(t.sorts) == 1, "N grouped statements must share ONE sort"
    assert len(t.scans) == 1, "compatible grouped statements must fuse"
    solo_cm = run_grouped(_cm(), table.select("item", "g"), "g", GROUPS)
    assert np.array_equal(np.asarray(h_cm.result()), np.asarray(solo_cm))
    solo_lr = run_grouped(LinregrAggregate(),
                          table.select("x", "y", "g"), "g", GROUPS)
    np.testing.assert_allclose(np.asarray(h_lr.result().coef),
                               np.asarray(solo_lr.coef),
                               rtol=1e-5, atol=1e-5)
    assert h_fm.result().shape == (GROUPS,)


def test_group_by_memo_across_plans_and_invalidate(table):
    tbl = Table.from_columns({k: v for k, v in table.columns.items()})
    with trace_execution() as t:
        execute(GroupedScanAgg(_cm(), tbl, "g", columns=("item",)))
        execute(GroupedScanAgg(_fm(), tbl, "g", columns=("item",)))
    assert len(t.sorts) == 1, "the group_by memo spans separate plans"
    assert tbl.group_by("g") is tbl.group_by("g", GROUPS)
    tbl.invalidate()
    with trace_execution() as t:
        tbl.group_by("g")
    assert len(t.sorts) == 1, "invalidate() must drop the memo"


def test_quantiles_grouped_single_sort(table):
    from repro.methods.quantiles import quantiles_grouped
    with trace_execution() as t:
        out = quantiles_grouped(table.select("y", "g").with_column(
            "v", table["y"]), "g", [0.25, 0.5, 0.75], bins=128)
    assert len(t.sorts) == 1
    assert len(t.scans) == 2  # range pass + histogram pass
    assert out.shape == (GROUPS, 3)


def test_sort_permutation_memo_spans_grouped_entry_points(table):
    """The hoisted ``Table.sort_permutation`` memo is shared by EVERY
    consumer of a table's partitioning sort: ``fit_grouped`` and a
    planned grouped scan over the same (table, key) pay ONE argsort;
    ``quantiles_grouped``'s two internal passes pay one more on its
    projection table; and ``Trace.summary()`` attributes each to its
    table in the ``sorts_by_table`` rollup."""
    from repro.core import fit_grouped
    from repro.methods.linregr import LinregrTask
    from repro.methods.quantiles import quantiles_grouped
    tbl = Table.from_columns({k: v for k, v in table.columns.items()})
    tbl = tbl.with_column("v", tbl["y"])
    with trace_execution() as t:
        quantiles_grouped(tbl, "g", [0.5], bins=64)
        fit_grouped(LinregrTask(), tbl, "g", GROUPS, max_iters=1, tol=None)
        execute(GroupedScanAgg(LinregrAggregate(), tbl, "g",
                               columns=("x", "y")))
    assert len(t.sorts) == 2, "one sort per (table, key), ever"
    by = t.summary()["sorts_by_table"]
    assert by[id(tbl)] == 1 and sorted(by.values()) == [1, 1]
    perm_a = tbl.sort_permutation("g")
    perm_b = tbl.sort_permutation("g")
    assert perm_a is perm_b, "memo must return the identical product"


# -- stream fusion ------------------------------------------------------------

def test_stream_statements_fuse_over_shared_source(table):
    blocks = iter([{"item": np.arange(100) % 30},
                   {"item": np.arange(100) % 60}])
    sess = Session()
    h_cm = sess.stream_scan(_cm(), blocks)
    h_fm = sess.stream_scan(_fm(), blocks)
    with trace_execution() as t:
        sess.run()
    # mandatory fusion: the shared iterator is consumed exactly once
    assert len(t.scans) == 1
    solo_tbl = Table.from_columns({"item": np.concatenate(
        [np.arange(100) % 30, np.arange(100) % 60])})
    assert np.array_equal(np.asarray(h_cm.result()),
                          np.asarray(run_local(_cm(), solo_tbl)))
    assert float(h_fm.result()) == float(run_local(_fm(), solo_tbl))


# -- fits through the plan layer ----------------------------------------------

def test_session_fit_matches_eager(two_tables=None):
    from repro.core import synthetic_classification_table
    from repro.methods.logregr import logregr
    tbl, _ = synthetic_classification_table(jax.random.PRNGKey(3), 2000, 4)
    sess = Session()
    h = sess.logregr(tbl, max_iters=8)
    with trace_execution() as t:
        sess.run()
    assert len(t.fits) == 1 and t.fits[0].engine == "local"
    eager = logregr(tbl, max_iters=8)
    np.testing.assert_allclose(np.asarray(h.result().coef),
                               np.asarray(eager.coef), rtol=1e-6)
    assert h.result().n_iters == eager.n_iters


def test_handle_before_run_raises(table):
    sess = Session()
    h = sess.scan(_cm(), table)
    with pytest.raises(RuntimeError, match="has not executed"):
        h.result()


# -- golden EXPLAIN plans -----------------------------------------------------

def test_explain_golden_fused_batch(table):
    sess = Session()
    sess.scan(_cm(), table)
    sess.scan(_fm(), table)
    sess.scan(_hist(), table)
    sess.grouped_scan(_cm(), table, "g", num_groups=GROUPS,
                      columns=("item",))
    sess.grouped_scan(_fm(), table, "g", num_groups=GROUPS,
                      columns=("item",))
    assert sess.explain() == (
        "plan: 5 statements -> 2 passes, 1 sort\n"
        "  pass 0: shared-scan [local] t0 rows=512 cost=512 [heuristic]\n"
        "    s0: CountMinAggregate\n"
        "    s1: FMAggregate\n"
        "    s2: HistogramAggregate\n"
        "  pass 1: grouped-scan [grouped-segment] t0 by g groups=4 "
        "sort=v0 rows=512 cost=1024 [heuristic] (rejected: masked=2048)\n"
        "    s3: CountMinAggregate\n"
        "    s4: FMAggregate"
    )


def test_explain_golden_masked_and_fit(table, key):
    mask = jnp.asarray(np.arange(N) % 2 == 0)
    from repro.methods.logregr import IRLSTask
    from repro.core import IterativeFit
    sess = Session()
    sess.scan(_hist(), table, mask=mask, block_size=128)
    sess.grouped_scan(_cm(), table, "g", num_groups=GROUPS,
                      columns=("item",), method="masked")
    sess.statement(IterativeFit(
        IRLSTask(), table.select("x", "y"), max_iters=5, tol=1e-4,
        label="irls"))
    assert sess.explain() == (
        "plan: 3 statements -> 3 passes, 1 sort\n"
        "  pass 0: shared-scan [local] t0 rows=512 mask=yes block=128 "
        "cost=512 [heuristic]\n"
        "    s0: HistogramAggregate\n"
        "  pass 1: grouped-scan [grouped-masked] t0 by g groups=4 "
        "sort=v0 rows=512 cost=2048 [heuristic] (rejected: segment=1024)\n"
        "    s1: CountMinAggregate\n"
        "  pass 2: fit [local] t1 rows=512 max_iters=5 tol=0.0001 "
        "cost=2560 [heuristic]\n"
        "    irls: IRLSTask"
    )


# -- measured calibration -> planner costs ------------------------------------

def _cal(engines, **kw):
    from repro.core.calibration import Calibration
    return Calibration(backend="cpu", timestamp="2026-08-07T00:00:00",
                       engines=engines, kernels=kw.get("kernels", {}),
                       grouped_block=kw.get("grouped_block", []))


def test_calibration_flips_grouped_choice_and_explain(table):
    """An active calibration whose measurements contradict the heuristic
    must drive BOTH the selection and the explain() annotation; without
    activation the PR-5 heuristic behavior is unchanged."""
    from repro.core import calibration
    cal = _cal({
        "grouped-segment": {"sketch": [
            {"rows": 512, "groups": 4, "seconds": 2.0e-3}]},
        "grouped-masked": {"sketch": [
            {"rows": 512, "groups": 4, "seconds": 5.0e-4}]},
    })
    sess = Session()
    sess.grouped_scan(_cm(), table, "g", num_groups=GROUPS,
                      columns=("item",))
    with calibration.use(cal):
        txt = sess.explain()
    assert "[grouped-masked]" in txt, txt       # measured ranking wins
    assert "[measured cpu@2026-08-07T00:00:00]" in txt
    assert "cost=0.50ms" in txt and "segment=2.00ms" in txt
    # calibration file on disk but NOT activated: heuristics, unchanged
    txt2 = sess.explain()
    assert "[grouped-segment]" in txt2 and "[heuristic]" in txt2
    assert "cost=1024" in txt2


def test_calibration_partial_coverage_falls_back(table):
    """Measured seconds never rank against heuristic row counts: a
    calibration missing ANY candidate leaves the whole selection on the
    heuristic model."""
    from repro.core import calibration
    cal = _cal({"grouped-masked": {"generic": [
        {"rows": 512, "groups": 4, "seconds": 1e-6}]}})  # no segment entry
    with calibration.use(cal):
        m, costs, src = select_grouped_method(512, 4, segment_ok=True)
    assert m == "segment" and src == {"kind": "heuristic"}
    assert costs["segment"] == 1024


def test_calibration_bucket_interpolation():
    from repro.core.calibration import Calibration
    cal = Calibration(
        backend="cpu", timestamp="t", kernels={}, grouped_block=[
            {"rows": 1024, "groups": 4, "block": 256},
            {"rows": 1 << 20, "groups": 4, "block": 4096}],
        engines={"local": {"generic": [
            {"rows": 1000, "seconds": 1.0},
            {"rows": 1_000_000, "seconds": 50.0}]}})
    # nearest log2 bucket, linearly scaled in rows
    assert cal.engine_seconds("local", "generic", 2000) == 2.0
    assert cal.engine_seconds("local", "generic", 500_000) == 25.0
    # class fallback: unmeasured class uses the generic tables
    assert cal.engine_seconds("local", "xtx", 1000) == 1.0
    assert cal.engine_seconds("sharded", "generic", 1000) is None
    # measured-best grouped block per shape bucket
    assert cal.grouped_block_size(2048, 4) == 256
    assert cal.grouped_block_size(1 << 19, 4) == 4096


def test_calibration_drives_segment_block_size():
    from repro.core import calibration
    from repro.core.aggregates import segment_block_size
    heur = segment_block_size(10_000, 10)
    cal = _cal({}, grouped_block=[{"rows": 10_000, "groups": 10,
                                   "block": 512}])
    with calibration.use(cal):
        assert segment_block_size(10_000, 10) == 512
        # explicit block_size still wins over the measurement
        assert segment_block_size(10_000, 10, 64) == 64
    assert segment_block_size(10_000, 10) == heur
