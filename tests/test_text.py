"""Statistical text analytics tests (§5.2, Table 3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Table
from repro.core.aggregates import run_local
from repro.core.convex import sgd as sgd_solver


@pytest.fixture(scope="module")
def crf_setup(key):
    from repro.methods.crf import crf_init_params, crf_program, \
        extract_features
    kk = jax.random.split(key, 4)
    B, T, V, L, F = 64, 12, 30, 3, 64
    toks = jax.random.randint(kk[0], (B, T), 0, V)
    labels = (toks % L).astype(jnp.int32)
    mask = jnp.ones((B, T), jnp.float32)
    feats = extract_features(toks, F)
    tbl = Table.from_columns({"feats": feats, "labels": labels,
                              "mask": mask})
    params = sgd_solver(crf_program(F, L, mu=1e-4), tbl,
                        crf_init_params(F, L, kk[1]), stepsize=0.3,
                        epochs=20, batch=16, key=kk[2], anneal=False)
    return params, feats, labels, mask, kk[3]


def test_crf_training_viterbi(crf_setup):
    from repro.methods.crf import viterbi_decode
    params, feats, labels, mask, _ = crf_setup
    pred = viterbi_decode(params, feats, mask)
    assert float(jnp.mean(pred == labels)) > 0.9


def test_crf_loglik_increases_with_training(key, crf_setup):
    from repro.methods.crf import crf_init_params, crf_log_likelihood
    params, feats, labels, mask, _ = crf_setup
    init = crf_init_params(64, 3, key)
    ll_init = float(crf_log_likelihood(init, feats, labels, mask))
    ll_trained = float(crf_log_likelihood(params, feats, labels, mask))
    assert ll_trained > ll_init


def test_viterbi_beats_or_matches_greedy(crf_setup):
    from repro.methods.crf import crf_log_likelihood, emissions, \
        viterbi_decode
    params, feats, labels, mask, _ = crf_setup
    vit = viterbi_decode(params, feats, mask)
    greedy = jnp.argmax(emissions(params, feats), -1)
    ll_vit = float(crf_log_likelihood(params, feats, vit, mask))
    ll_greedy = float(crf_log_likelihood(params, feats, greedy, mask))
    assert ll_vit >= ll_greedy - 1e-3  # max-product optimality


def test_gibbs_inference(crf_setup):
    from repro.methods.crf import gibbs_sample
    params, feats, labels, mask, k = crf_setup
    sampled, marginals = gibbs_sample(params, feats, mask, k, n_sweeps=20)
    assert float(jnp.mean(sampled == labels)) > 0.75
    np.testing.assert_allclose(np.asarray(jnp.sum(marginals, -1)), 1.0,
                               atol=1e-4)


def test_mh_inference(crf_setup):
    from repro.methods.crf import mh_sample
    params, feats, labels, mask, k = crf_setup
    sampled, acc_rate = mh_sample(params, feats, mask, k, n_steps=300)
    assert float(jnp.mean(sampled == labels)) > 0.6
    assert 0.05 < float(acc_rate) < 0.95


def test_string_match_trigram():
    from repro.methods.string_match import (TrigramIndexAggregate,
                                            approx_match, encode_strings)
    corpus = ["tim tebow", "tom brady", "tim duncan", "peyton manning",
              "tim tebow jr", "aaron rodgers"]
    chars = encode_strings(corpus)
    tbl = Table.from_columns({"chars": chars,
                              "doc_id": jnp.arange(len(corpus))})
    index = run_local(TrigramIndexAggregate(len(corpus), 512), tbl)
    idx, scores = approx_match(index, "tim tebow", threshold=0.4)
    matched = {corpus[i] for i in np.asarray(idx) if i >= 0}
    assert matched == {"tim tebow", "tim tebow jr"}
    assert float(scores[0]) == pytest.approx(1.0)   # exact match -> 1.0
    assert float(scores[1]) < 0.1                    # unrelated -> ~0


def test_feature_extraction_shapes(key):
    from repro.methods.crf import extract_features
    toks = jax.random.randint(key, (4, 9), 0, 100)
    feats = extract_features(toks, 128)
    assert feats.shape == (4, 9, 3)
    assert int(jnp.max(feats)) < 128 and int(jnp.min(feats)) >= 0
    dictionary = jnp.zeros((100,), jnp.int32).at[:50].set(1)
    feats_d = extract_features(toks, 128, dictionary)
    assert feats_d.shape == (4, 9, 4)
