"""Per-kernel validation: Pallas body (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes, plus the dispatch registry every method
call site routes through."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest


# -- dispatch registry --------------------------------------------------------

def test_registry_lists_builtin_kernels():
    from repro.kernels import registry
    assert set(registry.available()) >= {
        "xtx", "kmeans_assign", "countmin", "flash_attention"}


def test_registry_auto_falls_back_to_ref_off_tpu(key):
    from repro.kernels import registry
    x = jax.random.normal(key, (256, 8))
    y = jax.random.normal(key, (256,))
    entry = registry.get("xtx")
    if jax.default_backend() != "tpu":
        assert entry.pick(x, y) == "ref"
    out = registry.dispatch("xtx", x, y)
    ref = registry.dispatch("xtx", x, y, impl="ref")
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]),
                               rtol=1e-5, atol=1e-4)


def test_registry_pallas_impl_matches_ref(key):
    """impl="pallas" always runs the kernel body (interpret off-TPU)."""
    from repro.kernels import registry
    items = jax.random.randint(key, (333,), 0, 400)
    mask = jax.random.uniform(jax.random.fold_in(key, 1), (333,)) > 0.3
    a = registry.dispatch("countmin", items, mask, 4, 128, impl="pallas")
    b = registry.dispatch("countmin", items, mask, 4, 128, impl="ref")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_registry_unknown_kernel_and_duplicate():
    from repro.kernels import registry
    with pytest.raises(KeyError):
        registry.get("no_such_kernel")
    with pytest.raises(ValueError):
        registry.dispatch("xtx", impl="bogus")
    with pytest.raises(ValueError):
        registry.register("xtx", ref=lambda: None)
    # explicit overwrite is allowed and undone to keep the session clean
    orig = registry.get("xtx")
    registry.register("xtx", ref=orig.ref, pallas=orig.pallas,
                      supports=orig.supports, overwrite=True)


def test_registry_resolve_impl():
    from repro.kernels.registry import resolve_impl
    assert resolve_impl(False) is None
    assert resolve_impl(True) == "auto"
    assert resolve_impl("pallas") == "pallas"
    assert resolve_impl("ref") == "ref"
    with pytest.raises(ValueError):
        resolve_impl("mxu")


def test_registry_flash_supports_gates_ragged_seq(key):
    from repro.kernels import registry
    entry = registry.get("flash_attention")
    q = jax.random.normal(key, (1, 2, 96, 32))
    k = jax.random.normal(key, (1, 1, 96, 32))
    # 96 % 64 != 0 -> the Pallas tiling can't take it; auto must pick ref
    assert not entry.supports(q, k, k, tile_q=64, tile_k=64)
    assert entry.pick(q, k, k, tile_q=64, tile_k=64) == "ref"
    assert entry.supports(q, k, k, tile_q=32, tile_k=32)


# -- xtx ----------------------------------------------------------------------

@pytest.mark.parametrize("n,d", [(64, 4), (333, 7), (1000, 10), (2048, 128),
                                 (1024, 320)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_xtx_kernel(key, n, d, dtype):
    from repro.kernels.xtx import ops, ref
    kx, ky = jax.random.split(jax.random.fold_in(key, n * d))
    x = jax.random.normal(kx, (n, d), dtype)
    y = jax.random.normal(ky, (n,), dtype)
    xtx, xty = ops.xtx_xty(x, y)
    rxtx, rxty = ref.xtx_xty_ref(x, y)
    rtol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(xtx), np.asarray(rxtx), rtol=rtol,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(xty), np.asarray(rxty), rtol=rtol,
                               atol=1e-3)
    assert xtx.dtype == jnp.float32  # f32 accumulation policy


def test_xtx_kernel_symmetry(key):
    from repro.kernels.xtx import ops
    x = jax.random.normal(key, (512, 24))
    xtx, _ = ops.xtx_xty(x, jnp.zeros(512))
    np.testing.assert_allclose(np.asarray(xtx), np.asarray(xtx.T),
                               rtol=1e-6)


# -- kmeans_assign --------------------------------------------------------------

@pytest.mark.parametrize("n,d,k", [(256, 2, 4), (777, 17, 9), (1024, 64, 32),
                                   (100, 3, 5)])
def test_kmeans_assign_kernel(key, n, d, k):
    from repro.kernels.kmeans_assign import ops, ref
    kx, kc, km = jax.random.split(jax.random.fold_in(key, n + d + k), 3)
    x = jax.random.normal(kx, (n, d))
    c = 2.0 * jax.random.normal(kc, (k, d))
    m = (jax.random.uniform(km, (n,)) > 0.1).astype(jnp.float32)
    a, mind, sums, counts = ops.assign_and_reduce(x, c, m)
    ra, rmind, rsums, rcounts = ref.assign_and_reduce_ref(x, c, m)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ra))
    np.testing.assert_allclose(np.asarray(mind), np.asarray(rmind),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(rsums),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(rcounts))


@pytest.mark.parametrize("use_kernel", [True, "pallas"])
def test_kmeans_kernel_in_method(key, use_kernel):
    """End-to-end: registry-dispatched kmeans_fit equals the inline path
    (True = auto dispatch; "pallas" pins the kernel body, interpret mode
    off-TPU)."""
    from repro.methods.kmeans import kmeans_fit
    from repro.core import Table
    pts = jax.random.normal(key, (512, 4))
    tbl = Table.from_columns({"x": pts})
    seed = jax.random.normal(jax.random.fold_in(key, 1), (3, 4))
    a = kmeans_fit(tbl, 3, init_centroids=seed, max_iters=5)
    b = kmeans_fit(tbl, 3, init_centroids=seed, max_iters=5,
                   use_kernel=use_kernel)
    np.testing.assert_allclose(np.asarray(a.centroids),
                               np.asarray(b.centroids), rtol=1e-4,
                               atol=1e-4)


# -- countmin -------------------------------------------------------------------

@pytest.mark.parametrize("n,depth,width", [(256, 4, 256), (1000, 8, 1024),
                                           (123, 2, 128)])
def test_countmin_kernel(key, n, depth, width):
    from repro.kernels.countmin import ops, ref
    ki, km = jax.random.split(jax.random.fold_in(key, n))
    items = jax.random.randint(ki, (n,), 0, 500)
    mask = jax.random.uniform(km, (n,)) > 0.2
    out = ops.countmin_block(items, mask, depth, width)
    expect = ref.countmin_block_ref(items, mask, depth, width)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
    assert int(out.sum()) == depth * int(mask.sum())


# -- flash attention -------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hk,s,d,causal", [
    (1, 2, 1, 128, 64, True),
    (2, 4, 2, 256, 64, True),
    (1, 8, 1, 128, 128, False),
    (1, 2, 2, 64, 32, True),
    (1, 4, 4, 128, 64, True),   # MHA (group=1)
])
def test_flash_attention_kernel(key, b, hq, hk, s, d, causal):
    from repro.kernels.flash_attention import ops, ref
    kq, kk, kv = jax.random.split(jax.random.fold_in(key, s * hq + d), 3)
    q = jax.random.normal(kq, (b, hq, s, d))
    k = jax.random.normal(kk, (b, hk, s, d))
    v = jax.random.normal(kv, (b, hk, s, d))
    out = ops.flash_attention(q, k, v, causal=causal, tile_q=min(64, s),
                              tile_k=min(64, s), force=True)
    expect = ref.attention_ref(q, k, v, scale=1.0 / d ** 0.5, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


def test_flash_attention_bf16(key):
    from repro.kernels.flash_attention import ops, ref
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (1, 2, 128, 64), jnp.bfloat16)
    k = jax.random.normal(kk, (1, 1, 128, 64), jnp.bfloat16)
    v = jax.random.normal(kv, (1, 1, 128, 64), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, tile_q=64, tile_k=64, force=True)
    expect = ref.attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), scale=1.0 / 8.0)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect), rtol=3e-2, atol=3e-2)
    assert out.dtype == jnp.bfloat16


def test_flash_attention_causality(key):
    """Future tokens must not influence outputs: perturb token t+1 …"""
    from repro.kernels.flash_attention import ops
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (1, 2, 64, 32))
    k = jax.random.normal(kk, (1, 1, 64, 32))
    v = jax.random.normal(kv, (1, 1, 64, 32))
    base = ops.flash_attention(q, k, v, tile_q=32, tile_k=32, force=True)
    k2 = k.at[:, :, 40:].add(10.0)
    v2 = v.at[:, :, 40:].add(10.0)
    pert = ops.flash_attention(q, k2, v2, tile_q=32, tile_k=32, force=True)
    np.testing.assert_allclose(np.asarray(base[:, :, :40]),
                               np.asarray(pert[:, :, :40]), rtol=1e-5,
                               atol=1e-6)
    assert float(jnp.max(jnp.abs(base[:, :, 41:] - pert[:, :, 41:]))) > 1e-3


@pytest.mark.parametrize("use_kernel", [True, "pallas"])
def test_linregr_kernel_in_method(key, use_kernel):
    """Registry-dispatched linregr == inline-transition linregr."""
    from repro.core import synthetic_regression_table
    from repro.methods.linregr import linregr
    tbl, _ = synthetic_regression_table(key, 2048, 12)
    a = linregr(tbl)
    b = linregr(tbl, use_kernel=use_kernel)
    np.testing.assert_allclose(np.asarray(a.coef), np.asarray(b.coef),
                               rtol=1e-4, atol=1e-5)
