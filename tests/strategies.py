"""Seeded, hypothesis-free property-test strategies.

The container image carries no ``hypothesis``; this module gives the
property suite the same input diversity with explicit, reproducible
seeding: every case is a :class:`Draw` derived from ``(base_seed,
case_index)``, and assertion messages should embed ``draw.seed`` so any
failure replays with ``Draw(seed)``.

Generators cover the shapes the engine contract cares about:

* table sizes / feature dims (ragged block tails included),
* group layouts — uniform, zipf-skewed, empty groups, singleton groups,
  non-contiguous (round-robin) ids, and everything-in-one-group,
* star-schema join layouts — clean, dangling foreign keys, skewed
  fan-out, empty dimension, duplicate dimension keys (invalid input the
  join must reject), duplicate attribute values (collapsed by GROUP BY),
* dyadic-exact feature draws (small multiples of ``1/denom``), whose f32
  sums and pairwise products are exact so fold ORDER cannot change any
  aggregate state — the input class that turns allclose engine-parity
  checks into bit-identical ones.
"""

from __future__ import annotations

import numpy as np

__all__ = ["Draw", "cases", "group_layout", "GROUP_PATTERNS",
           "join_layout", "JOIN_PATTERNS"]

GROUP_PATTERNS = ("uniform", "skewed", "empty", "singleton",
                  "non_contiguous", "one_group")

JOIN_PATTERNS = ("clean", "dangling", "skewed", "empty_dim", "dup_keys",
                 "dup_attr")


class Draw:
    """One generated case: a seeded ``np.random.Generator`` with the
    draw helpers property tests need.  ``Draw(seed)`` replays a case."""

    def __init__(self, seed: int):
        self.seed = int(seed)
        self.rng = np.random.default_rng(self.seed)

    def __repr__(self):  # shows up in assertion messages
        return f"Draw(seed={self.seed})"

    # -- scalars -----------------------------------------------------------
    def integers(self, lo: int, hi: int) -> int:
        """Uniform int in [lo, hi] inclusive."""
        return int(self.rng.integers(lo, hi + 1))

    def floats(self, lo: float, hi: float) -> float:
        return float(lo + (hi - lo) * self.rng.random())

    def sample(self, seq):
        return seq[int(self.rng.integers(0, len(seq)))]

    # -- arrays ------------------------------------------------------------
    def normal(self, shape, dtype=np.float32) -> np.ndarray:
        return self.rng.standard_normal(shape).astype(dtype)

    def uniform(self, shape, lo=0.0, hi=1.0, dtype=np.float32) -> np.ndarray:
        return (lo + (hi - lo) * self.rng.random(shape)).astype(dtype)

    def ints(self, shape, lo: int, hi: int) -> np.ndarray:
        """Uniform int array in [lo, hi] inclusive."""
        return self.rng.integers(lo, hi + 1, size=shape).astype(np.int32)

    def bools(self, shape, p: float = 0.5) -> np.ndarray:
        return self.rng.random(shape) < p

    def dyadic(self, shape, denom: int = 8, scale: float = 1.0
               ) -> np.ndarray:
        """~N(0, scale) rounded to multiples of 1/denom: exactly
        representable in f32, with exact sums/products at test sizes."""
        v = np.round(self.rng.standard_normal(shape) * scale * denom)
        return (v / denom).astype(np.float32)

    def permutation(self, n: int) -> np.ndarray:
        return self.rng.permutation(n)


def cases(n_cases: int = 10, base_seed: int = 0):
    """Iterate ``n_cases`` independent :class:`Draw` objects.  The seed
    mixing keeps different (test, base_seed) streams disjoint."""
    for i in range(n_cases):
        yield Draw(base_seed * 1_000_003 + i)


def group_layout(draw: Draw, n: int, num_groups: int,
                 pattern: str | None = None):
    """A ``(n,)`` int32 group-id column exercising one GROUP BY layout
    class; returns ``(gids, pattern)``.

    Patterns: ``uniform`` ids; ``skewed`` zipf-ish sizes (a few big
    segments, a long tail); ``empty`` leaves at least one id unused;
    ``singleton`` pins one group to exactly one row; ``non_contiguous``
    round-robins ids so no group's rows are adjacent; ``one_group`` puts
    every row in a single id.
    """
    G = max(1, int(num_groups))
    if pattern is None:
        pattern = draw.sample(GROUP_PATTERNS)
    if pattern == "uniform":
        gids = draw.ints((n,), 0, G - 1)
    elif pattern == "skewed":
        w = 1.0 / (np.arange(G) + 1.0)
        gids = draw.rng.choice(G, size=n, p=w / w.sum()).astype(np.int32)
    elif pattern == "empty":
        used = max(1, G - max(1, G // 3))  # ids [used, G) stay empty
        gids = draw.ints((n,), 0, used - 1)
    elif pattern == "singleton":
        gids = draw.ints((n,), 0, G - 1)
        solo = draw.integers(0, G - 1)
        gids[gids == solo] = (solo + 1) % G if G > 1 else 0
        gids[draw.integers(0, n - 1)] = solo  # exactly one row
    elif pattern == "non_contiguous":
        gids = (np.arange(n) % G).astype(np.int32)
    elif pattern == "one_group":
        gids = np.full((n,), draw.integers(0, G - 1), np.int32)
    else:
        raise ValueError(f"unknown group pattern {pattern!r}")
    return gids.astype(np.int32), pattern


def join_layout(draw: Draw, n_fact: int, n_dim: int, num_groups: int,
                pattern: str | None = None):
    """A star-schema equi-join case: ``(fk, dim_keys, dim_attr, pattern)``
    — fact foreign keys, dimension primary keys (non-contiguous, shuffled
    so the join cannot cheat by treating keys as row indices), and the
    dimension attribute being grouped by.

    Patterns: ``clean`` every FK matches; ``dangling`` some FKs hit no
    dimension key (exercises ``on_missing=``); ``skewed`` zipf-ish
    fan-out (a few dim rows own most fact rows); ``empty_dim`` a zero-row
    dimension; ``dup_keys`` duplicate dimension KEYS — invalid input the
    join must reject loudly; ``dup_attr`` distinct keys sharing attribute
    values (GROUP BY must collapse them into one group).
    """
    G = max(1, int(num_groups))
    if pattern is None:
        pattern = draw.sample(JOIN_PATTERNS)
    if pattern == "empty_dim":
        dim_keys = np.zeros(0, np.int32)
        dim_attr = np.zeros(0, np.int32)
        fk = draw.ints((n_fact,), 0, 1 << 20)
        return fk, dim_keys, dim_attr, pattern
    # sparse, shuffled key space: keys are NOT row positions or group ids
    dim_keys = draw.permutation(n_dim * 7)[:n_dim].astype(np.int32) + 11
    dim_attr = draw.ints((n_dim,), 0, G - 1)
    if pattern == "dup_attr":
        dim_attr = (np.arange(n_dim) % G).astype(np.int32)  # G << n_dim
    if pattern == "skewed":
        w = 1.0 / (np.arange(n_dim) + 1.0)
        rows = draw.rng.choice(n_dim, size=n_fact, p=w / w.sum())
    else:
        rows = draw.rng.integers(0, n_dim, size=n_fact)
    fk = dim_keys[rows].astype(np.int32)
    if pattern == "dangling":
        miss = draw.bools((n_fact,), p=0.2)
        if not miss.any():
            miss[draw.integers(0, n_fact - 1)] = True
        fk = np.where(miss, np.int32(-5), fk).astype(np.int32)
    if pattern == "dup_keys":
        dim_keys = dim_keys.copy()
        dim_keys[n_dim // 2] = dim_keys[0]  # invalid on purpose
    return fk, dim_keys, dim_attr, pattern
